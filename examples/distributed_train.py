"""Distributed-semantics example: the SAME train step the 256-chip dry-run
lowers, executed for real on a tiny 4-device debug mesh (CPU host devices),
with sharded params/optimizer/batch, microbatching, and both TP dataflows.

Run in a fresh process (device count must be set before jax init):

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python examples/distributed_train.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.configs.base import ShapeSpec
from repro.data.pipeline import SyntheticLM
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_debug_mesh
from repro.optim import adamw
from repro.sharding import rules


def main():
    assert len(jax.devices()) >= 4, "set XLA_FLAGS device count first"
    mesh = make_debug_mesh((2, 2), ("data", "model"))
    shape = ShapeSpec("tiny", seq_len=64, global_batch=8, kind="train")

    for tp_mode in ("allreduce", "allgather"):
        cfg = get("qwen3-1.7b").reduced().replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=512).with_policy(microbatches=2, tp_mode=tp_mode)
        oc = adamw.AdamWConfig(peak_lr=5e-3, warmup_steps=5, total_steps=50)
        fn, shapes, specs = steps_mod.make_train_step(cfg, mesh, shape,
                                                      opt_cfg=oc)
        pshapes, oshapes, _ = shapes
        pspec, ospec, bspec = specs

        from repro.models import model as lm
        params = lm.init(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, rules.to_named(pspec, mesh))
        opt = jax.device_put(adamw.init(params, oc),
                             rules.to_named(ospec, mesh))
        pipe = SyntheticLM(cfg, shape, seed=0)

        losses = []
        for step in range(20):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
            batch = jax.device_put(batch, rules.to_named(bspec, mesh))
            params, opt, mets = fn(params, opt, batch)
            losses.append(float(mets["loss_out"]))
        print(f"tp_mode={tp_mode}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"on mesh {dict(mesh.shape)}")
        assert losses[-1] < losses[0]
    print("distributed_train OK")


if __name__ == "__main__":
    main()
