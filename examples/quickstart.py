"""Quickstart: the paper's AME-on-PIM engine in five minutes.

1. Run AME instructions (mfadd/mfsub/mfmacc) on the functional Aquabolt-XL
   model and read the calibrated cycle costs (paper Figs 7-9).
2. Run an end-to-end GEMM entirely "in PIM mode" through the device
   runtime and compare against the reduction-free TPU kernel (ame_gemm,
   interpret mode on CPU).
3. Scale the same op across HBM pseudo-channels (the paper's future work)
   and dump an HBM-PIMulator-compatible command trace.

To *watch* a multi-channel schedule instead of just measuring it, export
a Perfetto-loadable profile and the critical-path attribution — see
docs/observability.md and ``examples/serve_lm.py --profile out.json``.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import AMEEngine, UnsupportedOnPIM, max_tile_mfmacc
from repro.runtime import PIMRuntime, emit_trace, parse_trace, pim_gemm
from repro.kernels.ame_gemm import ame_gemm
from repro.kernels import ref

rng = np.random.default_rng(0)


def main():
    # --- 1. AME instructions on the PIM engine ------------------------------
    eng = AMEEngine()
    a = jnp.asarray(rng.standard_normal((128, 64)) * 0.3, jnp.float16)
    b = jnp.asarray(rng.standard_normal((128, 64)) * 0.3, jnp.float16)
    eng.msettilem(128), eng.msettilek(64)
    eng.mld(0, a)
    eng.mld(1, b)
    rep = eng.mfadd(0, 0, 1)
    print(f"mfadd.h.mm 128x64: {rep.cycles:.0f} cycles "
          f"({rep.flop_per_cycle:.1f} FLOP/cycle)")
    rep = eng.mfsub(0, 0, 1)           # emulated: MUL by -1 + ADD (SUB-PEP)
    print(f"mfsub.h.mm 128x64: {rep.cycles:.0f} cycles "
          f"(emulated, {rep.flop_per_cycle:.1f} FLOP/cycle)")
    try:
        eng.mfmax(0, 0, 1)
    except UnsupportedOnPIM as e:
        print(f"mfmax.h.mm: correctly unsupported -> {e}")

    # matrix multiply via the reduction-free outer-product dataflow
    eng2 = AMEEngine()
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.3, jnp.float16)
    eng2.msettilem(128), eng2.msettilek(64), eng2.msettilen(32)
    eng2.mld(0, a)
    eng2.mld(1, w)
    rep = eng2.mfmacc(0, 0, 1)
    out = np.asarray(eng2.mst(0))
    ref_out = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
    print(f"mfmacc.h 128x64x32: {rep.cycles:.0f} cycles, "
          f"max err vs fp32 {np.abs(out - ref_out).max():.3f}")

    head = max_tile_mfmacc()
    print(f"\npaper headline (128x4096 tiles): {head.flop_per_cycle:.1f} "
          f"FLOP/cycle, {head.gflops:.1f} GFLOP/s, "
          f"{head.launches} MAC-PEP launches  [paper: 59.4 / 14.9 / 256]")

    # --- 2. end-to-end GEMM in PIM mode + the TPU-adapted kernel ------------
    A = jnp.asarray(rng.standard_normal((256, 192)) * 0.2, jnp.float16)
    B = jnp.asarray(rng.standard_normal((192, 96)) * 0.2, jnp.float16)
    C_pim, rep1 = pim_gemm(A, B)               # 1 pseudo-channel
    print(f"\npim_gemm 256x192x96: {rep1.makespan_cycles:.0f} modeled "
          f"cycles, {rep1.flop_per_cycle:.1f} FLOP/cycle at makespan")
    C_tpu = ame_gemm(A.astype(jnp.float32), B.astype(jnp.float32),
                     block_m=128, block_n=96, block_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(C_tpu - ref.gemm(A.astype(jnp.float32),
                                                 B.astype(jnp.float32)))))
    print(f"ame_gemm (output-stationary Pallas kernel, interpret): "
          f"max err {err:.2e}")

    # --- 3. the device runtime: multi-pseudo-channel scaling + traces -------
    C_2ch, rep2 = pim_gemm(A, B, channels=2)   # output partitioning
    assert np.array_equal(np.asarray(C_pim), np.asarray(C_2ch)), \
        "multi-channel execution is bit-exact with single-channel"
    print(f"\n2 pseudo-channels: {rep2.summary()}")
    print(f"speedup vs 1ch: "
          f"{rep1.makespan_cycles / rep2.makespan_cycles:.2f}x (makespan)")

    # analytic mode sweeps paper-scale shapes without running numerics
    big = np.zeros((512, 4096), np.float16), np.zeros((4096, 512), np.float16)
    _, rep16 = pim_gemm(*big, channels=16, placement="2d-block",
                        execute=False)
    print(f"16ch 512x4096x512 (analytic): {rep16.gflops:.0f} GFLOP/s, "
          f"util_min={min(rep16.utilizations()):.2f}")

    # every execution can be dumped as an HBM-PIMulator-style trace
    rt = PIMRuntime(channels=2)
    rt.gemm(A[:32, :24], B[:24, :16])
    stats = parse_trace(emit_trace(rt.stack))
    print(f"command trace: {stats.pim_commands} PIM column commands, "
          f"{stats.launches} PEP launches, opcodes={dict(stats.opcodes)}")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
