"""Serve a small model with batched requests: slot-based continuous
batching, prefill + batched decode, per-request latency stats.

With ``--pim-offload`` the decode path is mirrored onto a resident-weight
PIM runtime (weights placed once, balanced placement): each step's
matmuls are accounted on a 16-pseudo-channel stack and the run ends with
the steady-state PIM-vs-host roofline — weights amortized, h2d traffic
is activations only.

With ``--pim-numeric`` the sidecar also *executes* each step's matmul
set on the per-channel engines (weights materialized and resident) and
cross-checks every output — lm_head logits included — against an XLA
reference within FP16 accumulation tolerance.

With ``--profile out.json`` the offload runtime runs in async timeline
mode and the run additionally writes a Chrome-trace profile of the PIM
schedule (open at https://ui.perfetto.dev), prints the critical-path
attribution of the PIM makespan, and reports per-request TTFT/TPOT
percentiles from the serve loop's metrics — see docs/observability.md.

Request timestamps are stamped from a deterministic virtual clock by
default (latency percentiles are simulated seconds, identical across
runs and machines — see docs/serving.md); ``--wall`` restores
``time.time()`` stamping.  ``--traffic RATE`` additionally replays a
seeded Poisson arrival trace through the virtual-time ``TrafficServer``
and prints disaggregated-vs-colocated goodput at an SLO.

  PYTHONPATH=src python examples/serve_lm.py [--requests 12] [--slots 4]
  PYTHONPATH=src python examples/serve_lm.py --pim-offload
  PYTHONPATH=src python examples/serve_lm.py --pim-offload --pim-numeric
  PYTHONPATH=src python examples/serve_lm.py --profile pim_profile.json
  PYTHONPATH=src python examples/serve_lm.py --traffic 50
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get
from repro.models import model as lm
from repro.serve.loop import Request, Server
from repro.serve.offload import DecodeOffload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--pim-offload", action="store_true",
                    help="account decode matmuls on a resident-weight "
                         "PIM runtime and report the roofline")
    ap.add_argument("--pim-channels", type=int, default=16)
    ap.add_argument("--pim-numeric", action="store_true",
                    help="run the offloaded matmuls numerically on the "
                         "per-channel engines, cross-checked against XLA")
    ap.add_argument("--profile", metavar="OUT_JSON", default=None,
                    help="write a Chrome-trace profile of the PIM decode "
                         "schedule here (implies --pim-offload in async "
                         "timeline mode) and report critical-path + "
                         "TTFT/TPOT latency metrics")
    ap.add_argument("--wall", action="store_true",
                    help="stamp request timestamps from time.time() "
                         "instead of the deterministic virtual clock")
    ap.add_argument("--traffic", type=float, metavar="RATE_RPS",
                    default=None,
                    help="also replay a seeded Poisson trace at RATE_RPS "
                         "through the virtual-time TrafficServer and "
                         "print disaggregated vs colocated goodput")
    args = ap.parse_args()

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=4, d_model=256,
                                              d_ff=512, vocab_size=1024)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    metrics = None
    if args.profile:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    offload = DecodeOffload(cfg, channels=args.pim_channels,
                            numeric=args.pim_numeric,
                            async_mode=args.profile is not None,
                            metrics=metrics) \
        if args.pim_offload or args.pim_numeric or args.profile else None
    srv = Server(cfg, params, slots=args.slots, cache_len=160,
                 pim_offload=offload, metrics=metrics, wall=args.wall)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.integers(4, 32))
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(0, 1023, plen).astype(np.int32),
                           max_new=args.max_new))
    done = srv.run_until_drained()
    wall = time.time() - t0

    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.finished_at - r.submitted_at for r in done]
    print(f"served {len(done)} requests / {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s on CPU, slots={args.slots})")
    unit = "wall" if args.wall else "virtual"
    print(f"latency ({unit} seconds) p50={np.percentile(lat, 50):.4f}s "
          f"p99={np.percentile(lat, 99):.4f}s")
    assert len(done) == args.requests
    if offload is not None:
        roof = offload.roofline()
        print(f"pim offload [{roof['channels']}ch, {roof['placement']}]: "
              f"{len(offload.steps)} decode steps, "
              f"weights={roof['weight_bytes']}B uploaded once "
              f"({roof['upload_bytes']}B sharded)")
        print(f"  steady state (full batch): "
              f"h2d={roof['steady_h2d_bytes']}B (activations only), "
              f"d2h={roof['steady_d2h_bytes']}B, "
              f"weight reuse={roof['steady_reuse_bytes']}B/step")
        if args.pim_numeric:
            err = max(s.numeric_max_err for s in offload.steps)
            lerr = max(s.logits_max_err for s in offload.steps)
            print(f"  numeric decode-on-PIM: every matmul executed on the "
                  f"engines and matched XLA (max err={err:.1e}, "
                  f"lm_head logits err={lerr:.1e})")
        print(f"  roofline: pim={roof['steady_pim_s']:.2e}s vs "
              f"host={roof['steady_host_s']:.2e}s "
              f"({roof['steady_host_bound']}-bound host), "
              f"pim_vs_host={roof['steady_pim_vs_host']:.3f}")
        assert roof["steady_reuse_bytes"] == offload.weight_bytes
    if args.profile:
        from repro.obs import export_chrome_trace, profile_report
        trace = export_chrome_trace(offload.rt, args.profile)
        rep = profile_report(offload.rt)
        print(f"profile: {len(trace['traceEvents'])} events -> "
              f"{args.profile} (open at https://ui.perfetto.dev)")
        print(rep.summary(top_k=5))
        lat_sum = srv.latency_summary()
        ttft, tpot = lat_sum["ttft_s"], lat_sum["tpot_s"]
        print(f"serve latency [{lat_sum['requests']} requests, "
              f"{lat_sum['tokens']} tokens]: "
              f"ttft p50={ttft['p50']:.3f}s p99={ttft['p99']:.3f}s | "
              f"tpot p50={tpot['p50']:.4f}s p99={tpot['p99']:.4f}s")
    if args.traffic:
        from repro.serve.loop import TrafficServer
        from repro.serve.traffic import SLO, HostCostModel, poisson_trace
        off = DecodeOffload(cfg, channels=args.pim_channels)
        cost = HostCostModel(cfg)
        step_s = off.step(args.slots).pim_s
        slo = SLO(ttft_s=4 * cost.prefill_s(256), tpot_s=1.3 * step_s)
        tr = poisson_trace(args.traffic, 200, seed=7, prompt_len=256,
                           max_new=args.max_new)
        print(f"traffic: 200 Poisson arrivals @ {args.traffic:.1f} rps, "
              f"slo(ttft={slo.ttft_s:.4f}s tpot={slo.tpot_s:.5f}s)")
        for label, dis in (("disaggregated", True), ("colocated", False)):
            ts = TrafficServer(off, slots=args.slots, disaggregate=dis,
                               chunk_tokens=64, slo=slo)
            ts.run(tr)
            s = ts.latency_summary()
            print(f"  {label:13s}: goodput={s['goodput_rps']:8.2f} rps  "
                  f"attainment={s['slo_attainment']:.2f}  "
                  f"ttft_p99={s['ttft_s']['p99']:.4f}s  "
                  f"tpot_p99={s['tpot_s']['p99']:.5f}s")
    print("serve_lm OK")


if __name__ == "__main__":
    main()
