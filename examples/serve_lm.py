"""Serve a small model with batched requests: slot-based continuous
batching, prefill + batched decode, per-request latency stats.

  PYTHONPATH=src python examples/serve_lm.py [--requests 12] [--slots 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get
from repro.models import model as lm
from repro.serve.loop import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=4, d_model=256,
                                              d_ff=512, vocab_size=1024)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, slots=args.slots, cache_len=160)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.integers(4, 32))
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(0, 1023, plen).astype(np.int32),
                           max_new=args.max_new))
    done = srv.run_until_drained()
    wall = time.time() - t0

    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.finished_at - r.submitted_at for r in done]
    print(f"served {len(done)} requests / {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s on CPU, slots={args.slots})")
    print(f"latency p50={np.percentile(lat, 50):.2f}s "
          f"p99={np.percentile(lat, 99):.2f}s")
    assert len(done) == args.requests
    print("serve_lm OK")


if __name__ == "__main__":
    main()
