"""End-to-end driver: train a ~50M-param qwen3-family model (scaled to
this 1-core host; --layers/--d-model scale it to 100M+) for a couple of
hundred steps on the synthetic bigram corpus, with the full production
substrate — AdamW, checkpoint/restart, preemption handling, straggler
watchdog, metrics JSONL.  The CE must drop by >=0.5 nats and approach the
chain's conditional entropy (crossing the uniform baseline).

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get
from repro.data.pipeline import SyntheticLM
from repro.models import model as lm
from repro.optim import adamw
from repro.train.loop import LoopConfig, TrainLoop


def build_cfg(layers=8, d_model=768):
    # ~53M params at the defaults; 12 x 896 gives ~100M on a bigger host
    return get("qwen3-1.7b").reduced().replace(
        n_layers=layers, d_model=d_model, n_heads=d_model // 64,
        n_kv_heads=max(d_model // 192, 1), d_ff=int(d_model * 8 // 3),
        vocab_size=4096, head_dim=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="runs/train_lm")
    args = ap.parse_args()

    cfg = build_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    n = lm.param_count(params)
    print(f"arch={cfg.name}(reduced) params={n/1e6:.1f}M")

    oc = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=20,
                           total_steps=args.steps, weight_decay=0.01)
    opt = adamw.init(params, oc)
    # a 512-state bigram chain: enough structure to show clear learning
    # inside a few hundred small-batch steps on this host
    pipe = SyntheticLM(cfg, SHAPES["train_4k"], seed=0,
                       batch_override=args.batch, seq_override=args.seq,
                       active_vocab=512)

    @jax.jit
    def step_fn(p, s, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, mets), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, batch, cfg), has_aux=True)(p)
        p2, s2, om = adamw.apply(p, g, s, oc)
        return p2, s2, dict(mets, **om)

    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=10,
                   out_dir=args.out),
        step_fn, params, opt, pipe)
    out = loop.run()
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                      for k, v in out.items()}))

    lines = [json.loads(l) for l in
             (Path(args.out) / "metrics.jsonl").read_text().splitlines()]
    first, last = lines[0]["ce"], lines[-1]["ce"]
    print(f"ce: {first:.3f} -> {last:.3f} "
          f"(uniform baseline {np.log(pipe.active_vocab):.3f})")
    assert last < first - 0.5, "loss did not improve"
    print("train_lm OK")


if __name__ == "__main__":
    main()
