"""``python -m repro.obs <file>`` — summarize observability artifacts.

Accepts any of the three on-disk formats this repo produces and prints
a terminal summary:

* a Chrome trace JSON (``{"traceEvents": [...]}``) exported by
  :func:`repro.obs.profile.export_chrome_trace` — per-track busy time,
  op counts, flow-edge count;
* a dumped :class:`repro.obs.critical_path.ProfileReport` JSON
  (``{"profile_report": 1, ...}``) — the attribution summary;
* a ``.trace`` command trace (:mod:`repro.runtime.trace` line grammar)
  — command/transaction counts per channel, marker totals.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
from typing import Dict

from repro.obs.critical_path import PathSegment, ProfileReport


def _summarize_chrome(trace: Dict) -> str:
    events = trace.get("traceEvents", [])
    other = trace.get("otherData", {})
    names: Dict[tuple, str] = {}
    busy_us: collections.Counter = collections.Counter()
    ops_per_track: collections.Counter = collections.Counter()
    op_names: collections.Counter = collections.Counter()
    flows = 0
    for ev in events:
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "M":
            if ev.get("name") == "process_name":
                names[(ev.get("pid"), None)] = ev["args"]["name"]
            elif ev.get("name") == "thread_name":
                names[key] = ev["args"]["name"]
        elif ph == "X" and ev.get("cat") in ("op", "link"):
            busy_us[key] += ev.get("dur", 0.0)
            ops_per_track[key] += 1
            op_names[ev.get("name", "?")] += 1
        elif ph == "s" and ev.get("cat") == "dep":
            flows += 1
    lines = [f"chrome trace: {len(events)} events, "
             f"{sum(ops_per_track.values())} op slices, "
             f"{flows} dep flows"]
    if other:
        lines.append(
            f"  makespan={other.get('makespan_cycles', 0):.0f}cyc  "
            f"ops={other.get('n_ops', '?')}  "
            f"stacks={other.get('n_stacks', '?')}")
    for key in sorted(busy_us):
        pid, tid = key
        proc = names.get((pid, None), f"pid {pid}")
        thread = names.get(key, f"tid {tid}")
        lines.append(f"  [{proc} / {thread}] "
                     f"busy={busy_us[key]:.3f}us "
                     f"slices={ops_per_track[key]}")
    for name, cnt in op_names.most_common(8):
        lines.append(f"  op {name!r}: {cnt} slices")
    return "\n".join(lines)


def _summarize_report(data: Dict, top: int = 5) -> str:
    rep = ProfileReport(
        makespan_cycles=data["makespan_cycles"],
        segments=[PathSegment(**s) for s in data.get("segments", [])],
        by_op={int(k): v for k, v in data.get("by_op", {}).items()},
        op_names={int(k): v for k, v in data.get("op_names", {}).items()},
        by_channel={int(k): v
                    for k, v in data.get("by_channel", {}).items()},
        link_cycles=data.get("link_cycles", 0.0),
        slack_cycles=data.get("slack_cycles", 0.0),
        channel_busy={int(k): v
                      for k, v in data.get("channel_busy", {}).items()},
        n_ops=data.get("n_ops", 0))
    return rep.summary(top_k=top)


def _summarize_trace(text: str) -> str:
    from repro.runtime.trace import parse_trace
    st = parse_trace(text)
    lines = [f"command trace: {st.pim_commands} PIM commands, "
             f"{st.launches} launches, {st.cfr_writes} CFR writes"]
    if st.opcodes:
        ops = " ".join(f"{k}={v}" for k, v in sorted(st.opcodes.items()))
        lines.append(f"  opcodes: {ops}")
    for ch in st.channels:
        lines.append(
            f"  ch {ch}: pim={st.pim_per_channel.get(ch, 0)} "
            f"w={st.mem_writes.get(ch, 0)} r={st.mem_reads.get(ch, 0)} "
            f"resident_bytes={st.resident_bytes.get(ch, 0)} "
            f"spill_bytes={st.spill_bytes.get(ch, 0)}")
    if st.stacks_seen:
        lines.append(f"  stacks: {sorted(set(st.stacks_seen))} "
                     f"host_link_bytes={dict(st.host_link_bytes)}")
    if st.op_starts:
        lines.append(f"  async markers: {len(st.op_starts)} TSTART / "
                     f"{len(st.op_ends)} TEND over "
                     f"{len({op for _, op in st.op_starts})} ops")
    if st.kvappend_bytes or st.kvevict_bytes:
        lines.append(
            f"  kv markers: append_bytes={sum(st.kvappend_bytes.values())} "
            f"evict_bytes={sum(st.kvevict_bytes.values())} over "
            f"{len(set(st.kvappend_bytes) | set(st.kvevict_bytes))} channels")
    if st.link_stacks_seen:
        lines.append(
            f"  stack links: {sorted(set(st.link_stacks_seen))} "
            f"bytes_per_link={dict(st.host_link_bytes_per_link)}")
    if st.migrate_events:
        lines.append(
            f"  migrate markers: {len(st.migrate_events)} events, "
            f"{sum(m[4] for m in st.migrate_events)} bytes")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="summarize a .trace file, Chrome trace JSON, or "
                    "ProfileReport JSON")
    ap.add_argument("path", help="artifact to summarize")
    ap.add_argument("--top", type=int, default=5,
                    help="top-k ops for profile reports (default 5)")
    ns = ap.parse_args(argv)
    with open(ns.path) as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{"):
        data = json.loads(text)
        if "traceEvents" in data:
            print(_summarize_chrome(data))
        elif "profile_report" in data or "makespan_cycles" in data:
            print(_summarize_report(data, top=ns.top))
        else:
            print("unrecognized JSON artifact (expected traceEvents or "
                  "profile_report)", file=sys.stderr)
            return 2
    else:
        print(_summarize_trace(text))
    return 0


if __name__ == "__main__":
    sys.exit(main())
