"""Critical-path attribution over a timeline's op DAG.

``RuntimeReport.cluster_makespan_cycles`` says *how long* a schedule
took; this module says *why*.  Starting from the last-retiring op, the
walk moves backward through time asking, at every instant, "what was the
binding constraint here?" — a channel busy interval, a host-link
transfer window, a dependency retire, or nothing (slack: every resource
idle while the schedule waits on an earlier event).  The result is a
chain of :class:`PathSegment`\\ s that **partitions** ``[0, makespan]``
exactly: coverage == makespan is an invariant, gated in the bench
suite, not a best-effort statistic.

Why exact float equality works here: every clock value on the timeline
is produced by ``max()`` over previously-produced clock values plus
integer cycle counts (see :meth:`repro.runtime.timeline.Timeline.
submit`), so a shard's start is *bit-identical* to whichever constraint
bound it.  The walk matches ends to starts with a tiny tolerance
(:data:`TOL`) purely as belt-and-braces; in practice the comparisons are
exact.

Predecessor priority at a segment boundary ``s`` (earliest first match
wins):

1. a **dependency** retiring at ``s`` — the op waited on its DAG edge;
2. the **previous span on the same channel** ending at ``s`` — the op
   was channel-bound (queueing, not dataflow);
3. a **host-link window** ending at ``s`` — the op was link-bound;
4. any op **retiring** at ``s`` (degenerate zero-busy ops hop straight
   through to their own deps);
5. otherwise **slack**: attribute ``(e, s]`` to idle time, where ``e``
   is the latest event end before ``s``, and resume from that event.

The walk is pure analysis — it reads ``OpHandle``-shaped objects
(``op_id``/``name``/``deps``/``spans``/``link_window``/``retire``) and
never touches the clocks, so it works identically on a live
``Timeline.ops`` log and on the serialized-mode shadow log kept by
:class:`repro.obs.profile.Profiler`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

#: slop for matching clock values; timeline clocks propagate bit-exactly
#: (maxes of sums of previously-produced floats) so this never actually
#: absorbs error — it only guards hypothetical future float churn
TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class PathSegment:
    """One interval of the makespan and what it was spent on.

    ``kind`` is ``"channel"`` (busy on flat channel ``channel``),
    ``"link"`` (host-link transfer window), ``"ready"`` (a zero-length
    marker where a degenerate op retired), or ``"slack"`` (no resource
    active; ``op_id``/``name`` refer to the op whose event *ends* the
    idle gap, i.e. the one the schedule was waiting behind).
    """

    op_id: int
    name: str
    kind: str
    channel: Optional[int]
    t0: float
    t1: float

    @property
    def cycles(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class ProfileReport:
    """Structured result of one critical-path walk.

    ``segments`` is chronological (ascending ``t0``) and partitions
    ``[0, makespan_cycles]``; ``by_op``/``by_channel`` fold the non-slack
    segments by attribution; ``channel_busy`` is total busy cycles per
    channel across *all* ops (utilization denominator = makespan).
    """

    makespan_cycles: float
    segments: List[PathSegment]
    by_op: Dict[int, float]
    op_names: Dict[int, str]
    by_channel: Dict[int, float]
    link_cycles: float
    slack_cycles: float
    channel_busy: Dict[int, float]
    n_ops: int

    @property
    def coverage_cycles(self) -> float:
        """Sum of segment lengths — invariant: == :attr:`makespan_cycles`."""
        return sum(s.cycles for s in self.segments)

    def top(self, k: int = 5) -> List[Tuple[str, int, float]]:
        """Top-``k`` (name, op_id, cycles) contributors to the path."""
        ranked = sorted(self.by_op.items(), key=lambda kv: -kv[1])
        return [(self.op_names.get(op_id, "?"), op_id, cyc)
                for op_id, cyc in ranked[:k]]

    def to_json(self) -> Dict:
        return {
            "profile_report": 1,
            "makespan_cycles": self.makespan_cycles,
            "coverage_cycles": self.coverage_cycles,
            "link_cycles": self.link_cycles,
            "slack_cycles": self.slack_cycles,
            "n_ops": self.n_ops,
            "by_op": {str(k): v for k, v in sorted(self.by_op.items())},
            "op_names": {str(k): v
                         for k, v in sorted(self.op_names.items())},
            "by_channel": {str(k): v
                           for k, v in sorted(self.by_channel.items())},
            "channel_busy": {str(k): v
                             for k, v in sorted(self.channel_busy.items())},
            "segments": [dataclasses.asdict(s) for s in self.segments],
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    def summary(self, top_k: int = 5) -> str:
        """Human-readable attribution the CLI and examples print."""
        mk = self.makespan_cycles
        lines = [f"critical path over {self.n_ops} ops, "
                 f"makespan={mk:.0f}cyc (coverage={self.coverage_cycles:.0f})"]

        def pct(c: float) -> str:
            return f"{100.0 * c / mk:.1f}%" if mk else "n/a"

        chan = sum(self.by_channel.values())
        lines.append(f"  channel-bound={chan:.0f}cyc ({pct(chan)})  "
                     f"link-bound={self.link_cycles:.0f}cyc "
                     f"({pct(self.link_cycles)})  "
                     f"slack={self.slack_cycles:.0f}cyc "
                     f"({pct(self.slack_cycles)})")
        if self.channel_busy and mk:
            utils = [b / mk for b in self.channel_busy.values()]
            lines.append(f"  channel util: mean="
                         f"{sum(utils) / len(utils):.3f} "
                         f"max={max(utils):.3f} over "
                         f"{len(self.channel_busy)} channels")
        for name, op_id, cyc in self.top(top_k):
            lines.append(f"  #{op_id:<4d} {name:<24s} "
                         f"{cyc:10.0f}cyc  {pct(cyc)}")
        return "\n".join(lines)


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= TOL


def critical_path(ops: Sequence) -> ProfileReport:
    """Walk the op DAG backward from the retiring op; see module doc.

    ``ops`` is any sequence of ``OpHandle``-shaped records.  Returns a
    :class:`ProfileReport` whose segments partition ``[0, makespan]``.
    """
    ops = list(ops)
    by_id = {h.op_id: h for h in ops}
    channel_busy: Dict[int, float] = {}
    for h in ops:
        for ch, (_, b) in h.spans.items():
            channel_busy[ch] = channel_busy.get(ch, 0.0) + b

    makespan = max((h.retire for h in ops), default=0.0)
    if not ops or makespan <= TOL:
        return ProfileReport(
            makespan_cycles=0.0, segments=[], by_op={},
            op_names={h.op_id: h.name for h in ops}, by_channel={},
            link_cycles=0.0, slack_cycles=0.0,
            channel_busy=channel_busy, n_ops=len(ops))

    # every (end, kind, channel, start, op) event, for slack fallback and
    # generic end-matching; "retire" pseudo-events let the walk hop
    # through degenerate zero-busy ops
    events: List[Tuple[float, str, Optional[int], float, object]] = []
    spans_by_ch: Dict[int, List[Tuple[float, float, object]]] = {}
    for h in ops:
        for ch, (s, b) in h.spans.items():
            events.append((s + b, "channel", ch, s, h))
            spans_by_ch.setdefault(ch, []).append((s, s + b, h))
        if h.link_window is not None:
            events.append((h.link_window[1], "link", None,
                           h.link_window[0], h))
        if not h.spans and h.link_window is None:
            events.append((h.retire, "ready", None, h.retire, h))

    def element_ending_at(h, t: float):
        """``h``'s own interval ending at ``t`` (tightest start wins)."""
        best = None
        for ch, (s, b) in h.spans.items():
            if _close(s + b, t) and (best is None or s > best[2]):
                best = ("channel", ch, s)
        if h.link_window is not None and _close(h.link_window[1], t):
            if best is None or h.link_window[0] > best[2]:
                best = ("link", None, h.link_window[0])
        if best is None and _close(h.retire, t):
            best = ("ready", None, t)
        return best

    def pred_at(h, elem_kind: str, elem_ch: Optional[int], s: float):
        """The op binding ``h`` at boundary ``s`` (priority per moduledoc)."""
        for d in h.deps:                               # 1. dependency edge
            dh = by_id.get(d)
            if dh is not None and _close(dh.retire, s):
                return dh
        if elem_kind == "channel":                     # 2. channel queueing
            for (_, e, oh) in spans_by_ch.get(elem_ch, ()):
                if _close(e, s) and oh is not h:
                    return oh
        for oh in ops:                                 # 3. link-bound
            if oh.link_window is not None and _close(oh.link_window[1], s):
                return oh
        for oh in ops:                                 # 4. any retire
            if oh is not h and _close(oh.retire, s) and oh.op_id < h.op_id:
                return oh
        return None

    segments: List[PathSegment] = []
    t = makespan
    cur = max((h for h in ops if _close(h.retire, makespan)),
              key=lambda h: h.op_id)
    visited = set()
    max_iters = 4 * (len(events) + len(ops)) + 16
    for _ in range(max_iters):
        if t <= TOL:
            break
        key = (cur.op_id, round(t, 6))
        slack_forced = key in visited   # revisit ⇒ only slack can progress
        visited.add(key)
        elem = None if slack_forced else element_ending_at(cur, t)
        if elem is not None:
            kind, ch, s = elem
            if kind != "ready":         # ready markers are zero-length
                segments.append(PathSegment(
                    op_id=cur.op_id, name=cur.name, kind=kind,
                    channel=ch, t0=s, t1=t))
                t = s
            if t <= TOL:
                break
            nxt = pred_at(cur, kind, ch, t)
            if nxt is not None:
                cur = nxt
                continue
        # slack: nothing ends at t on the current chain — fall back to
        # the latest event end strictly before t, idle in between
        prior = [(e, h) for (e, _, _, _, h) in events if e < t - TOL]
        if not prior:
            segments.append(PathSegment(
                op_id=cur.op_id, name=cur.name, kind="slack",
                channel=None, t0=0.0, t1=t))
            t = 0.0
            break
        e, owner = max(prior, key=lambda p: p[0])
        segments.append(PathSegment(
            op_id=owner.op_id, name=owner.name, kind="slack",
            channel=None, t0=e, t1=t))
        t = e
        cur = owner
    assert t <= TOL, (
        f"critical-path walk stalled at t={t} (makespan={makespan}); "
        f"{len(segments)} segments so far")

    segments.reverse()                  # chronological
    by_op: Dict[int, float] = {}
    by_channel: Dict[int, float] = {}
    link_cycles = 0.0
    slack_cycles = 0.0
    for seg in segments:
        if seg.kind == "slack":
            slack_cycles += seg.cycles
            continue
        by_op[seg.op_id] = by_op.get(seg.op_id, 0.0) + seg.cycles
        if seg.kind == "channel":
            by_channel[seg.channel] = (
                by_channel.get(seg.channel, 0.0) + seg.cycles)
        elif seg.kind == "link":
            link_cycles += seg.cycles
    return ProfileReport(
        makespan_cycles=makespan, segments=segments, by_op=by_op,
        op_names={h.op_id: h.name for h in ops}, by_channel=by_channel,
        link_cycles=link_cycles, slack_cycles=slack_cycles,
        channel_busy=channel_busy, n_ops=len(ops))
