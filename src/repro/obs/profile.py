"""Timeline profiler: op log capture and Chrome Trace Event export.

Two capture paths feed one export/analysis pipeline:

* **async runtimes** already keep the full op log —
  ``PIMRuntime(async_mode=True).timeline.ops`` records every
  :class:`~repro.runtime.timeline.OpHandle` with spans, link windows and
  dep edges.  Profiling an async runtime reads that log as-is: zero
  capture cost, nothing extra runs during scheduling.
* **serialized runtimes** have no clock, so :class:`Profiler` keeps a
  *shadow* log: each op is barrier-placed on a pseudo-clock (every span
  opens at the previous op's retire, exactly the serialized
  accumulation semantics ``pim_cycles += rep.cluster_makespan_cycles``)
  and chained to its predecessor with a dep edge.  The shadow records
  are plain :class:`OpHandle`\\ s, so the critical-path walk and the
  trace exporter run unchanged on either source.

The export target is Chrome Trace Event Format JSON — the
``{"traceEvents": [...]}`` dict Perfetto (https://ui.perfetto.dev) and
``chrome://tracing`` load directly.  Track mapping:

* one *process* per stack (``pid`` = stack index, named ``stack N``)
  plus one for the shared host link (``pid`` = n_stacks,
  ``host-link``);
* one *thread* per pseudo-channel within its stack (``tid`` = local
  channel id, named with the flat id so cluster traces stay
  unambiguous);
* one complete event (``ph: "X"``) per (op, channel) span, with h2d /
  compute / d2h **phase sub-slices** nested inside it when the op
  carries a :class:`~repro.runtime.scheduler.ChannelReport` (the
  overlap busy model places lead-in first, the stream window second,
  the drain last; ``overlap=False`` reports nest strictly
  sequentially);
* one ``ph: "s"`` / ``ph: "f"`` **flow pair per dep edge** — Perfetto
  draws these as arrows from the producer's retire to the consumer's
  first span.

Timestamps are microseconds (Chrome's unit) at the 250 MHz PIM clock:
``us = cycles / 250``.  Cycle values ride along in ``args`` so nothing
is lost to the unit conversion.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.isa import PIM_FREQ_HZ
from repro.runtime.timeline import OpHandle

from repro.obs.critical_path import ProfileReport, critical_path

#: Chrome trace timestamps are microseconds; the PIM clock is 250 MHz
US_PER_CYCLE = 1e6 / PIM_FREQ_HZ


class Profiler:
    """Shadow op log for a serialized (``async_mode=False``) runtime.

    Attached via ``PIMRuntime(profile=True)`` (or an explicit instance);
    the scheduler calls :meth:`on_op` after each op's ledgers close.
    Records are barrier-placed: every span and link window opens at the
    previous op's retire, so the shadow clock's frontier equals the sum
    of per-op ``cluster_makespan_cycles`` — the serialized wall-clock.

    Strictly additive: the profiler only *reads* each op's finished
    report; ledgers, traces and numerics are untouched (property-tested
    against an unprofiled twin).
    """

    def __init__(self):
        self.ops: List[OpHandle] = []
        self.runtime = None
        self._clock = 0.0
        self._next_id = 1

    def attach(self, runtime) -> "Profiler":
        self.runtime = runtime
        return self

    @property
    def now(self) -> float:
        """The shadow clock frontier (serialized wall-clock so far)."""
        return self._clock

    def on_op(self, name: str, channel_busy: Dict[int, float],
              link_cycles: int = 0, report=None, result=None) -> OpHandle:
        """Barrier-place one finished op on the shadow clock."""
        t0 = self._clock
        spans = {ch: (t0, float(b)) for ch, b in channel_busy.items()
                 if b > 0}
        link_window = (t0, t0 + link_cycles) if link_cycles > 0 else None
        ends = [s + b for s, b in spans.values()]
        if link_window is not None:
            ends.append(link_window[1])
        retire = max(ends, default=t0)
        handle = OpHandle(
            op_id=self._next_id, name=name,
            deps=(self.ops[-1].op_id,) if self.ops else (),
            start=t0, retire=retire, spans=spans,
            link_window=link_window, report=report, result=result)
        self._next_id += 1
        self._clock = retire
        self.ops.append(handle)
        return handle

    def amend_last(self, name: str, report=None) -> None:
        """Rename the most recent record (the gemv-wraps-gemm case)."""
        assert self.ops, "no op recorded yet"
        self.ops[-1].name = name
        if report is not None:
            self.ops[-1].report = report


def _ops_of(runtime) -> List[OpHandle]:
    """The op log backing ``runtime`` — timeline (async) or shadow."""
    tl = getattr(runtime, "timeline", None)
    if tl is not None:
        return tl.ops
    prof = getattr(runtime, "profile", None)
    if prof is not None:
        return prof.ops
    raise ValueError(
        "runtime has no op log: construct it with async_mode=True or "
        "profile=True to capture one")


def _topology(runtime):
    """(stack_of, local_of, n_stacks) channel-mapping helpers."""
    cluster = getattr(runtime, "_cluster", None)
    if cluster is not None:
        cps = cluster.channels_per_stack
        return (lambda ch: ch // cps), (lambda ch: ch % cps), \
            cluster.n_stacks
    return (lambda ch: 0), (lambda ch: ch), 1


def _phase_slices(cr) -> List[Dict]:
    """(name, offset, dur, args) phase breakdown of one ChannelReport,
    offsets relative to the span start, per the busy model."""
    out = []
    if not cr.overlap:               # synchronous DMA: strict sequence
        t = 0.0
        for name, dur in (("h2d", cr.h2d_cycles),
                          ("compute", cr.compute_cycles),
                          ("d2h", cr.d2h_cycles)):
            if dur > 0:
                out.append({"name": name, "off": t, "dur": float(dur)})
            t += dur
        return out
    stream = max(cr.compute_cycles, cr.h2d_cycles - cr.lead_in_cycles)
    if cr.lead_in_cycles > 0:
        out.append({"name": "h2d", "off": 0.0,
                    "dur": float(cr.lead_in_cycles)})
    if stream > 0:
        out.append({"name": "compute", "off": float(cr.lead_in_cycles),
                    "dur": float(stream),
                    "args": {"compute_cycles": cr.compute_cycles,
                             "h2d_stream_cycles": max(
                                 0, cr.h2d_cycles - cr.lead_in_cycles)}})
    if cr.d2h_cycles > 0:
        out.append({"name": "d2h",
                    "off": float(cr.lead_in_cycles) + stream,
                    "dur": float(cr.d2h_cycles)})
    return out


def chrome_trace(runtime) -> Dict:
    """The full Chrome Trace Event dict for ``runtime``'s op log."""
    ops = _ops_of(runtime)
    stack_of, local_of, n_stacks = _topology(runtime)
    link_pid = n_stacks
    events: List[Dict] = []

    # track metadata: processes = stacks (+ link), threads = channels
    seen_tracks = set()
    for s in range(n_stacks):
        events.append({"ph": "M", "pid": s, "tid": 0,
                       "name": "process_name",
                       "args": {"name": f"stack {s}"}})
        events.append({"ph": "M", "pid": s, "tid": 0,
                       "name": "process_sort_index", "args": {"sort_index": s}})
    events.append({"ph": "M", "pid": link_pid, "tid": 0,
                   "name": "process_name", "args": {"name": "host-link"}})
    events.append({"ph": "M", "pid": link_pid, "tid": 0,
                   "name": "process_sort_index",
                   "args": {"sort_index": link_pid}})
    events.append({"ph": "M", "pid": link_pid, "tid": 0,
                   "name": "thread_name", "args": {"name": "link"}})

    for h in ops:
        for ch in sorted(h.spans):
            s, b = h.spans[ch]
            pid, tid = stack_of(ch), local_of(ch)
            if (pid, tid) not in seen_tracks:
                seen_tracks.add((pid, tid))
                events.append({
                    "ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name",
                    "args": {"name": f"ch {pid}.{tid} (flat {ch})"}})
                events.append({
                    "ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_sort_index",
                    "args": {"sort_index": tid}})
            args = {"op_id": h.op_id, "channel": ch,
                    "start_cycles": s, "busy_cycles": b}
            cr = None
            if h.report is not None:
                cr = next((c for c in h.report.per_channel
                           if c.channel == ch), None)
            if cr is not None:
                args.update(flops=cr.flops, h2d_bytes=cr.h2d_bytes,
                            d2h_bytes=cr.d2h_bytes,
                            reuse_bytes=cr.reuse_bytes)
            events.append({"ph": "X", "cat": "op", "name": h.name,
                           "pid": pid, "tid": tid,
                           "ts": s * US_PER_CYCLE,
                           "dur": b * US_PER_CYCLE, "args": args})
            if cr is not None:
                for ph in _phase_slices(cr):
                    events.append({
                        "ph": "X", "cat": "phase", "name": ph["name"],
                        "pid": pid, "tid": tid,
                        "ts": (s + ph["off"]) * US_PER_CYCLE,
                        "dur": ph["dur"] * US_PER_CYCLE,
                        "args": ph.get("args", {})})
        if h.link_window is not None:
            ls, le = h.link_window
            args = {"op_id": h.op_id, "start_cycles": ls,
                    "link_cycles": le - ls}
            if h.report is not None:
                args["link_bytes"] = h.report.host_link_bytes
            events.append({"ph": "X", "cat": "link", "name": h.name,
                           "pid": link_pid, "tid": 0,
                           "ts": ls * US_PER_CYCLE,
                           "dur": (le - ls) * US_PER_CYCLE, "args": args})

    # dep edges as flow arrows: producer retire -> consumer first span
    by_id = {h.op_id: h for h in ops}
    for h in ops:
        dst = min(h.spans.items(), key=lambda kv: kv[1][0], default=None)
        for d in h.deps:
            src = by_id.get(d)
            if src is None:
                continue
            # anchor the arrow tail inside the producer's last interval
            tail = max(((ch, se) for ch, se in
                        ((c, sp[0] + sp[1]) for c, sp in src.spans.items())),
                       key=lambda kv: kv[1], default=None)
            flow_id = f"d{src.op_id}_{h.op_id}"
            if tail is not None:
                tch, tend = tail
                events.append({"ph": "s", "cat": "dep", "name": "dep",
                               "id": flow_id,
                               "pid": stack_of(tch), "tid": local_of(tch),
                               "ts": tend * US_PER_CYCLE})
            elif src.link_window is not None:
                events.append({"ph": "s", "cat": "dep", "name": "dep",
                               "id": flow_id, "pid": link_pid, "tid": 0,
                               "ts": src.link_window[1] * US_PER_CYCLE})
            else:
                continue                     # degenerate producer: no anchor
            if dst is not None:
                dch, (ds, _) = dst
                events.append({"ph": "f", "bp": "e", "cat": "dep",
                               "name": "dep", "id": flow_id,
                               "pid": stack_of(dch), "tid": local_of(dch),
                               "ts": ds * US_PER_CYCLE})
            elif h.link_window is not None:
                events.append({"ph": "f", "bp": "e", "cat": "dep",
                               "name": "dep", "id": flow_id,
                               "pid": link_pid, "tid": 0,
                               "ts": h.link_window[0] * US_PER_CYCLE})
            else:                            # degenerate consumer: drop tail
                events.pop()

    # fault-injection instants (repro.faults): injection / recovery /
    # retransmit markers as Perfetto instant events on the affected
    # channel's track (channel -1 = the host link)
    inj = getattr(runtime, "faults", None)
    if inj is not None:
        for kind, cycle, ch, label in inj.instants:
            if ch < 0:
                pid, tid = link_pid, 0
            else:
                pid, tid = stack_of(ch), local_of(ch)
            events.append({"ph": "i", "s": "g", "cat": "fault",
                           "name": f"{kind}: {label}",
                           "pid": pid, "tid": tid,
                           "ts": cycle * US_PER_CYCLE,
                           "args": {"kind": kind, "cycle": cycle,
                                    "channel": ch}})

    makespan = max((h.retire for h in ops), default=0.0)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.obs",
            "clock_hz": PIM_FREQ_HZ,
            "makespan_cycles": makespan,
            "n_ops": len(ops),
            "n_stacks": n_stacks,
        },
    }


def export_chrome_trace(runtime, path: Optional[str] = None) -> Dict:
    """Serialize ``runtime``'s op log to Chrome Trace JSON; optionally
    write it to ``path`` (open the file at https://ui.perfetto.dev)."""
    trace = chrome_trace(runtime)
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


def profile_report(runtime) -> ProfileReport:
    """Critical-path attribution of ``runtime``'s op log."""
    return critical_path(_ops_of(runtime))
