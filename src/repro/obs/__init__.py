"""Observability: timeline profiling, critical-path attribution, metrics.

The runtime's ledgers answer "how much"; this package answers "when",
"why", and "how is it distributed":

* :mod:`repro.obs.profile` — capture an op log (an async runtime's
  timeline, or a :class:`Profiler` shadow log on a serialized runtime)
  and export it as Chrome Trace Event JSON for Perfetto.
* :mod:`repro.obs.critical_path` — walk the op DAG backward from the
  retiring op and partition the makespan into channel-bound /
  link-bound / slack segments (:class:`ProfileReport`).
* :mod:`repro.obs.metrics` — counters / gauges / histograms with exact
  percentiles; instrumented in ``PIMRuntime``, ``PIMCluster``,
  ``DecodeOffload`` and the serve loop (TTFT/TPOT).

``python -m repro.obs <file>`` summarizes a ``.trace`` file, a Chrome
trace JSON, or a dumped :class:`ProfileReport`.  See
``docs/observability.md`` for the formats and the metrics catalog.
"""
from repro.obs.critical_path import PathSegment, ProfileReport, critical_path
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.profile import (
    Profiler,
    US_PER_CYCLE,
    chrome_trace,
    export_chrome_trace,
    profile_report,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "PathSegment",
    "ProfileReport",
    "Profiler",
    "US_PER_CYCLE",
    "chrome_trace",
    "critical_path",
    "export_chrome_trace",
    "profile_report",
]
