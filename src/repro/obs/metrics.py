"""Lightweight metrics registry: counters, gauges, histograms.

The runtime's ledgers are *per-object* state — a ``PIMDevice`` knows its
own bytes, a ``StepRecord`` its own step — but serving-level questions
("what is TTFT p99 across this run?", "how many bytes crossed the host
link in total?") need accumulation across objects and time.  This module
is that accumulation layer: a :class:`MetricsRegistry` of named
instruments, each carrying a unit and a help string so reports are
self-describing (the catalog is rendered in ``docs/observability.md``).

Design constraints, in order:

* **Zero cost when off.**  Nothing here is instantiated unless a caller
  passes ``metrics=`` to a runtime/offload/server; instrumented code
  guards every touch with ``if metrics is not None``.  Ledgers, traces
  and numerics are byte-identical with metrics off (property-tested).
* **No dependencies, no threads, no exporters.**  Instruments are plain
  Python objects; :meth:`MetricsRegistry.snapshot` returns a JSON-ready
  dict — the serving simulator and the bench harness write it where
  they already write artifacts.
* **Percentiles over buckets.**  Histograms keep raw observations
  (bounded by :data:`HISTOGRAM_MAX_SAMPLES` reservoir truncation) and
  compute exact p50/p90/p99 — at simulation scale exactness beats
  bucket-boundary error, and the TTFT/TPOT gates want real percentiles.

Naming convention: dotted lowercase paths, ``<layer>.<quantity>_<unit>``
where the unit is not implied — ``runtime.h2d_bytes``, ``serve.ttft_s``,
``offload.step_pim_cycles``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Union

#: histograms keep raw samples up to this many observations; past it,
#: every k-th new sample overwrites a deterministic slot (cheap, keeps
#: the memory bound while staying reproducible — no RNG involved)
HISTOGRAM_MAX_SAMPLES = 65536


@dataclasses.dataclass
class Counter:
    """Monotonic accumulator (ops dispatched, bytes moved)."""

    name: str
    unit: str = ""
    help: str = ""
    value: float = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        assert n >= 0, f"counter {self.name} can only increase (got {n})"
        self.value += n

    def snapshot(self) -> Dict:
        return {"type": "counter", "unit": self.unit, "value": self.value}


@dataclasses.dataclass
class Gauge:
    """Last-write-wins level (queue depth, live slots)."""

    name: str
    unit: str = ""
    help: str = ""
    value: float = 0.0

    def set(self, v: Union[int, float]) -> None:
        self.value = float(v)

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n

    def dec(self, n: Union[int, float] = 1) -> None:
        self.value -= n

    def snapshot(self) -> Dict:
        return {"type": "gauge", "unit": self.unit, "value": self.value}


class Histogram:
    """Distribution of observations with exact percentiles.

    Keeps raw samples (reservoir-truncated past
    :data:`HISTOGRAM_MAX_SAMPLES`); ``count``/``total`` always reflect
    *every* observation, so means stay exact even when the sample buffer
    saturates.
    """

    def __init__(self, name: str, unit: str = "", help: str = ""):
        self.name = name
        self.unit = unit
        self.help = help
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: List[float] = []

    def record(self, v: Union[int, float]) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self._samples) < HISTOGRAM_MAX_SAMPLES:
            self._samples.append(v)
        else:  # deterministic overwrite keeps the buffer representative
            self._samples[self.count % HISTOGRAM_MAX_SAMPLES] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Exact linear-interpolation percentile of the recorded samples
        (``p`` in [0, 100]); 0.0 when nothing was recorded."""
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        rank = (len(xs) - 1) * p / 100.0
        lo = math.floor(rank)
        hi = math.ceil(rank)
        if lo == hi:
            return xs[lo]
        return xs[lo] + (xs[hi] - xs[lo]) * (rank - lo)

    def summary(self) -> Dict:
        """The percentile summary the latency gates read."""
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }

    def snapshot(self) -> Dict:
        return {"type": "histogram", "unit": self.unit, **self.summary()}


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named instruments, get-or-create by name.

    One registry per observed scope (a server run, an offload sidecar, a
    bench section); pass the same registry to several layers to merge
    their streams.  Re-requesting a name returns the existing instrument
    — the ``unit``/``help`` of the first registration win — and
    requesting an existing name as a different instrument type raises.
    """

    def __init__(self):
        self._instruments: Dict[str, Instrument] = {}

    def _get(self, cls, name: str, unit: str, help: str) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, unit, help)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}")
        return inst

    def counter(self, name: str, unit: str = "", help: str = "") -> Counter:
        return self._get(Counter, name, unit, help)

    def gauge(self, name: str, unit: str = "", help: str = "") -> Gauge:
        return self._get(Gauge, name, unit, help)

    def histogram(self, name: str, unit: str = "",
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, unit, help)

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __len__(self) -> int:
        return len(self._instruments)

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-ready view of every instrument, sorted by name."""
        return {name: self._instruments[name].snapshot()
                for name in sorted(self._instruments)}

    def catalog(self) -> List[Dict[str, str]]:
        """The (name, type, unit, help) rows of everything registered."""
        return [{"name": n, "type": type(i).__name__.lower(),
                 "unit": i.unit, "help": i.help}
                for n, i in sorted(self._instruments.items())]
