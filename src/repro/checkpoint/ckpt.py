"""Checkpointing: async, atomic, elastic.

* **Async**: `save()` snapshots to host (device_get) and hands the write to
  a background thread; training continues immediately.
* **Atomic**: writes land in ``step_XXXX.tmp`` and are renamed only when
  complete, so a preemption mid-write never corrupts the latest checkpoint.
* **Elastic**: checkpoints store *logical* (unsharded) arrays + a manifest;
  `restore()` returns host arrays that the caller ``device_put``s with
  whatever sharding the *current* mesh prescribes — restart on a different
  mesh shape reshards transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs model {leaf.shape}"
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved_step: Optional[int] = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()                         # one in-flight write at a time
        host = _flatten(jax.device_get(state))
        meta = dict(meta or {}, step=step, time=time.time())

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            np.savez(tmp / "arrays.npz", **host)
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():              # same step already published
                shutil.rmtree(tmp)
            else:
                os.replace(tmp, final)      # atomic publish
            self.last_saved_step = step
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return max(s) if s else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        arrays = dict(np.load(d / "arrays.npz"))
        meta = json.loads((d / "meta.json").read_text())
        return _unflatten(template, arrays), meta

    def restore_sharded(self, template: Any, shardings,
                        step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Restore and place with the current mesh's shardings (elastic)."""
        host, meta = self.restore(template, step)
        placed = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None
            else jax.device_put(x), host, shardings)
        return placed, meta
