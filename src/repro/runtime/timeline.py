"""Async dependency-aware op timeline: per-channel and per-link clocks.

The scheduler's default (``async_mode=False``) treats every op as a
global barrier: the op's makespan is max-over-channels busy time and ops
accumulate by simple addition (the serve loop's ``pim_cycles +=
rep.makespan_cycles``).  That is correct accounting for one op but wrong
for a *set* of independent ops — the paper's PEP execution model keeps
the host out of the loop precisely so many in-memory micro-kernels can
run concurrently, and PrIM's analysis shows PIM throughput is won or
lost on keeping all banks busy simultaneously.

This module is the async layer.  Every channel owns a monotonic clock
(``PIMDevice.tl_free``) and every cluster host link owns one
(``HostLinkLedger.tl_free``); an op submitted to the timeline becomes an
:class:`OpHandle` future whose shards start at::

    start(ch) = max(dep retire times, channel free time, link free time)

so independent ops interleave on disjoint channels, a fully chained DAG
reproduces the serialized makespan exactly (property-tested), and
host-link transfer windows are charged *inside* the timeline — a link
busy interval blocks dependent shard starts — instead of on a separate
serialization axis.

Dependencies are derived automatically by the scheduler from resident
:class:`~repro.runtime.residency.DeviceTensor` reads/writes (an op that
consumes a kept output starts after its producer retires; every op that
reads a placed weight starts after the upload), plus explicit ``after=``
edges for dataflow the runtime cannot see (e.g. the decode serve loop's
host-side attention/softmax between projections).

The timeline never changes *what* is charged: per-op ledgers, traces and
numerics are identical to the serialized mode (per-channel busy cycles
are conserved under any overlap — also property-tested); it only decides
*when* each op's per-channel busy interval is placed on the clock.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class OpHandle:
    """Lightweight future for one op submitted to an async runtime.

    ``spans`` maps flat channel id -> ``(start, busy)`` — the interval
    the op occupies on that channel's clock; ``link_window`` is the
    ``(start, end)`` interval the op's inter-stack traffic occupies on
    the shared host link (``None`` when the op never crosses stacks).
    ``deps`` holds the op ids this op waited on (inferred + explicit).
    ``result`` / ``report`` are the values the serialized mode would
    have returned from the op call.
    """

    op_id: int
    name: str
    deps: Tuple[int, ...]
    start: float
    retire: float
    spans: Dict[int, Tuple[float, float]]
    link_window: Optional[Tuple[float, float]] = None
    report: Optional[object] = None
    result: Optional[object] = None

    @property
    def busy_cycles(self) -> float:
        """Total channel-busy cycles this op occupies (conservation)."""
        return sum(b for _, b in self.spans.values())

    def __repr__(self) -> str:
        return (f"OpHandle({self.op_id}:{self.name}, "
                f"start={self.start:.0f}, retire={self.retire:.0f}, "
                f"channels={sorted(self.spans)}, deps={list(self.deps)})")


class Timeline:
    """Monotonic per-channel / per-link clocks plus the submitted op log.

    Owned by an ``async_mode=True`` :class:`~repro.runtime.scheduler.
    PIMRuntime`.  The clocks themselves live on the hardware objects —
    ``PIMDevice.tl_free`` and ``HostLinkLedger.tl_free`` — so a stack
    ``reset()`` clears them with the ledgers; the timeline just advances
    them and records :class:`OpHandle` intervals.

    ``ops`` keeps every handle (results and reports included) for the
    runtime's lifetime — the op log is the schedule record tests and the
    pipeline report read.  Long-running numeric loops that don't need
    old results can drop them (``handle.result = None``) after
    consumption; the timeline itself only ever reads ``spans``/
    ``retire``.
    """

    def __init__(self, stack, cluster=None):
        self.stack = stack            # PIMStack or PIMCluster (flat view)
        self.cluster = cluster        # PIMCluster or None
        self.ops: List[OpHandle] = []
        self._next_id = 1

    # -- clocks --------------------------------------------------------------

    @property
    def now(self) -> float:
        """The frontier: max over every channel clock and every link
        clock (the shared link, plus each per-stack link when the
        cluster runs ``link_topology="switched"``)."""
        t = max((d.tl_free for d in self.stack), default=0.0)
        if self.cluster is not None:
            t = max(t, *(l.tl_free for l in self.cluster.all_links()))
        return t

    @property
    def makespan(self) -> float:
        """Wall-clock of everything submitted so far (== :attr:`now`)."""
        return self.now

    def channel_busy(self, channel: int) -> float:
        """Total busy cycles placed on ``channel`` across all ops."""
        return sum(h.spans[channel][1] for h in self.ops
                   if channel in h.spans)

    # -- submission ----------------------------------------------------------

    def submit(self, name: str, channel_busy: Dict[int, float],
               link_cycles=0,
               deps: Optional[List[OpHandle]] = None,
               report=None, result=None) -> OpHandle:
        """Place one op's busy intervals on the clocks.

        ``channel_busy`` maps flat channel id -> this op's busy cycles on
        that channel (zero-busy channels are dropped).  ``link_cycles``
        is the op's host-link occupancy — an int charged on the shared
        link's clock, or (``link_topology="switched"``) a dict mapping
        stack id -> cycles (``None`` = the switch uplink) charged on
        each per-stack link's *own* clock, so disjoint-stack traffic
        overlaps.  Every window opens no earlier than the op's
        dependencies retire and its link is free, and dependent shard
        starts wait for the earliest window.  Returns the
        :class:`OpHandle` whose ``retire`` is what downstream ops wait
        on.
        """
        deps = [d for d in (deps or []) if d is not None]
        ready = max((d.retire for d in deps), default=0.0)
        link_window = None
        if isinstance(link_cycles, dict):
            windows = []
            for key in sorted(link_cycles,
                              key=lambda k: (k is None, k)):
                cyc = link_cycles[key]
                if cyc <= 0:
                    continue
                link = self.cluster.link_for(key)
                ls = max(ready, link.tl_free)
                link.tl_free = ls + cyc
                windows.append((ls, ls + cyc))
            if windows:
                link_window = (min(w[0] for w in windows),
                               max(w[1] for w in windows))
        elif link_cycles > 0:
            link = self.cluster.link
            ls = max(ready, link.tl_free)
            link_window = (ls, ls + link_cycles)
            link.tl_free = link_window[1]
        spans: Dict[int, Tuple[float, float]] = {}
        for ch, busy in channel_busy.items():
            if busy <= 0:
                continue
            dev = self.stack[ch]
            start = max(ready, dev.tl_free)
            if link_window is not None:
                # inter-stack operands must have begun crossing the link
                start = max(start, link_window[0])
            dev.tl_free = start + busy
            spans[ch] = (start, busy)
        ends = [s + b for s, b in spans.values()]
        if link_window is not None:
            ends.append(link_window[1])
        start = min((s for s, _ in spans.values()), default=ready)
        retire = max(ends, default=ready)
        if not spans and link_window is None:
            # degenerate op: all-zero channel_busy and no link traffic
            # (e.g. a place() whose shards were all already resident).
            # Normalize to a zero-length marker at its ready time —
            # empty spans with start == retire == ready — so downstream
            # interval consumers (critical-path walks, utilization
            # denominators) never see an undefined or inverted interval.
            assert start == retire == ready, (start, retire, ready)
        assert retire >= start, (name, start, retire)
        handle = OpHandle(
            op_id=self._next_id, name=name,
            deps=tuple(d.op_id for d in deps),
            start=start, retire=retire,
            spans=spans, link_window=link_window,
            report=report, result=result)
        self._next_id += 1
        self.ops.append(handle)
        return handle
