"""HBM-PIMulator-compatible command-trace emission and parsing.

Any runtime execution (numeric or analytic) can be dumped as a ``.trace``
file: one line per DRAM column command, in the line grammar of the
HBM-PIMulator trace format (yang2919/HBM-PIMulator), so traces can be fed
to trace-driven simulators and cross-checked against both the cost model
and the strict interpreter (:mod:`repro.core.pim`) — the emitter derives
per-pass base addresses from the *same* schedule functions
(:func:`repro.core.pep.mac_pass_coords`, the ``run_*_strict`` base tables),
so command counts match the strict interpreter exactly.

Line grammar::

    # comment
    AB W                          -- enter AB-PIM mode (one per PEP launch)
    W CFR "<idx>" <OPCODE>        -- program one CRF slot
    W MEM <ch> <bank> <row>       -- one 32-byte host->PIM transaction
    R MEM <ch> <bank> <row>       -- one 32-byte PIM->host transaction
    PIM <OP> [DST] [SRC0] [SRC1]  -- one column command of PEP execution

Operand rendering: ``GRF_A`` index i -> ``GRF,i``; ``GRF_B`` -> ``GRF,8+i``
(GRF_B occupies the upper CRF encoding half); ``SRF_A`` -> ``SRF,i``;
``SRF_M`` -> ``SRF,8+i``; even-bank block a -> ``BANK,2a``; odd-bank block
a -> ``BANK,2a+1`` (even/odd banks interleave in the bank address bits).

JUMP and EXIT issue zero column commands (paper §2.3.3) and are not
emitted; a trace's ``PIM`` line count therefore equals the engine ledger's
``commands`` — the round-trip property the tests pin.

Multi-stack clusters add comment-shaped marker lines (external replay
tools skip them; :func:`parse_trace` round-trips them):

    # STACK <s>                   -- following channels belong to stack s
    # HOSTLINK <kind> <bytes>     -- inter-stack bytes over the host link
                                     (kind: xstack | drain, plus the
                                     fault-injection kinds retry |
                                     reupload | degrade — degrade's count
                                     slot carries extra cycles, not bytes)
    # LINK <s>                    -- switched topology only: following
                                     HOSTLINK lines belong to stack s's
                                     private link (lines before any
                                     # LINK are the switch uplink's)
    # MIGRATE <layer> <expert> <src> <dst> <bytes>
                                  -- routed-MoE expert migration: the
                                     expert's weights moved src -> dst
                                     stack (the matching reupload bytes
                                     are HOSTLINK traffic)
    # SPILL <channel> <bytes>     -- residency evicted under a capacity
                                     bound (re-shipped on next use)

Fault injection (:mod:`repro.faults`) adds two more replay-neutral
markers on the affected channel's stream::

    # FAULT <channel> <cycle>     -- fail-stop injected at that cycle
    # RECOVER <channel> <bytes>   -- recovery traffic landed here (lost
                                     shards re-shipped / pinned outputs
                                     replayed from the last host copy)

A single-stack cluster emits none of these (no ``# STACK 0``), so its
trace is byte-identical to a bare :class:`PIMStack`'s; ``# SPILL`` lines
appear on bare stacks too when a capacity bound evicts.

Async-mode runtimes (``PIMRuntime(async_mode=True)``) additionally wrap
each op's per-channel events in timestamped markers from the timeline
scheduler::

    # TSTART <channel> <op_id> <cycles>   -- the op's busy interval opens
    # TEND <channel> <op_id> <cycles>     -- ... and retires

Both are comment-shaped (external replay skips them) and round-trip
through :func:`parse_trace` (``op_starts`` / ``op_ends``); they carry
*schedule* only, never commands, so :func:`strip_timestamps` recovers a
serialized run's trace byte-for-byte when the op stream is the same.

Traces are *expanded* (one line per command): dump small ops, not the
benchmark sweep shapes.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List, Optional

from repro.core.engine import InstrRecord, ShardSpan
from repro.core.isa import (
    AAM_BLOCKS,
    GRF_REGS,
    Operand,
    OperandSpace,
    PIMInstr,
    PIMOpcode,
    SIMD_LANES,
    SRF_REGS,
)
from repro.core.pep import (
    BA0,
    BT0,
    BT1,
    MINUS_ONE_BLOCK,
    ZERO_BLOCK,
    ChannelMemoryMap,
    build_ew_pep,
    build_mac_pep,
    build_sub_pep,
    ew_invocations,
    mac_invocations,
    mac_pass_coords,
)
from repro.runtime.device import PIMStack, transfer_cycles

#: fixed block bases used for trace address resolution (mirrors
#: :func:`repro.core.pep.init_channel` with its default region sizes)
_MM = ChannelMemoryMap(tiles=(2 + 2048, 2 + 2048 + 2048), accs=(0, 2048))

#: 32-byte transactions per notional 1 KB DRAM row (HBM-PIMulator's
#: 5-bit column field)
_COLS_PER_ROW = 32
_BANKS = 16

HEADER = """\
# AME-PIM runtime command trace (HBM-PIMulator line grammar)
#
# AB W                          -- enter AB-PIM mode (one per PEP launch)
# W CFR "[CFR_id]" [opcode]     -- CRF microkernel programming
# R/W MEM [channel] [bank] [row]-- one 32-byte host<->PIM transaction
# PIM [OP] [DST] [SRC0] [SRC1]  -- one column command of PEP execution
#
# operands: (GRF, id) (SRF, id) (BANK, block address)
# GRF 0-7 = GRF_A, GRF 8-15 = GRF_B; SRF 0-7 = SRF_A, SRF 8-15 = SRF_M
# BANK 2a = even-bank block a, BANK 2a+1 = odd-bank block a
# JUMP/EXIT are zero-command (predecoded) and do not appear.
# "# RESIDENT [channel] [bytes]" marks an operand shard reused in place
# (zero bus transactions); comment-shaped so external replay ignores it.
# "# KVAPPEND [channel] [bytes]" / "# KVEVICT [channel] [bytes]" mark
# paged-KV-cache page writes/evictions the same way (the append's real
# traffic is the adjacent MEM writes; the evict charges nothing now —
# the re-ship is real MEM traffic when the page is next needed)."""


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------


def _render(op: Operand, bases: Dict[str, int], b: int) -> str:
    step = op.index + b * op.step
    if op.space is OperandSpace.GRF_A:
        return f"GRF,{step}"
    if op.space is OperandSpace.GRF_B:
        return f"GRF,{GRF_REGS + step}"
    if op.space is OperandSpace.SRF_A:
        return f"SRF,{step}"
    if op.space is OperandSpace.SRF_M:
        return f"SRF,{SRF_REGS + step}"
    if op.space is OperandSpace.ZERO:
        return "BANK,0"
    addr = bases.get(op.base, 0) + op.index + b * op.step
    if op.space is OperandSpace.EVEN_BANK:
        return f"BANK,{2 * addr}"
    if op.space is OperandSpace.ODD_BANK:
        return f"BANK,{2 * addr + 1}"
    raise ValueError(op.space)


def _pim_lines(ins: PIMInstr, bases: Dict[str, int]) -> List[str]:
    """Expand one CRF instruction into its column-command trace lines."""
    if ins.op in (PIMOpcode.JUMP, PIMOpcode.EXIT):
        return []
    reps = AAM_BLOCKS if ins.aam else 1
    out = []
    for b in range(reps):
        parts = [f"PIM {ins.op.value.upper()}"]
        for o in (ins.dst, ins.src0, ins.src1):
            if o is not None:
                parts.append(_render(o, bases, b))
        out.append(" ".join(parts))
    return out


def _expand_launch(lines: List[str], crf: List[PIMInstr],
                   iter_bases, passes: int,
                   setup_bases: Optional[Dict[str, int]] = None) -> None:
    """One PEP launch: mode switch, CRF programming, then every pass."""
    lines.append("AB W")
    for idx, ins in enumerate(crf):
        lines.append(f'W CFR "{idx}" {ins.op.value.upper()}')
    loop_start = next((i.jump_target for i in crf
                       if i.op is PIMOpcode.JUMP), 0)
    for ins in crf[:loop_start]:                    # one-time prologue
        lines.extend(_pim_lines(ins, setup_bases or {}))
    for t in range(passes):
        bases = iter_bases(t)
        for ins in crf[loop_start:]:
            lines.extend(_pim_lines(ins, bases))


def _expand_mac(lines: List[str], rec: InstrRecord) -> None:
    a_base, acc_base = _MM.tiles[0], _MM.accs[0]
    for inv in mac_invocations(rec.k, rec.n):
        def bases(t: int, _inv=inv) -> Dict[str, int]:
            j, k0 = mac_pass_coords(_inv.start + t, rec.k)
            saddr = j * rec.k + k0
            return {BA0: acc_base + j, BT0: a_base + k0,
                    BT1: _MM.b_scalars + saddr // SIMD_LANES,
                    ZERO_BLOCK: _MM.zero}
        _expand_launch(lines, build_mac_pep(inv.passes), bases, inv.passes)


def _expand_ew(lines: List[str], rec: InstrRecord) -> None:
    a_base, b_base, acc_base = _MM.tiles[0], _MM.tiles[1], _MM.accs[0]
    for col0, passes in ew_invocations(rec.k):
        if rec.kind == "sub":
            crf = build_sub_pep(passes)
        else:
            crf = build_ew_pep(
                PIMOpcode.ADD if rec.kind == "add" else PIMOpcode.MUL,
                passes)

        def bases(t: int, _c0=col0) -> Dict[str, int]:
            c = _c0 + t * AAM_BLOCKS
            return {BT0: a_base + c, BT1: b_base + c, BA0: acc_base + c,
                    MINUS_ONE_BLOCK: _MM.minus_one, ZERO_BLOCK: _MM.zero}

        _expand_launch(lines, crf, bases, passes,
                       setup_bases={MINUS_ONE_BLOCK: _MM.minus_one})


def _mem_lines(kind: str, channel: int, nbytes: int) -> List[str]:
    rw = "W" if kind == "h2d" else "R"
    out = []
    for i in range(transfer_cycles(nbytes)):
        bank = i % _BANKS
        row = i // (_BANKS * _COLS_PER_ROW)
        out.append(f"{rw} MEM {channel} {bank} {row}")
    return out


def _emit_device(lines: List[str], dev) -> None:
    """One device's event stream as trace lines."""
    lines.append(f"# channel {dev.channel_id}")
    for kind, payload in dev.events:
        if kind in ("h2d", "d2h"):
            lines.extend(_mem_lines(kind, dev.channel_id, payload))
        elif kind == "reuse":
            # resident operand consumed in place: no MEM transactions;
            # comment-shaped so HBM-PIMulator replay skips it while our
            # parser round-trips the avoided traffic
            lines.append(f"# RESIDENT {dev.channel_id} {payload}")
        elif kind == "spill":
            # capacity eviction: no transactions now — the re-ship is a
            # real MEM write when the evicted operand next misses
            lines.append(f"# SPILL {dev.channel_id} {payload}")
        elif kind == "kvappend":
            # paged-KV page write: the new tokens' h2d is charged as real
            # MEM lines by the adjacent transfer event; this marker keys
            # the bytes to the KV cache for replay-neutral attribution
            lines.append(f"# KVAPPEND {dev.channel_id} {payload}")
        elif kind == "kvevict":
            # paged-KV page eviction under capacity pressure: zero
            # transactions now — the re-ship is real MEM traffic (and a
            # host-link reupload charge) when the page is restored
            lines.append(f"# KVEVICT {dev.channel_id} {payload}")
        elif kind in ("tstart", "tend"):
            # async-timeline schedule markers: zero commands, pure timing
            op_id, cycles = payload
            tag = "TSTART" if kind == "tstart" else "TEND"
            lines.append(f"# {tag} {dev.channel_id} {op_id} {cycles:.3f}")
        elif kind == "fault":
            # fail-stop injected (repro.faults): zero commands — the
            # channel simply issues nothing afterwards
            lines.append(f"# FAULT {dev.channel_id} {payload:.3f}")
        elif kind == "recover":
            # recovery landed here: the matching traffic is real MEM
            # lines (re-ship) or analytic busy time (output replay)
            lines.append(f"# RECOVER {dev.channel_id} {payload}")
        elif kind == "migrate":
            # routed-MoE expert migration landed on this (dst) stack:
            # zero commands — the weight movement is the matching
            # HOSTLINK reupload charge
            layer, expert, src, dst, nbytes = payload
            lines.append(
                f"# MIGRATE {layer} {expert} {src} {dst} {nbytes}")
        elif kind == "instr":
            # whole-shard spans (the fast paths' aggregated records)
            # expand to the identical per-tile instruction sequence,
            # so fast and reference traces are byte-for-byte equal
            recs = payload.records() if isinstance(payload, ShardSpan) \
                else (payload,)
            for rec in recs:
                if rec.kind == "mac":
                    _expand_mac(lines, rec)
                else:
                    _expand_ew(lines, rec)
        else:
            raise ValueError(kind)


def emit_trace(stack) -> str:
    """Serialize everything the stack's devices have executed so far.

    Accepts a :class:`PIMStack` or a :class:`~repro.runtime.cluster.
    PIMCluster`.  Multi-stack clusters group channels under ``# STACK s``
    markers and prepend the host-link ledger as ``# HOSTLINK`` lines; a
    single-stack cluster emits neither, staying byte-identical to a bare
    stack.
    """
    lines = [HEADER]
    stacks = getattr(stack, "stacks", None)
    if stacks is None:                               # bare PIMStack
        for dev in stack:
            _emit_device(lines, dev)
        return "\n".join(lines) + "\n"
    multi = len(stacks) > 1
    for kind, nbytes in stack.link.events:
        lines.append(f"# HOSTLINK {kind} {nbytes}")
    # switched topology: each stack's private link gets its own marker
    # section (shared topology has links=None and emits nothing extra,
    # keeping the trace byte-identical to the pre-topology format)
    for sid, ledger in enumerate(getattr(stack, "links", None) or ()):
        if ledger.events:
            lines.append(f"# LINK {sid}")
            for kind, nbytes in ledger.events:
                lines.append(f"# HOSTLINK {kind} {nbytes}")
    for sid, stk in enumerate(stacks):
        if multi:
            lines.append(f"# STACK {sid}")
        for dev in stk:
            _emit_device(lines, dev)
    return "\n".join(lines) + "\n"


def strip_timestamps(text: str) -> str:
    """Drop the async scheduler's ``# TSTART``/``# TEND`` marker lines.

    An async run over the same op stream differs from a serialized run
    only by these markers (the timeline places busy intervals, it never
    reorders or changes commands), so the stripped async trace is
    byte-identical to the serialized trace — the invariant the tests
    pin.
    """
    return "\n".join(ln for ln in text.split("\n")
                     if not _TS_LINE_RE.match(ln))


def dump_trace(stack: PIMStack, path: str) -> int:
    """Write the stack's trace to ``path``; returns the line count."""
    text = emit_trace(stack)
    with open(path, "w") as f:
        f.write(text)
    return text.count("\n")


# ---------------------------------------------------------------------------
# Parsing (round-trip checks / trace-driven replay entry point)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceStats:
    """Counts reconstructed from a trace file."""

    pim_commands: int = 0
    launches: int = 0                  # AB-mode switches
    cfr_writes: int = 0
    opcodes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    pim_per_channel: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    mem_writes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    mem_reads: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    resident_reuses: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    resident_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    spill_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    kvappend_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    kvevict_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel
    # -- async-timeline schedule markers: (channel, op_id) -> cycles.
    # Empty on serialized traces; stripping the marker lines from an
    # async trace recovers the serialized byte stream ------------------
    op_starts: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    op_ends: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    # -- cluster dimension: on single-stack traces the per-stack counters
    # accumulate under stack 0 (no # STACK markers exist to switch on) —
    # use ``stacks_seen`` (empty unless markers appeared) to distinguish
    # cluster traces, never truthiness of the counters ------------------
    stacks_seen: List[int] = dataclasses.field(default_factory=list)
    pim_per_stack: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    mem_writes_per_stack: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    mem_reads_per_stack: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    host_link_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per kind (xstack|drain)
    host_link_events: int = 0
    # -- switched link topology: per-stack-link sections (# LINK s).
    # ``link_stacks_seen`` records the section markers in order (empty on
    # shared-topology traces); ``host_link_bytes_per_link`` attributes
    # HOSTLINK bytes to the per-stack link they landed on (uplink bytes —
    # those before any # LINK marker — stay out of it) ------------------
    link_stacks_seen: List[int] = dataclasses.field(default_factory=list)
    host_link_bytes_per_link: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per stack link
    # -- routed-MoE expert migrations: (layer, expert, src, dst, bytes)
    # in marker order.  Empty unless a placement migration fired --------
    migrate_events: List[Tuple[int, int, int, int, int]] = \
        dataclasses.field(default_factory=list)
    # -- fault-injection markers (repro.faults): channel -> injection
    # cycle, and recovery bytes landed per channel.  Empty on fault-free
    # traces (the markers only exist when a fault actually fired) -------
    fault_channels: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    recover_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)       # per channel

    @property
    def channels(self):
        return sorted(set(self.pim_per_channel)
                      | set(self.mem_writes) | set(self.mem_reads))

    @property
    def total_host_link_bytes(self) -> int:
        return sum(self.host_link_bytes.values())


_CHANNEL_RE = re.compile(r"^# channel (\d+)$")
_RESIDENT_RE = re.compile(r"^# RESIDENT (\d+) (\d+)$")
_STACK_RE = re.compile(r"^# STACK (\d+)$")
_HOSTLINK_RE = re.compile(
    r"^# HOSTLINK (xstack|drain|retry|reupload|degrade|prefill|acts)"
    r" (\d+)$")
_LINK_RE = re.compile(r"^# LINK (\d+)$")
_MIGRATE_RE = re.compile(r"^# MIGRATE (\d+) (\d+) (\d+) (\d+) (\d+)$")
_SPILL_RE = re.compile(r"^# SPILL (\d+) (\d+)$")
_KVAPPEND_RE = re.compile(r"^# KVAPPEND (\d+) (\d+)$")
_KVEVICT_RE = re.compile(r"^# KVEVICT (\d+) (\d+)$")
_FAULT_RE = re.compile(r"^# FAULT (\d+) ([0-9.]+)$")
_RECOVER_RE = re.compile(r"^# RECOVER (\d+) (\d+)$")
_TSTART_RE = re.compile(r"^# TSTART (\d+) (\d+) ([0-9.]+)$")
_TEND_RE = re.compile(r"^# TEND (\d+) (\d+) ([0-9.]+)$")
_TS_LINE_RE = re.compile(r"^# T(?:START|END) ")
_MEM_RE = re.compile(r"^([RW]) MEM (\d+) (\d+) (\d+)$")
_PIM_RE = re.compile(r"^PIM ([A-Z]+)((?: [A-Z]+,\d+)*)$")
_CFR_RE = re.compile(r'^W CFR "(\d+)" ([A-Z]+)$')


def parse_trace(text: str) -> TraceStats:
    """Parse an emitted trace back into per-channel (and, for cluster
    traces, per-stack / host-link) command counts."""
    stats = TraceStats()
    channel = 0
    stack = 0
    cur_link = None          # per-stack link section (None = uplink)
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        mm = _CHANNEL_RE.match(line)
        if mm:
            channel = int(mm.group(1))
            continue
        mm = _STACK_RE.match(line)
        if mm:
            stack = int(mm.group(1))
            stats.stacks_seen.append(stack)
            continue
        mm = _LINK_RE.match(line)
        if mm:
            cur_link = int(mm.group(1))
            stats.link_stacks_seen.append(cur_link)
            continue
        mm = _HOSTLINK_RE.match(line)
        if mm:
            stats.host_link_events += 1
            stats.host_link_bytes[mm.group(1)] += int(mm.group(2))
            if cur_link is not None:
                stats.host_link_bytes_per_link[cur_link] += \
                    int(mm.group(2))
            continue
        mm = _MIGRATE_RE.match(line)
        if mm:
            stats.migrate_events.append(tuple(int(g)
                                              for g in mm.groups()))
            continue
        mm = _SPILL_RE.match(line)
        if mm:
            stats.spill_bytes[int(mm.group(1))] += int(mm.group(2))
            continue
        mm = _KVAPPEND_RE.match(line)
        if mm:
            stats.kvappend_bytes[int(mm.group(1))] += int(mm.group(2))
            continue
        mm = _KVEVICT_RE.match(line)
        if mm:
            stats.kvevict_bytes[int(mm.group(1))] += int(mm.group(2))
            continue
        mm = _RESIDENT_RE.match(line)
        if mm:
            stats.resident_reuses[int(mm.group(1))] += 1
            stats.resident_bytes[int(mm.group(1))] += int(mm.group(2))
            continue
        mm = _TSTART_RE.match(line)
        if mm:
            stats.op_starts[(int(mm.group(1)), int(mm.group(2)))] = \
                float(mm.group(3))
            continue
        mm = _TEND_RE.match(line)
        if mm:
            stats.op_ends[(int(mm.group(1)), int(mm.group(2)))] = \
                float(mm.group(3))
            continue
        mm = _FAULT_RE.match(line)
        if mm:
            stats.fault_channels[int(mm.group(1))] = float(mm.group(2))
            continue
        mm = _RECOVER_RE.match(line)
        if mm:
            stats.recover_bytes[int(mm.group(1))] += int(mm.group(2))
            continue
        if line.startswith("#"):
            continue
        if line == "AB W":
            stats.launches += 1
            continue
        mm = _CFR_RE.match(line)
        if mm:
            stats.cfr_writes += 1
            continue
        mm = _MEM_RE.match(line)
        if mm:
            if mm.group(1) == "W":
                stats.mem_writes[int(mm.group(2))] += 1
                stats.mem_writes_per_stack[stack] += 1
            else:
                stats.mem_reads[int(mm.group(2))] += 1
                stats.mem_reads_per_stack[stack] += 1
            continue
        mm = _PIM_RE.match(line)
        if mm:
            stats.pim_commands += 1
            stats.opcodes[mm.group(1)] += 1
            stats.pim_per_channel[channel] += 1
            stats.pim_per_stack[stack] += 1
            continue
        raise ValueError(f"unparseable trace line {lineno}: {line!r}")
    return stats
