"""Command-queue scheduler: dispatch one op across pseudo-channels.

The scheduler partitions a GEMM/GEMV/element-wise op according to a
placement policy (:mod:`repro.runtime.placement`), enqueues each shard's
command stream on its pseudo-channel's engine, and reports *makespan*
semantics: channels run asynchronously, so wall-clock time is the maximum
per-channel busy time, never the sum.

Per-channel busy time models transfer/compute overlap the way a
double-buffered host DMA behaves on real PIM parts (PrIM's lesson that
host<->PIM traffic dominates unless overlapped):

    busy = lead_in + max(compute, h2d - lead_in) + d2h

where ``lead_in`` is the transfer time of the channel's *first* operand
tile pair (nothing to overlap with yet), the remaining input traffic
streams behind compute, and results drain after the last PEP retires.

Operands may be host arrays (shipped in full every op, the one-shot
default) or :class:`~repro.runtime.residency.DeviceTensor` handles whose
shards already live on their channels: resident regions charge **zero**
h2d (a ``reuse`` event keeps the trace replayable), misses transfer and
become resident for the next op.  ``keep_output=True`` leaves exact-cover
output shards resident instead of draining them — the d2h is deferred to
:meth:`DeviceTensor.to_host` and skipped entirely when a chained op
consumes the handle in place (element-wise epilogue fusion).

Shards that split K produce FP16 partial products; the scheduler ships
each partial back to the host (accounted as d2h traffic) and reduces them
in ascending-k order — the host-side reduction that balanced placement
trades for utilization.  Partial output shards therefore always drain,
even under ``keep_output``: the reduced value only exists on the host.

Both execution modes charge *identical* ledgers (property-tested), and
each has a fast path and a reference path:

* ``execute=True``  — numerics run on each channel's :class:`AMEEngine`
  (order-exact FP16); output-space placements are bit-exact with a
  single-channel run, with or without residency.  The default
  ``engine="batched"`` executor runs each whole shard as one jitted scan
  (:func:`repro.core.engine.gemm_on_engine_batched`), bit-exact with the
  per-tile ``engine="tiled"`` reference walk.
* ``execute=False`` — analytic: only the cost model runs, for large-shape
  sweeps (the benchmark channel-scaling and residency sections).  Shards
  are charged via closed-form tile-count formulas
  (:func:`repro.core.cost.gemm_shard_cost`) in O(1) per shard; the
  per-tile generator walk remains available as ``engine="tiled"`` and
  charges bit-identical ledgers.

Both fast paths record one :class:`~repro.core.engine.ShardSpan` per
shard instead of per-tile instruction records; the trace emitter expands
spans back to the identical per-tile command stream, so
``emit_trace``/``parse_trace`` round-trips are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import cost as cost_mod
from repro.core.engine import (
    InstrRecord,
    ShardSpan,
    ew_on_engine,
    ew_on_engine_batched,
    ew_tiles,
    gemm_on_engine,
    gemm_on_engine_batched,
    gemm_tiles,
)
from repro.core.isa import PIM_FREQ_HZ
from repro.runtime.device import PIMDevice, PIMStack, transfer_cycles
from repro.runtime.placement import placement_shards
from repro.runtime.residency import BYTES_PER_ELEM, Box, DeviceTensor, \
    box_bytes

#: shard executor modes: "batched" = whole-shard jitted fast path (and
#: closed-form analytic costs); "tiled" = the per-tile reference walk
ENGINE_MODES = ("batched", "tiled")

F16 = np.float16

Operand = Union[jnp.ndarray, np.ndarray, DeviceTensor]


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelReport:
    """One pseudo-channel's share of an op."""

    channel: int
    compute_cycles: float
    flops: int
    commands: int
    h2d_bytes: int
    d2h_bytes: int
    h2d_cycles: int
    d2h_cycles: int
    lead_in_cycles: int
    reuse_bytes: int = 0    # h2d avoided by cross-op operand residency
    dedupe_bytes: int = 0   # h2d avoided by within-op slice dedupe

    @property
    def busy_cycles(self) -> float:
        """Wall-clock busy time under the overlap model (module docstring)."""
        if self.compute_cycles == 0 and self.h2d_cycles == 0 \
                and self.d2h_cycles == 0:
            return 0.0
        stream = max(self.compute_cycles, self.h2d_cycles
                     - self.lead_in_cycles)
        return self.lead_in_cycles + stream + self.d2h_cycles

    def utilization(self, makespan: float) -> float:
        """Fraction of the op's wall-clock this channel spent computing."""
        return self.compute_cycles / makespan if makespan else 0.0


@dataclasses.dataclass(frozen=True)
class RuntimeReport:
    """Device-level account of one scheduled op."""

    op: str
    shape: Tuple[int, ...]
    placement: str
    channels: int                     # pseudo-channels in the stack
    per_channel: Tuple[ChannelReport, ...]

    @property
    def makespan_cycles(self) -> float:
        return max((c.busy_cycles for c in self.per_channel), default=0.0)

    @property
    def total_flops(self) -> int:
        return sum(c.flops for c in self.per_channel)

    @property
    def total_commands(self) -> int:
        return sum(c.commands for c in self.per_channel)

    @property
    def total_bytes(self) -> int:
        return sum(c.h2d_bytes + c.d2h_bytes for c in self.per_channel)

    @property
    def total_h2d_bytes(self) -> int:
        return sum(c.h2d_bytes for c in self.per_channel)

    @property
    def total_d2h_bytes(self) -> int:
        return sum(c.d2h_bytes for c in self.per_channel)

    @property
    def total_reuse_bytes(self) -> int:
        """H2d traffic avoided by cross-op operand residency — on a
        resident-weights op this equals exactly the weight shard bytes."""
        return sum(c.reuse_bytes for c in self.per_channel)

    @property
    def total_dedupe_bytes(self) -> int:
        """H2d traffic avoided by within-op repeated-slice dedupe (charged
        identically on fresh and resident paths)."""
        return sum(c.dedupe_bytes for c in self.per_channel)

    @property
    def flop_per_cycle(self) -> float:
        """Effective throughput at makespan (the scaling headline).

        0.0 for empty/degenerate ops — guarded like
        :meth:`ChannelReport.utilization`, so fully-resident no-transfer
        no-compute reports never divide by zero.
        """
        mk = self.makespan_cycles
        return self.total_flops / mk if mk else 0.0

    @property
    def gflops(self) -> float:
        return self.flop_per_cycle * PIM_FREQ_HZ / 1e9

    @property
    def seconds(self) -> float:
        return self.makespan_cycles / PIM_FREQ_HZ

    def utilizations(self) -> List[float]:
        mk = self.makespan_cycles
        return [c.utilization(mk) for c in self.per_channel]

    def summary(self) -> str:
        # empty per_channel yields a degenerate all-zero line instead of
        # min()/max() raising — guarded like flop_per_cycle
        us = self.utilizations() or [0.0]
        busy = [c for c in self.per_channel if c.busy_cycles > 0]
        return (f"{self.op} {'x'.join(map(str, self.shape))} "
                f"[{self.placement}, {self.channels}ch, {len(busy)} busy]: "
                f"makespan={self.makespan_cycles:.0f}cyc "
                f"{self.gflops:.1f}GFLOP/s "
                f"util(min/mean/max)={min(us):.2f}/"
                f"{sum(us) / len(us):.2f}/{max(us):.2f} "
                f"bytes={self.total_bytes} reuse={self.total_reuse_bytes}")


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


def _unwrap(x: Operand, stack: PIMStack
            ) -> Tuple[Optional[DeviceTensor], Optional[np.ndarray],
                       Tuple[int, int]]:
    """Split an operand into (handle, host values, shape)."""
    if isinstance(x, DeviceTensor):
        assert x.stack is stack, \
            "DeviceTensor was placed on a different runtime's stack; " \
            "residency does not transfer between stacks"
        return x, x.values, x.shape
    return None, x, tuple(x.shape)


class PIMRuntime:
    """Schedules ops onto a :class:`PIMStack` and accounts them.

    ``engine`` selects the default shard executor: ``"batched"`` (fast,
    whole-shard jit / closed-form analytic) or ``"tiled"`` (the per-tile
    reference).  Both are bit-exact and charge identical ledgers; per-op
    ``engine=`` overrides the default.
    """

    def __init__(self, channels: int = 1, stack: Optional[PIMStack] = None,
                 engine: str = "batched"):
        assert engine in ENGINE_MODES, engine
        self.stack = stack if stack is not None else PIMStack(channels)
        self.engine = engine

    # -- internals -----------------------------------------------------------

    def _engine_mode(self, override: Optional[str]) -> str:
        mode = self.engine if override is None else override
        assert mode in ENGINE_MODES, mode
        return mode

    def _record_instrs(self, dev: PIMDevice, n_before: int) -> None:
        for rec in dev.engine.instrs[n_before:]:
            dev.events.append(("instr", rec))

    def _finish(self, op: str, shape: Tuple[int, ...], placement: str,
                before: Dict[int, "object"],
                lead_in: Dict[int, int]) -> RuntimeReport:
        reports = []
        for dev in self.stack:
            b = before[dev.channel_id]
            reports.append(ChannelReport(
                channel=dev.channel_id,
                compute_cycles=dev.compute_cycles - b.cycles,
                flops=dev.compute_flops - b.flops,
                commands=dev.compute_commands - b.commands,
                h2d_bytes=dev.xfer.h2d_bytes - b.h2d_bytes,
                d2h_bytes=dev.xfer.d2h_bytes - b.d2h_bytes,
                h2d_cycles=dev.xfer.h2d_cycles - b.h2d_cycles,
                d2h_cycles=dev.xfer.d2h_cycles - b.d2h_cycles,
                lead_in_cycles=lead_in.get(dev.channel_id, 0),
                reuse_bytes=dev.reuse_bytes - b.reuse_bytes,
                dedupe_bytes=dev.dedupe_bytes - b.dedupe_bytes))
        return RuntimeReport(op=op, shape=shape, placement=placement,
                             channels=len(self.stack),
                             per_channel=tuple(reports))

    def _ship_in(self, dev: PIMDevice, handle: Optional[DeviceTensor],
                 box: Box, shipped: Dict[int, Set], role: str) -> bool:
        """Charge one operand shard's h2d unless resident or already
        shipped to this channel within the current op.  Returns whether
        bytes actually moved (for the lead-in computation).

        Misses on a handle transfer *and* mark resident, so repeated ops
        converge to zero traffic; plain arrays dedupe only within the op
        (the GEMV x-vector shipped once per channel, not once per K-split
        shard).
        """
        nbytes = box_bytes(box)
        if handle is not None:
            if handle.is_resident(dev.channel_id, box):
                dev.note_reuse(nbytes)
                return False
            dev.host_to_pim(nbytes)
            handle.mark_resident(dev.channel_id, box)
            return True
        seen = shipped.setdefault(dev.channel_id, set())
        key = (role, box)
        if key in seen:
            dev.note_dedupe(nbytes)
            return False
        dev.host_to_pim(nbytes)
        seen.add(key)
        return True

    # -- operand placement (the residency entry point) -----------------------

    def place(self, array, *, placement: str = "balanced", role: str = "A",
              other_dim: int = 1) -> DeviceTensor:
        """Upload an array's shards onto the stack; returns a resident
        :class:`DeviceTensor` handle.

        The placement decides the per-channel decomposition using the op
        geometry the tensor will serve in: ``role="A"`` treats the array
        as the (M, K) left/element-wise operand of ops with
        ``N = other_dim`` (the resident-weights GEMV regime); ``role="B"``
        as the (K, N) right operand with ``M = other_dim``.  The one-time
        h2d is charged now, on each shard's channel; subsequent ops with a
        matching placement geometry charge zero h2d for this operand.

        Pass a ``(rows, cols)`` tuple instead of an array for an analytic
        (shape-only) handle usable with ``execute=False`` sweeps.
        """
        if isinstance(array, tuple):
            arr, shape = None, array
        else:
            arr = np.asarray(array, F16)
            shape = arr.shape
        assert len(shape) == 2, shape
        handle = DeviceTensor(self.stack, shape, values=arr)
        if role == "A":
            m, k = shape
            shards = placement_shards(placement, m, k, other_dim,
                                      len(self.stack))
            boxes = [(s.channel, s.a_box) for s in shards]
        elif role == "B":
            k, n = shape
            shards = placement_shards(placement, other_dim, k, n,
                                      len(self.stack))
            boxes = [(s.channel, s.b_box) for s in shards]
        else:
            raise ValueError(f"role must be 'A' or 'B', got {role!r}")
        for ch, box in boxes:
            if handle.is_resident(ch, box):    # replicated shard geometry
                continue
            self.stack[ch].host_to_pim(box_bytes(box))
            handle.mark_resident(ch, box)
        return handle

    # -- GEMM / GEMV ---------------------------------------------------------

    def gemm(self, a: Operand, b: Operand, *,
             placement: str = "row-striped",
             execute: bool = True,
             keep_output: bool = False,
             engine: Optional[str] = None
             ) -> Tuple[Optional[Union[jnp.ndarray, DeviceTensor]],
                        RuntimeReport]:
        """C = A(m,k) @ B(k,n) partitioned across the stack's channels.

        ``a``/``b`` may be host arrays or resident :class:`DeviceTensor`
        handles.  With ``keep_output=True`` the result is returned as a
        resident handle (exact-cover output shards stay on their channels;
        K-split partials still drain for the host reduction) instead of a
        host array.  ``engine`` overrides the runtime's shard executor
        ("batched"/"tiled") for this op.
        """
        mode = self._engine_mode(engine)
        ah, a_vals, (m, k) = _unwrap(a, self.stack)
        bh, b_vals, (k2, n) = _unwrap(b, self.stack)
        assert k == k2, ((m, k), (k2, n))
        assert not execute or (a_vals is not None and b_vals is not None), \
            "analytic (shape-only) DeviceTensor operands require " \
            "execute=False"
        shards = placement_shards(placement, m, k, n, len(self.stack))

        before = {d.channel_id: d.snapshot() for d in self.stack}
        lead_in: Dict[int, int] = {}
        shipped: Dict[int, Set] = {}
        out = np.zeros((m, n), F16) if execute else None
        out_handle = DeviceTensor(self.stack, (m, n), values=out,
                                  copy=False) if keep_output else None
        partials: Dict[Tuple[int, int, int, int],
                       List[Tuple[int, np.ndarray]]] = {}

        for s in shards:
            dev = self.stack[s.channel]
            a_ships = self._ship_in(dev, ah, s.a_box, shipped, "A")
            b_ships = self._ship_in(dev, bh, s.b_box, shipped, "B")
            if s.channel not in lead_in:
                i0, i1, j0, j1, c0, c1 = next(gemm_tiles(s.rows, s.ks, s.ns))
                first = ((i1 - i0) * (c1 - c0) if a_ships else 0) \
                    + ((c1 - c0) * (j1 - j0) if b_ships else 0)
                lead_in[s.channel] = transfer_cycles(first * BYTES_PER_ELEM)
            if execute:
                n_before = len(dev.engine.instrs)
                run = gemm_on_engine_batched if mode == "batched" \
                    else gemm_on_engine
                sub = run(dev.engine,
                          a_vals[s.m0:s.m1, s.k0:s.k1],
                          b_vals[s.k0:s.k1, s.n0:s.n1])
                self._record_instrs(dev, n_before)
                if s.is_partial(k):
                    partials.setdefault((s.m0, s.m1, s.n0, s.n1), []) \
                        .append((s.k0, sub))
                else:
                    out[s.m0:s.m1, s.n0:s.n1] = sub
            elif mode == "batched":
                # closed-form: O(1) per shard, bit-identical to the walk
                agg = cost_mod.gemm_shard_cost(s.rows, s.ks, s.ns)
                dev.charge_analytic(agg.cycles, agg.flops, agg.commands)
                dev.events.append(
                    ("instr", ShardSpan("mac", s.rows, s.ks, s.ns)))
            else:
                for i0, i1, j0, j1, c0, c1 in gemm_tiles(s.rows, s.ks, s.ns):
                    rep = cost_mod.mfmacc_cost(i1 - i0, c1 - c0, j1 - j0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr",
                         InstrRecord("mac", i1 - i0, c1 - c0, j1 - j0)))
            if keep_output and not s.is_partial(k):
                out_handle.mark_resident(s.channel, s.out_box)
                out_handle.pending_d2h.append((s.channel, s.out_box))
            else:
                dev.pim_to_host(s.rows * s.ns * BYTES_PER_ELEM)  # C / partial

        if execute:
            # host-side reduction of K-split partials, ascending-k FP16
            for (m0, m1, n0, n1), parts in partials.items():
                acc: Optional[np.ndarray] = None
                for _, arr in sorted(parts, key=lambda t: t[0]):
                    acc = arr if acc is None else (acc + arr).astype(F16)
                out[m0:m1, n0:n1] = acc

        report = self._finish("gemm", (m, k, n), placement, before, lead_in)
        if keep_output:
            return out_handle, report
        return (jnp.asarray(out) if execute else None), report

    def gemv(self, a: Operand, x: jnp.ndarray, *,
             placement: str = "row-striped",
             execute: bool = True,
             engine: Optional[str] = None
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
        """y = A @ x (the MPC-Wrapper comparison workload), as N=1 GEMM.

        ``a`` may be a resident handle (the serve-loop decode regime:
        weights placed once, only the x vector moves per call); per-channel
        x transfers are deduped across K-split shards that share a slice.
        """
        assert not isinstance(x, DeviceTensor), \
            "gemv x must be a host vector; place A instead"
        y, rep = self.gemm(a, np.asarray(x, F16)[:, None],
                           placement=placement, execute=execute,
                           engine=engine)
        rep = dataclasses.replace(rep, op="gemv")
        return (y[:, 0] if y is not None else None), rep

    # -- element-wise --------------------------------------------------------

    def elementwise(self, kind: str, a: Operand, b: Operand, *,
                    placement: str = "row-striped",
                    execute: bool = True,
                    keep_output: bool = False,
                    engine: Optional[str] = None
                    ) -> Tuple[Optional[Union[jnp.ndarray, DeviceTensor]],
                               RuntimeReport]:
        """out = a <kind> b partitioned over the (M, C) output grid.

        Placements reuse the GEMM shard geometry with the column axis in
        the K slot and N=1; a K-split shard is just a column slab here, so
        every placement is an exact output partition (no reduction).

        Operands may be resident handles — in particular the
        ``keep_output`` handle of a previous GEMM/element-wise op on the
        same placement, in which case the chained operand never touches
        the host (epilogue fusion).  ``keep_output=True`` keeps this op's
        result resident the same way.
        """
        assert kind in ("add", "sub", "mul")
        mode = self._engine_mode(engine)
        ah, a_vals, (m, c) = _unwrap(a, self.stack)
        bh, b_vals, bshape = _unwrap(b, self.stack)
        assert (m, c) == bshape, ((m, c), bshape)
        assert not execute or (a_vals is not None and b_vals is not None), \
            "analytic (shape-only) DeviceTensor operands require " \
            "execute=False"
        shards = placement_shards(placement, m, c, 1, len(self.stack))

        before = {d.channel_id: d.snapshot() for d in self.stack}
        lead_in: Dict[int, int] = {}
        shipped: Dict[int, Set] = {}
        out = np.zeros((m, c), F16) if execute else None
        out_handle = DeviceTensor(self.stack, (m, c), values=out,
                                  copy=False) if keep_output else None

        for s in shards:
            dev = self.stack[s.channel]
            # both operands use the (m, col) footprint: C sits in the K slot
            a_ships = self._ship_in(dev, ah, s.a_box, shipped, "A")
            b_ships = self._ship_in(dev, bh, s.a_box, shipped, "B")
            if s.channel not in lead_in:
                i0, i1, c0, c1 = next(ew_tiles(s.rows, s.ks))
                first = (i1 - i0) * (c1 - c0) * \
                    (int(a_ships) + int(b_ships))
                lead_in[s.channel] = transfer_cycles(first * BYTES_PER_ELEM)
            if execute:
                n_before = len(dev.engine.instrs)
                run = ew_on_engine_batched if mode == "batched" \
                    else ew_on_engine
                sub = run(dev.engine, kind,
                          a_vals[s.m0:s.m1, s.k0:s.k1],
                          b_vals[s.m0:s.m1, s.k0:s.k1])
                self._record_instrs(dev, n_before)
                out[s.m0:s.m1, s.k0:s.k1] = sub
            elif mode == "batched":
                agg = cost_mod.ew_shard_cost(kind, s.rows, s.ks)
                dev.charge_analytic(agg.cycles, agg.flops, agg.commands)
                dev.events.append(("instr", ShardSpan(kind, s.rows, s.ks)))
            else:
                for i0, i1, c0, c1 in ew_tiles(s.rows, s.ks):
                    rep = cost_mod.elementwise_cost(kind, i1 - i0, c1 - c0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr", InstrRecord(kind, i1 - i0, c1 - c0)))
            if keep_output:
                out_handle.mark_resident(s.channel, s.a_box)
                out_handle.pending_d2h.append((s.channel, s.a_box))
            else:
                dev.pim_to_host(s.rows * s.ks * BYTES_PER_ELEM)

        report = self._finish(f"ew-{kind}", (m, c), placement, before,
                              lead_in)
        if keep_output:
            return out_handle, report
        return (jnp.asarray(out) if execute else None), report


# ---------------------------------------------------------------------------
# Convenience entry points (the end-to-end PIM-mode API)
# ---------------------------------------------------------------------------


def pim_gemm(a: jnp.ndarray, b: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True,
             engine: str = "batched"
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """C = A @ B entirely in PIM mode on a fresh ``channels``-wide stack."""
    return PIMRuntime(channels=channels, engine=engine).gemm(
        a, b, placement=placement, execute=execute)


def pim_gemv(a: jnp.ndarray, x: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True,
             engine: str = "batched"
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """y = A @ x entirely in PIM mode on a fresh ``channels``-wide stack."""
    return PIMRuntime(channels=channels, engine=engine).gemv(
        a, x, placement=placement, execute=execute)
