"""Command-queue scheduler: dispatch one op across pseudo-channels.

The scheduler partitions a GEMM/GEMV/element-wise op according to a
placement policy (:mod:`repro.runtime.placement`), enqueues each shard's
command stream on its pseudo-channel's engine, and reports *makespan*
semantics: channels run asynchronously, so wall-clock time is the maximum
per-channel busy time, never the sum.

Per-channel busy time models transfer/compute overlap the way a
double-buffered host DMA behaves on real PIM parts (PrIM's lesson that
host<->PIM traffic dominates unless overlapped):

    busy = lead_in + max(compute, h2d - lead_in) + d2h

where ``lead_in`` is the transfer time of the channel's *first* operand
tile pair (nothing to overlap with yet), the remaining input traffic
streams behind compute, and results drain after the last PEP retires.
``PIMRuntime(overlap=False)`` switches to the synchronous-DMA comparison
model instead: ``busy = h2d + compute + d2h`` — nothing overlaps, the
PrIM-style worst case (identical ledgers, only busy time differs).

The runtime drives either one :class:`PIMStack` or a multi-stack
:class:`~repro.runtime.cluster.PIMCluster` (``PIMRuntime(stacks=N)``).
Placement then grows a leading stack axis — flat-channel geometry is
unchanged at fixed total channels (makespan parity) — and traffic that
crosses stacks is additionally charged on the cluster's shared host
link: operand boxes shipped to more than one stack within an op, and
K-split partial drains whose reduction group spans stacks.  Per-op
``stack=`` restricts the decomposition to one stack (the decode-offload
regime: each layer's weights live on their home stack).  Single-stack
runs never touch the link — their ledgers and traces are byte-identical
to a bare stack.

Operands may be host arrays (shipped in full every op, the one-shot
default) or :class:`~repro.runtime.residency.DeviceTensor` handles whose
shards already live on their channels: resident regions charge **zero**
h2d (a ``reuse`` event keeps the trace replayable), misses transfer and
become resident for the next op.  ``keep_output=True`` leaves exact-cover
output shards resident instead of draining them — the d2h is deferred to
:meth:`DeviceTensor.to_host` and skipped entirely when a chained op
consumes the handle in place (element-wise epilogue fusion).

Shards that split K produce FP16 partial products; the scheduler ships
each partial back to the host (accounted as d2h traffic) and reduces them
in ascending-k order — the host-side reduction that balanced placement
trades for utilization.  Partial output shards therefore always drain,
even under ``keep_output``: the reduced value only exists on the host.

Both execution modes charge *identical* ledgers (property-tested), and
each has a fast path and a reference path:

* ``execute=True``  — numerics run on each channel's :class:`AMEEngine`
  (order-exact FP16); output-space placements are bit-exact with a
  single-channel run, with or without residency.  The default
  ``engine="batched"`` executor runs each whole shard as one jitted scan
  (:func:`repro.core.engine.gemm_on_engine_batched`), bit-exact with the
  per-tile ``engine="tiled"`` reference walk.
* ``execute=False`` — analytic: only the cost model runs, for large-shape
  sweeps (the benchmark channel-scaling and residency sections).  Shards
  are charged via closed-form tile-count formulas
  (:func:`repro.core.cost.gemm_shard_cost`) in O(1) per shard; the
  per-tile generator walk remains available as ``engine="tiled"`` and
  charges bit-identical ledgers.

Both fast paths record one :class:`~repro.core.engine.ShardSpan` per
shard instead of per-tile instruction records; the trace emitter expands
spans back to the identical per-tile command stream, so
``emit_trace``/``parse_trace`` round-trips are unchanged.

``PIMRuntime(async_mode=True)`` layers the dependency-aware timeline of
:mod:`repro.runtime.timeline` on top: ops return :class:`OpHandle`
futures instead of ``(out, report)``, dependencies are inferred from
resident :class:`DeviceTensor` reads/writes (plus explicit ``after=``
edges), and each op's per-channel busy intervals start at ``max(dep
retire, channel free, link free)`` instead of a global barrier — so
independent ops interleave on disjoint channels and host-link windows
block only their dependents.  Ops may also target an explicit channel
subset (``channels=``), the lever the async decode offload uses to run
q/k/v and gate/up concurrently on one stack.  Ledgers, numerics, and
traces are unchanged by async mode (the timeline adds only
replay-neutral ``# TSTART``/``# TEND`` trace markers); with the default
``async_mode=False`` nothing here runs at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import cost as cost_mod
from repro.core.engine import (
    InstrRecord,
    ShardSpan,
    ew_on_engine,
    ew_on_engine_batched,
    ew_tiles,
    gemm_on_engine,
    gemm_on_engine_batched,
    gemm_tiles,
)
from repro.core.isa import PIM_FREQ_HZ
from repro.runtime.cluster import PIMCluster
from repro.runtime.device import PIMDevice, PIMStack, transfer_cycles
from repro.runtime.placement import Shard, cluster_shards, \
    placement_shards, stack_restricted_shards, subset_shards
from repro.runtime.residency import BYTES_PER_ELEM, Box, DeviceTensor, \
    box_bytes
from repro.runtime.timeline import OpHandle, Timeline

#: shard executor modes: "batched" = whole-shard jitted fast path (and
#: closed-form analytic costs); "tiled" = the per-tile reference walk
ENGINE_MODES = ("batched", "tiled")

F16 = np.float16

Operand = Union[jnp.ndarray, np.ndarray, DeviceTensor]


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelReport:
    """One pseudo-channel's share of an op.

    ``channel`` is the cluster-flat id; ``stack`` the owning stack (0 on
    a bare single stack).  ``overlap=False`` reports the synchronous-DMA
    busy model (nothing overlaps) instead of the double-buffered default.
    """

    channel: int
    compute_cycles: float
    flops: int
    commands: int
    h2d_bytes: int
    d2h_bytes: int
    h2d_cycles: int
    d2h_cycles: int
    lead_in_cycles: int
    reuse_bytes: int = 0    # h2d avoided by cross-op operand residency
    dedupe_bytes: int = 0   # h2d avoided by within-op slice dedupe
    stack: int = 0          # owning stack (leading placement axis)
    spill_bytes: int = 0    # residency evicted under a capacity bound
    overlap: bool = True    # transfer/compute overlap model in effect

    @property
    def busy_cycles(self) -> float:
        """Wall-clock busy time under the overlap model (module docstring)."""
        if self.compute_cycles == 0 and self.h2d_cycles == 0 \
                and self.d2h_cycles == 0:
            return 0.0
        if not self.overlap:           # synchronous DMA: strict sequence
            return self.h2d_cycles + self.compute_cycles + self.d2h_cycles
        stream = max(self.compute_cycles, self.h2d_cycles
                     - self.lead_in_cycles)
        return self.lead_in_cycles + stream + self.d2h_cycles

    def utilization(self, makespan: float) -> float:
        """Fraction of the op's wall-clock this channel spent computing."""
        return self.compute_cycles / makespan if makespan else 0.0


@dataclasses.dataclass(frozen=True)
class RuntimeReport:
    """Device-level account of one scheduled op.

    ``stacks`` / ``host_link_bytes`` / ``host_link_cycles`` account the
    cluster dimension: inter-stack traffic over the shared host link
    (always 0 on a single stack).  :attr:`makespan_cycles` keeps its
    per-channel meaning — fixed-total-channel cluster reshapes are
    makespan-parity — while :attr:`cluster_makespan_cycles` folds the
    link in as a second serialization axis.
    """

    op: str
    shape: Tuple[int, ...]
    placement: str
    channels: int                     # pseudo-channels the op decomposed over
    per_channel: Tuple[ChannelReport, ...]
    stacks: int = 1                   # stacks behind the runtime
    host_link_bytes: int = 0          # inter-stack bytes over the host link
    host_link_cycles: int = 0
    # fail-stopped flat channel ids at dispatch time (repro.faults) —
    # non-empty reports ran degraded, on the surviving decomposition
    failed_channels: Tuple[int, ...] = ()

    @property
    def makespan_cycles(self) -> float:
        return max((c.busy_cycles for c in self.per_channel), default=0.0)

    @property
    def cluster_makespan_cycles(self) -> float:
        """Makespan with the shared host link as a serialization axis."""
        return max(self.makespan_cycles, float(self.host_link_cycles))

    @property
    def total_flops(self) -> int:
        return sum(c.flops for c in self.per_channel)

    @property
    def total_commands(self) -> int:
        return sum(c.commands for c in self.per_channel)

    @property
    def total_bytes(self) -> int:
        return sum(c.h2d_bytes + c.d2h_bytes for c in self.per_channel)

    @property
    def total_h2d_bytes(self) -> int:
        return sum(c.h2d_bytes for c in self.per_channel)

    @property
    def total_d2h_bytes(self) -> int:
        return sum(c.d2h_bytes for c in self.per_channel)

    @property
    def total_reuse_bytes(self) -> int:
        """H2d traffic avoided by cross-op operand residency — on a
        resident-weights op this equals exactly the weight shard bytes."""
        return sum(c.reuse_bytes for c in self.per_channel)

    @property
    def total_dedupe_bytes(self) -> int:
        """H2d traffic avoided by within-op repeated-slice dedupe (charged
        identically on fresh and resident paths)."""
        return sum(c.dedupe_bytes for c in self.per_channel)

    @property
    def total_spill_bytes(self) -> int:
        """Residency bytes evicted under per-channel capacity bounds
        during this op (the re-ship exposure, not charged traffic)."""
        return sum(c.spill_bytes for c in self.per_channel)

    @property
    def flop_per_cycle(self) -> float:
        """Effective throughput at makespan (the scaling headline).

        0.0 for empty/degenerate ops — guarded like
        :meth:`ChannelReport.utilization`, so fully-resident no-transfer
        no-compute reports never divide by zero.
        """
        mk = self.makespan_cycles
        return self.total_flops / mk if mk else 0.0

    @property
    def gflops(self) -> float:
        return self.flop_per_cycle * PIM_FREQ_HZ / 1e9

    @property
    def seconds(self) -> float:
        return self.makespan_cycles / PIM_FREQ_HZ

    def utilizations(self) -> List[float]:
        mk = self.makespan_cycles
        return [c.utilization(mk) for c in self.per_channel]

    def summary(self) -> str:
        # empty per_channel yields a degenerate all-zero line instead of
        # min()/max() raising — guarded like flop_per_cycle
        us = self.utilizations() or [0.0]
        busy = [c for c in self.per_channel if c.busy_cycles > 0]
        line = (f"{self.op} {'x'.join(map(str, self.shape))} "
                f"[{self.placement}, {self.channels}ch, {len(busy)} busy]: "
                f"makespan={self.makespan_cycles:.0f}cyc "
                f"{self.gflops:.1f}GFLOP/s "
                f"util(min/mean/max)={min(us):.2f}/"
                f"{sum(us) / len(us):.2f}/{max(us):.2f} "
                f"bytes={self.total_bytes} reuse={self.total_reuse_bytes}")
        if self.stacks > 1:           # single-stack summaries are unchanged
            line += (f" stacks={self.stacks} "
                     f"link_bytes={self.host_link_bytes}")
            # the cluster dimension, self-describing: how serialized the
            # shared link is against the channel makespan, and where the
            # residency machinery moved (or refused to move) bytes
            cmk = self.cluster_makespan_cycles
            link_util = self.host_link_cycles / cmk if cmk else 0.0
            line += (f"\n  cluster: makespan={cmk:.0f}cyc "
                     f"link_cycles={self.host_link_cycles} "
                     f"link_util={link_util:.2f} "
                     f"reuse={self.total_reuse_bytes} "
                     f"dedupe={self.total_dedupe_bytes} "
                     f"spill={self.total_spill_bytes}")
        if self.failed_channels:
            # degraded-makespan section: the op ran on the surviving
            # decomposition, so makespan above IS the degraded figure
            line += (f"\n  degraded: failed_channels="
                     f"{list(self.failed_channels)} "
                     f"surviving={self.channels}ch "
                     f"makespan={self.makespan_cycles:.0f}cyc")
        return line


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


def _unwrap(x: Operand, stack: PIMStack
            ) -> Tuple[Optional[DeviceTensor], Optional[np.ndarray],
                       Tuple[int, int]]:
    """Split an operand into (handle, host values, shape)."""
    if isinstance(x, DeviceTensor):
        assert x.stack is stack, \
            "DeviceTensor was placed on a different runtime's stack; " \
            "residency does not transfer between stacks"
        return x, x.values, x.shape
    return None, x, tuple(x.shape)


class PIMRuntime:
    """Schedules ops onto a :class:`PIMStack` (or a multi-stack
    :class:`PIMCluster`) and accounts them.

    ``engine`` selects the default shard executor: ``"batched"`` (fast,
    whole-shard jit / closed-form analytic) or ``"tiled"`` (the per-tile
    reference).  Both are bit-exact and charge identical ledgers; per-op
    ``engine=`` overrides the default.

    ``stacks > 1`` builds a :class:`PIMCluster` of ``stacks`` x
    ``channels`` pseudo-channels behind one shared host link; ``stack=``
    also accepts a pre-built cluster.  ``overlap=False`` switches busy
    time to the synchronous-DMA model (no transfer/compute overlap);
    ``capacity_bytes`` bounds each channel's residency table (LRU
    eviction counted as spill).

    ``async_mode=True`` attaches the dependency-aware
    :class:`~repro.runtime.timeline.Timeline`: ops return
    :class:`~repro.runtime.timeline.OpHandle` futures (``.result`` /
    ``.report`` carry what the serialized mode returns), start times
    respect inferred DeviceTensor dependencies plus explicit ``after=``
    edges, and ``self.timeline.now`` is the async wall-clock.  Ledgers
    and traces stay identical to serialized mode.
    """

    def __init__(self, channels: int = 1, stack: Optional[PIMStack] = None,
                 engine: str = "batched", stacks: int = 1,
                 overlap: bool = True,
                 capacity_bytes: Optional[int] = None,
                 async_mode: bool = False,
                 link_topology: str = "shared",
                 metrics=None, profile=None, faults=None):
        assert engine in ENGINE_MODES, engine
        if stack is not None:
            if stacks != 1 or capacity_bytes is not None \
                    or link_topology != "shared":
                raise ValueError(
                    "stacks=/capacity_bytes=/link_topology= configure a "
                    "runtime-built stack and are ignored with an explicit "
                    "stack= — build the PIMCluster/PIMStack with them "
                    "instead")
            self.stack = stack
        elif stacks > 1:
            self.stack = PIMCluster(stacks, channels,
                                    capacity_bytes=capacity_bytes,
                                    link_topology=link_topology)
        else:
            self.stack = PIMStack(channels, capacity_bytes=capacity_bytes)
        self.engine = engine
        self.overlap = overlap
        self._cluster = self.stack if isinstance(self.stack, PIMCluster) \
            else None
        self.async_mode = async_mode
        self.timeline: Optional[Timeline] = \
            Timeline(self.stack, self._cluster) if async_mode else None
        # dep inference: tensor uid -> the OpHandle that last wrote it
        # (place uploads and keep_output results); readers wait on it
        self._writers: Dict[int, OpHandle] = {}
        # -- observability (repro.obs), strictly additive: both hooks
        # only *read* finished reports/ledgers, so traces, ledgers and
        # numerics are untouched when either is attached, and nothing
        # below runs at all when both stay None (the default)
        self.metrics = metrics
        if metrics is not None and self._cluster is not None:
            for link in self._cluster.all_links():
                link.metrics = metrics
        self.profile = None
        if profile:
            from repro.obs.profile import Profiler
            prof = Profiler() if profile is True else profile
            self.profile = prof.attach(self)
        # -- fault injection (repro.faults), same additive discipline:
        # an attached *empty* plan leaves ledgers ==-equal and traces
        # byte-identical, and with faults=None nothing below runs at all
        self.faults = None
        if faults is not None:
            from repro.faults.injector import FaultInjector
            from repro.faults.plan import as_plan
            self.faults = FaultInjector(as_plan(faults), self)
            if self._cluster is not None:
                # per-link routing: every ledger (shared/uplink and each
                # per-stack link on a switched cluster) gets the hook, so
                # retries/degradation land on the link that carried the
                # bytes
                for link in self._cluster.all_links():
                    link.faults = self.faults

    # -- internals -----------------------------------------------------------

    def _engine_mode(self, override: Optional[str]) -> str:
        mode = self.engine if override is None else override
        assert mode in ENGINE_MODES, mode
        return mode

    @property
    def n_stacks(self) -> int:
        return self._cluster.n_stacks if self._cluster else 1

    def _shards(self, placement: str, m: int, k: int, n: int,
                stack: Optional[int],
                channels: Optional[Sequence[int]] = None
                ) -> Tuple[Shard, ...]:
        """Resolve the op's shard decomposition, stack axis included.

        ``channels`` restricts the op to an explicit subset of flat
        channel ids (the async concurrent-group regime); ``stack``
        restricts to one whole stack of a cluster.  They are mutually
        exclusive.
        """
        if channels is not None:
            if stack is not None:
                raise ValueError(
                    "pass stack= or channels=, not both — a channel "
                    "subset already pins the op's devices")
            chans = tuple(sorted(channels))
            total = len(self.stack)
            if not chans or not all(0 <= c < total for c in chans):
                raise ValueError(
                    f"channel subset {chans} out of range for "
                    f"{total} flat channels")
            cps = self._cluster.channels_per_stack if self._cluster \
                else len(self.stack)
            return subset_shards(placement, m, k, n, chans, cps)
        if self._cluster is None:
            if stack is not None:
                raise ValueError(
                    "stack= requires a multi-stack runtime "
                    "(PIMRuntime(stacks=N) or an explicit PIMCluster)")
            return placement_shards(placement, m, k, n, len(self.stack))
        cps = self._cluster.channels_per_stack
        if stack is None:
            return cluster_shards(placement, m, k, n,
                                  self._cluster.n_stacks, cps)
        if not 0 <= stack < self._cluster.n_stacks:
            raise ValueError(
                f"stack {stack} out of range for a "
                f"{self._cluster.n_stacks}-stack cluster")
        return stack_restricted_shards(placement, m, k, n, stack, cps)

    def _flat(self, s: Shard) -> int:
        """Cluster-flat channel id of a shard's (stack, channel)."""
        if self._cluster is None:
            return s.channel
        return self._cluster.flat(s.stack, s.channel)

    def _link_charge_ship(self, key, stack_idx: int, nbytes: int,
                          link_seen: Dict) -> None:
        """Charge the host link when an operand box crosses stacks.

        Shared topology: every copy of the same box beyond its first
        stack's is inter-stack — one ``xstack`` charge per extra
        destination on the shared link.  Switched topology: the switch
        *multicasts*, so a replicated box is read out of its source
        stack once — one ``xstack`` charge on the source stack's link
        when the first extra destination appears, further destinations
        free.  ``link_seen`` tracks each box's destination stacks in
        first-landed order across the op.
        """
        if self._cluster is None:
            return
        seen = link_seen.setdefault(key, [])
        if seen and stack_idx not in seen:
            if self._cluster.links is not None:
                if len(seen) == 1:      # multicast: source reads out once
                    self._cluster.link_for(seen[0]).charge("xstack", nbytes)
            else:
                self._cluster.link.charge("xstack", nbytes)
        if stack_idx not in seen:
            seen.append(stack_idx)

    def _record_instrs(self, dev: PIMDevice, n_before: int) -> None:
        for rec in dev.engine.instrs[n_before:]:
            dev.events.append(("instr", rec))

    def _link_before(self) -> Tuple:
        """Pre-op link snapshot: (total bytes, total cycles) over every
        link ledger, plus — switched topology only — the per-link cycle
        clocks the async submit path splits its occupancy dict from."""
        if self._cluster is None:
            return (0, 0, None)
        b, c = self._cluster.link_totals()
        per = (tuple(l.cycles for l in self._cluster.all_links())
               if self._cluster.links is not None else None)
        return (b, c, per)

    def _link_cycles_async(self, total_cycles: int, link_before: Tuple):
        """The ``link_cycles`` argument for :meth:`Timeline.submit`: the
        op's total link occupancy on a shared topology, or a
        ``{stack|None: cycles}`` per-link delta dict on a switched one
        (``None`` keys the switch uplink)."""
        per_before = link_before[2] if len(link_before) > 2 else None
        if per_before is None:
            return total_cycles
        delta = {}
        for i, link in enumerate(self._cluster.all_links()):
            d = link.cycles - per_before[i]
            if d > 0:
                delta[None if i == 0 else i - 1] = d
        return delta

    def _op_devices(self, stack: Optional[int],
                    channels: Optional[Sequence[int]] = None
                    ) -> List[PIMDevice]:
        """Devices participating in an op: the explicit subset under a
        ``channels=`` restriction, one stack's under ``stack=``, the
        whole stack/cluster otherwise — so restricted ops snapshot and
        report only the channels that can do work."""
        if channels is not None:
            return [self.stack[c] for c in sorted(channels)]
        if stack is None or self._cluster is None:
            return list(self.stack)
        return self._cluster.stacks[stack].devices

    def _note_op(self, report: RuntimeReport) -> None:
        """Fold one finished op's report into the metrics registry."""
        m = self.metrics
        m.counter("runtime.ops", unit="ops",
                  help="ops scheduled (gemm/gemv/elementwise)").inc()
        m.counter("runtime.flops", unit="flop",
                  help="FLOPs executed across channels").inc(
            report.total_flops)
        m.counter("runtime.commands", unit="commands",
                  help="PIM column commands issued").inc(
            report.total_commands)
        m.counter("runtime.h2d_bytes", unit="bytes",
                  help="host->PIM bytes actually transferred").inc(
            report.total_h2d_bytes)
        m.counter("runtime.d2h_bytes", unit="bytes",
                  help="PIM->host bytes actually transferred").inc(
            report.total_d2h_bytes)
        m.counter("runtime.reuse_bytes", unit="bytes",
                  help="h2d avoided by cross-op residency").inc(
            report.total_reuse_bytes)
        m.counter("runtime.dedupe_bytes", unit="bytes",
                  help="h2d avoided by within-op slice dedupe").inc(
            report.total_dedupe_bytes)
        m.counter("runtime.spill_bytes", unit="bytes",
                  help="residency evicted under capacity bounds").inc(
            report.total_spill_bytes)
        m.histogram("runtime.op_makespan_cycles", unit="cycles",
                    help="per-op cluster makespan distribution").record(
            report.cluster_makespan_cycles)

    def _fault_epilogue(self, report: RuntimeReport,
                        out_handle: Optional[DeviceTensor]) -> None:
        """Per-op fault-injector bookkeeping: register kept outputs for
        pinned-output replay (with their producer busy cycles), advance
        the serialized fault clock, and close the op's lost-uid window."""
        inj = self.faults
        if out_handle is not None and out_handle.pending_d2h:
            inj.register(out_handle)
            busy_by = {c.channel: c.busy_cycles for c in report.per_channel}
            for ch, _box in out_handle.pending_d2h:
                inj.note_output(out_handle.uid, ch, busy_by.get(ch, 0.0))
        if self.timeline is None:
            inj.advance(report.cluster_makespan_cycles)
        inj.end_op()

    def _submit_async(self, name: str, busy: Dict[int, float],
                      link_cycles: int, marks: Dict[int, int],
                      reads: Sequence[int], writes: Sequence[int],
                      after: Optional[Sequence[OpHandle]],
                      report: Optional[RuntimeReport],
                      result) -> OpHandle:
        """Register one executed op on the timeline (async mode only).

        ``marks`` holds each participating device's event-stream length
        from before the op ran — the insertion point for the op's
        ``# TSTART`` marker, so timestamps wrap exactly the events the
        op appended and stripping them recovers the serialized trace
        byte-for-byte.
        """
        deps: List[OpHandle] = []
        seen: Set[int] = set()
        for h in [self._writers.get(uid) for uid in reads] \
                + list(after or ()):
            if h is not None and h.op_id not in seen:
                deps.append(h)
                seen.add(h.op_id)
        handle = self.timeline.submit(name, busy, link_cycles, deps,
                                      report=report, result=result)
        for uid in writes:
            self._writers[uid] = handle
        for ch, (start, b) in handle.spans.items():
            dev = self.stack[ch]
            dev.events.insert(marks[ch], ("tstart", (handle.op_id, start)))
            dev.events.append(("tend", (handle.op_id, start + b)))
        return handle

    def _finish(self, op: str, shape: Tuple[int, ...], placement: str,
                before: Dict[int, "object"],
                lead_in: Dict[int, int],
                link_before: Tuple[int, int] = (0, 0),
                devices: Optional[List[PIMDevice]] = None) -> RuntimeReport:
        devs = list(self.stack) if devices is None else devices
        reports = []
        for dev in devs:
            b = before[dev.channel_id]
            reports.append(ChannelReport(
                channel=dev.channel_id,
                compute_cycles=dev.compute_cycles - b.cycles,
                flops=dev.compute_flops - b.flops,
                commands=dev.compute_commands - b.commands,
                h2d_bytes=dev.xfer.h2d_bytes - b.h2d_bytes,
                d2h_bytes=dev.xfer.d2h_bytes - b.d2h_bytes,
                h2d_cycles=dev.xfer.h2d_cycles - b.h2d_cycles,
                d2h_cycles=dev.xfer.d2h_cycles - b.d2h_cycles,
                lead_in_cycles=lead_in.get(dev.channel_id, 0),
                reuse_bytes=dev.reuse_bytes - b.reuse_bytes,
                dedupe_bytes=dev.dedupe_bytes - b.dedupe_bytes,
                stack=(self._cluster.stack_of(dev.channel_id)
                       if self._cluster else 0),
                spill_bytes=dev.spill_bytes - b.spill_bytes,
                overlap=self.overlap))
        lb, lc = self._link_before()[:2]
        return RuntimeReport(
            op=op, shape=shape, placement=placement,
            channels=len(devs),       # == the decomposition width
            per_channel=tuple(reports),
            stacks=self.n_stacks,
            host_link_bytes=lb - link_before[0],
            host_link_cycles=lc - link_before[1],
            failed_channels=(tuple(sorted(self.faults.failed))
                             if self.faults is not None
                             and self.faults.failed else ()))

    def _ship_in(self, dev: PIMDevice, handle: Optional[DeviceTensor],
                 box: Box, shipped: Dict[int, Set], role: str,
                 link_seen: Optional[Dict] = None) -> bool:
        """Charge one operand shard's h2d unless resident or already
        shipped to this channel within the current op.  Returns whether
        bytes actually moved (for the lead-in computation).

        Misses on a handle transfer *and* mark resident, so repeated ops
        converge to zero traffic; plain arrays dedupe only within the op
        (the GEMV x-vector shipped once per channel, not once per K-split
        shard).  On a cluster, a box that actually moves to channels of
        more than one stack additionally charges the host link for every
        stack beyond its first (``link_seen`` tracks per-operand boxes
        across the op).
        """
        nbytes = box_bytes(box)
        if handle is not None:
            if handle.is_resident(dev.channel_id, box):
                dev.note_reuse(nbytes)
                return False
            dev.host_to_pim(nbytes)
            if self.faults is not None:
                # a miss whose residency was lost to a channel failure is
                # recovery traffic: the host link re-carries it on clusters
                self.faults.on_reship(dev, handle.uid, nbytes)
            if link_seen is not None:
                self._link_charge_ship(
                    (role, handle.uid, box),
                    self._cluster.stack_of(dev.channel_id), nbytes,
                    link_seen)
            handle.mark_resident(dev.channel_id, box)
            return True
        seen = shipped.setdefault(dev.channel_id, set())
        key = (role, box)
        if key in seen:
            dev.note_dedupe(nbytes)
            return False
        dev.host_to_pim(nbytes)
        if link_seen is not None:
            self._link_charge_ship(
                (role, None, box),
                self._cluster.stack_of(dev.channel_id), nbytes, link_seen)
        seen.add(key)
        return True

    # -- operand placement (the residency entry point) -----------------------

    def place(self, array, *, placement: str = "balanced", role: str = "A",
              other_dim: int = 1,
              stack: Optional[int] = None,
              channels: Optional[Sequence[int]] = None) -> DeviceTensor:
        """Upload an array's shards onto the stack; returns a resident
        :class:`DeviceTensor` handle.

        The placement decides the per-channel decomposition using the op
        geometry the tensor will serve in: ``role="A"`` treats the array
        as the (M, K) left/element-wise operand of ops with
        ``N = other_dim`` (the resident-weights GEMV regime); ``role="B"``
        as the (K, N) right operand with ``M = other_dim``.  The one-time
        h2d is charged now, on each shard's channel; subsequent ops with a
        matching placement geometry charge zero h2d for this operand.

        Pass a ``(rows, cols)`` tuple instead of an array for an analytic
        (shape-only) handle usable with ``execute=False`` sweeps.  On a
        multi-stack runtime, ``stack=`` pins the whole tensor to one
        stack (consume it with the same ``stack=`` on ops); the default
        spreads shards over every stack, charging the host link where a
        replicated box lands on more than one stack.  ``channels=`` pins
        the tensor to an explicit flat-channel subset instead (consume
        it with the same ``channels=`` on ops); on an async runtime the
        upload itself becomes a timeline op, so every consumer of the
        handle starts after the weights have landed.
        """
        if isinstance(array, tuple):
            arr, shape = None, tuple(array)
        else:
            arr = np.asarray(array, F16)
            shape = arr.shape
        if len(shape) != 2:
            raise ValueError(
                f"PIMRuntime.place expects a 2D array or a (rows, cols) "
                f"shape tuple, got shape {shape} — reshape/flatten to 2D "
                f"(e.g. arr.reshape(rows, -1)) before placing")
        if self.faults is not None:
            stack, channels = self.faults.on_op(stack, channels)
        handle = DeviceTensor(self.stack, shape, values=arr)
        if role == "A":
            m, k = shape
            shards = self._shards(placement, m, k, other_dim, stack,
                                  channels)
            boxes = [(s, s.a_box) for s in shards]
        elif role == "B":
            k, n = shape
            shards = self._shards(placement, other_dim, k, n, stack,
                                  channels)
            boxes = [(s, s.b_box) for s in shards]
        else:
            raise ValueError(f"role must be 'A' or 'B', got {role!r}")
        op_devs = self._op_devices(stack, channels)
        marks = {d.channel_id: len(d.events) for d in op_devs}
        before_h2d = {d.channel_id: d.xfer.h2d_cycles for d in op_devs}
        before_h2d_bytes = {d.channel_id: d.xfer.h2d_bytes
                            for d in op_devs} \
            if self.metrics is not None else None
        link_before = self._link_before()
        link_seen: Dict = {}
        for s, box in boxes:
            flat = self._flat(s)
            if handle.is_resident(flat, box):    # replicated shard geometry
                continue
            self.stack[flat].host_to_pim(box_bytes(box))
            if self._cluster is not None:
                self._link_charge_ship((role, handle.uid, box), s.stack,
                                       box_bytes(box), link_seen)
            handle.mark_resident(flat, box)
        if self.metrics is not None:
            self.metrics.counter(
                "runtime.place_ops", unit="ops",
                help="operand placements (weight uploads)").inc()
            self.metrics.counter(
                "runtime.upload_bytes", unit="bytes",
                help="one-time h2d charged by place()").inc(
                sum(d.xfer.h2d_bytes - before_h2d_bytes[d.channel_id]
                    for d in op_devs))
        if self.faults is not None:
            if self.timeline is None:
                self.faults.advance(max(
                    max((float(d.xfer.h2d_cycles - before_h2d[d.channel_id])
                         for d in op_devs), default=0.0),
                    float(self._link_before()[1] - link_before[1])))
            self.faults.end_op()
        if self.timeline is not None:
            busy = {d.channel_id:
                    float(d.xfer.h2d_cycles - before_h2d[d.channel_id])
                    for d in op_devs}
            self._submit_async(
                "place", busy,
                self._link_cycles_async(
                    self._link_before()[1] - link_before[1], link_before),
                marks,
                reads=(), writes=(handle.uid,), after=None,
                report=None, result=handle)
        elif self.profile is not None:
            self.profile.on_op(
                "place",
                {d.channel_id:
                 float(d.xfer.h2d_cycles - before_h2d[d.channel_id])
                 for d in op_devs},
                self._link_before()[1] - link_before[1])
        return handle

    # -- GEMM / GEMV ---------------------------------------------------------

    def gemm(self, a: Operand, b: Operand, *,
             placement: str = "row-striped",
             execute: bool = True,
             keep_output: bool = False,
             engine: Optional[str] = None,
             stack: Optional[int] = None,
             channels: Optional[Sequence[int]] = None,
             after: Optional[Sequence[OpHandle]] = None
             ) -> Union[Tuple[Optional[Union[jnp.ndarray, DeviceTensor]],
                              RuntimeReport], OpHandle]:
        """C = A(m,k) @ B(k,n) partitioned across the stack's channels.

        ``a``/``b`` may be host arrays or resident :class:`DeviceTensor`
        handles.  With ``keep_output=True`` the result is returned as a
        resident handle (exact-cover output shards stay on their channels;
        K-split partials still drain for the host reduction) instead of a
        host array.  ``engine`` overrides the runtime's shard executor
        ("batched"/"tiled") for this op.  On a multi-stack runtime,
        ``stack=`` restricts the op to one stack's channels; the default
        decomposes over every stack and charges inter-stack traffic on
        the host link.  ``channels=`` restricts to an explicit flat
        channel subset instead (concurrent-group regime).

        On an async runtime the call returns an :class:`OpHandle`
        (``.result`` / ``.report`` carry this tuple's values) whose
        timeline start respects inferred DeviceTensor dependencies plus
        the explicit ``after=`` handles; serialized runtimes ignore
        ``after=`` (program order already implies it).
        """
        mode = self._engine_mode(engine)
        ah, a_vals, (m, k) = _unwrap(a, self.stack)
        bh, b_vals, (k2, n) = _unwrap(b, self.stack)
        assert k == k2, ((m, k), (k2, n))
        assert not execute or (a_vals is not None and b_vals is not None), \
            "analytic (shape-only) DeviceTensor operands require " \
            "execute=False"
        if self.faults is not None:
            # fire due fault events, then decompose over survivors only
            stack, channels = self.faults.on_op(stack, channels)
        shards = self._shards(placement, m, k, n, stack, channels)

        op_devs = self._op_devices(stack, channels)
        marks = {d.channel_id: len(d.events) for d in op_devs}
        before = {d.channel_id: d.snapshot() for d in op_devs}
        link_before = self._link_before()
        lead_in: Dict[int, int] = {}
        shipped: Dict[int, Set] = {}
        link_seen: Optional[Dict] = {} if self._cluster else None
        out = np.zeros((m, n), F16) if execute else None
        out_handle = DeviceTensor(self.stack, (m, n), values=out,
                                  copy=False) if keep_output else None
        partials: Dict[Tuple[int, int, int, int],
                       List[Tuple[int, np.ndarray]]] = {}
        # K-split reduction groups: out_box -> [(stack, drained bytes)] in
        # dispatch order, for the cross-stack host-link gather charge
        drain_groups: Dict[Tuple[int, int, int, int],
                           List[Tuple[int, int]]] = {}

        for s in shards:
            flat = self._flat(s)
            dev = self.stack[flat]
            a_ships = self._ship_in(dev, ah, s.a_box, shipped, "A",
                                    link_seen)
            b_ships = self._ship_in(dev, bh, s.b_box, shipped, "B",
                                    link_seen)
            if flat not in lead_in:
                i0, i1, j0, j1, c0, c1 = next(gemm_tiles(s.rows, s.ks, s.ns))
                first = ((i1 - i0) * (c1 - c0) if a_ships else 0) \
                    + ((c1 - c0) * (j1 - j0) if b_ships else 0)
                lead_in[flat] = transfer_cycles(first * BYTES_PER_ELEM)
            if execute:
                n_before = len(dev.engine.instrs)
                run = gemm_on_engine_batched if mode == "batched" \
                    else gemm_on_engine
                sub = run(dev.engine,
                          a_vals[s.m0:s.m1, s.k0:s.k1],
                          b_vals[s.k0:s.k1, s.n0:s.n1])
                self._record_instrs(dev, n_before)
                if s.is_partial(k):
                    partials.setdefault((s.m0, s.m1, s.n0, s.n1), []) \
                        .append((s.k0, sub))
                else:
                    out[s.m0:s.m1, s.n0:s.n1] = sub
            elif mode == "batched":
                # closed-form: O(1) per shard, bit-identical to the walk
                agg = cost_mod.gemm_shard_cost(s.rows, s.ks, s.ns)
                dev.charge_analytic(agg.cycles, agg.flops, agg.commands)
                dev.events.append(
                    ("instr", ShardSpan("mac", s.rows, s.ks, s.ns)))
            else:
                for i0, i1, j0, j1, c0, c1 in gemm_tiles(s.rows, s.ks, s.ns):
                    rep = cost_mod.mfmacc_cost(i1 - i0, c1 - c0, j1 - j0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr",
                         InstrRecord("mac", i1 - i0, c1 - c0, j1 - j0)))
            # an output shard stays on-channel only if residency actually
            # records it (a capacity bound may refuse); otherwise it
            # drains now like any result, so ledger and trace stay
            # consistent with what the host really received
            kept = keep_output and not s.is_partial(k) \
                and out_handle.mark_resident(flat, s.out_box, pin=True)
            if kept:
                out_handle.pending_d2h.append((flat, s.out_box))
            else:
                drained = s.rows * s.ns * BYTES_PER_ELEM   # C / partial
                dev.pim_to_host(drained)
                if s.is_partial(k) and self._cluster is not None:
                    drain_groups.setdefault(s.out_box, []) \
                        .append((s.stack, drained))

        # K-split reduction groups spanning stacks gather their partials
        # over the host link: every partial from a non-home stack (home =
        # the group's first-dispatched shard's stack) crosses it — on a
        # switched cluster, over the *sending* stack's own link (the
        # partials are distinct data, so there is nothing to multicast)
        if self._cluster is not None:
            for parts in drain_groups.values():
                home = parts[0][0]
                for st, nbytes in parts:
                    if st != home:
                        self._cluster.link_for(st).charge("drain", nbytes)

        if execute:
            # host-side reduction of K-split partials, ascending-k FP16
            for (m0, m1, n0, n1), parts in partials.items():
                acc: Optional[np.ndarray] = None
                for _, arr in sorted(parts, key=lambda t: t[0]):
                    acc = arr if acc is None else (acc + arr).astype(F16)
                out[m0:m1, n0:n1] = acc

        report = self._finish("gemm", (m, k, n), placement, before,
                              lead_in, link_before=link_before,
                              devices=op_devs)
        if self.metrics is not None:
            self._note_op(report)
        if self.faults is not None:
            self._fault_epilogue(report, out_handle)
        result = out_handle if keep_output \
            else (jnp.asarray(out) if execute else None)
        if self.timeline is not None:
            return self._submit_async(
                "gemm",
                {c.channel: c.busy_cycles for c in report.per_channel},
                self._link_cycles_async(report.host_link_cycles,
                                        link_before), marks,
                reads=[h.uid for h in (ah, bh) if h is not None],
                writes=(out_handle.uid,) if keep_output else (),
                after=after, report=report, result=result)
        if self.profile is not None:
            self.profile.on_op(
                "gemm",
                {c.channel: c.busy_cycles for c in report.per_channel},
                report.host_link_cycles, report=report)
        return result, report

    def gemv(self, a: Operand, x: jnp.ndarray, *,
             placement: str = "row-striped",
             execute: bool = True,
             engine: Optional[str] = None,
             stack: Optional[int] = None,
             channels: Optional[Sequence[int]] = None,
             after: Optional[Sequence[OpHandle]] = None
             ) -> Union[Tuple[Optional[jnp.ndarray], RuntimeReport],
                        OpHandle]:
        """y = A @ x (the MPC-Wrapper comparison workload), as N=1 GEMM.

        ``a`` may be a resident handle (the serve-loop decode regime:
        weights placed once, only the x vector moves per call); per-channel
        x transfers are deduped across K-split shards that share a slice.
        """
        assert not isinstance(x, DeviceTensor), \
            "gemv x must be a host vector; place A instead"
        res = self.gemm(a, np.asarray(x, F16)[:, None],
                        placement=placement, execute=execute,
                        engine=engine, stack=stack, channels=channels,
                        after=after)
        if isinstance(res, OpHandle):
            res.name = "gemv"
            res.report = dataclasses.replace(res.report, op="gemv")
            if res.result is not None:
                res.result = res.result[:, 0]
            return res
        y, rep = res
        rep = dataclasses.replace(rep, op="gemv")
        if self.profile is not None:
            self.profile.amend_last("gemv", rep)
        return (y[:, 0] if y is not None else None), rep

    # -- element-wise --------------------------------------------------------

    def elementwise(self, kind: str, a: Operand, b: Operand, *,
                    placement: str = "row-striped",
                    execute: bool = True,
                    keep_output: bool = False,
                    engine: Optional[str] = None,
                    stack: Optional[int] = None,
                    channels: Optional[Sequence[int]] = None,
                    after: Optional[Sequence[OpHandle]] = None
                    ) -> Union[
                        Tuple[Optional[Union[jnp.ndarray, DeviceTensor]],
                              RuntimeReport], OpHandle]:
        """out = a <kind> b partitioned over the (M, C) output grid.

        Placements reuse the GEMM shard geometry with the column axis in
        the K slot and N=1; a K-split shard is just a column slab here, so
        every placement is an exact output partition (no reduction).

        Operands may be resident handles — in particular the
        ``keep_output`` handle of a previous GEMM/element-wise op on the
        same placement, in which case the chained operand never touches
        the host (epilogue fusion).  ``keep_output=True`` keeps this op's
        result resident the same way.
        """
        assert kind in ("add", "sub", "mul")
        mode = self._engine_mode(engine)
        ah, a_vals, (m, c) = _unwrap(a, self.stack)
        bh, b_vals, bshape = _unwrap(b, self.stack)
        assert (m, c) == bshape, ((m, c), bshape)
        assert not execute or (a_vals is not None and b_vals is not None), \
            "analytic (shape-only) DeviceTensor operands require " \
            "execute=False"
        if self.faults is not None:
            stack, channels = self.faults.on_op(stack, channels)
        shards = self._shards(placement, m, c, 1, stack, channels)

        op_devs = self._op_devices(stack, channels)
        marks = {d.channel_id: len(d.events) for d in op_devs}
        before = {d.channel_id: d.snapshot() for d in op_devs}
        link_before = self._link_before()
        lead_in: Dict[int, int] = {}
        shipped: Dict[int, Set] = {}
        link_seen: Optional[Dict] = {} if self._cluster else None
        out = np.zeros((m, c), F16) if execute else None
        out_handle = DeviceTensor(self.stack, (m, c), values=out,
                                  copy=False) if keep_output else None

        for s in shards:
            flat = self._flat(s)
            dev = self.stack[flat]
            # both operands use the (m, col) footprint: C sits in the K slot
            a_ships = self._ship_in(dev, ah, s.a_box, shipped, "A",
                                    link_seen)
            b_ships = self._ship_in(dev, bh, s.a_box, shipped, "B",
                                    link_seen)
            if flat not in lead_in:
                i0, i1, c0, c1 = next(ew_tiles(s.rows, s.ks))
                first = (i1 - i0) * (c1 - c0) * \
                    (int(a_ships) + int(b_ships))
                lead_in[flat] = transfer_cycles(first * BYTES_PER_ELEM)
            if execute:
                n_before = len(dev.engine.instrs)
                run = ew_on_engine_batched if mode == "batched" \
                    else ew_on_engine
                sub = run(dev.engine, kind,
                          a_vals[s.m0:s.m1, s.k0:s.k1],
                          b_vals[s.m0:s.m1, s.k0:s.k1])
                self._record_instrs(dev, n_before)
                out[s.m0:s.m1, s.k0:s.k1] = sub
            elif mode == "batched":
                agg = cost_mod.ew_shard_cost(kind, s.rows, s.ks)
                dev.charge_analytic(agg.cycles, agg.flops, agg.commands)
                dev.events.append(("instr", ShardSpan(kind, s.rows, s.ks)))
            else:
                for i0, i1, c0, c1 in ew_tiles(s.rows, s.ks):
                    rep = cost_mod.elementwise_cost(kind, i1 - i0, c1 - c0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr", InstrRecord(kind, i1 - i0, c1 - c0)))
            # as in gemm: only actually-resident outputs defer their drain
            if keep_output and out_handle.mark_resident(flat, s.a_box,
                                                        pin=True):
                out_handle.pending_d2h.append((flat, s.a_box))
            else:
                dev.pim_to_host(s.rows * s.ks * BYTES_PER_ELEM)

        report = self._finish(f"ew-{kind}", (m, c), placement, before,
                              lead_in, link_before=link_before,
                              devices=op_devs)
        if self.metrics is not None:
            self._note_op(report)
        if self.faults is not None:
            self._fault_epilogue(report, out_handle)
        result = out_handle if keep_output \
            else (jnp.asarray(out) if execute else None)
        if self.timeline is not None:
            return self._submit_async(
                f"ew-{kind}",
                {cr.channel: cr.busy_cycles for cr in report.per_channel},
                self._link_cycles_async(report.host_link_cycles,
                                        link_before), marks,
                reads=[h.uid for h in (ah, bh) if h is not None],
                writes=(out_handle.uid,) if keep_output else (),
                after=after, report=report, result=result)
        if self.profile is not None:
            self.profile.on_op(
                f"ew-{kind}",
                {cr.channel: cr.busy_cycles for cr in report.per_channel},
                report.host_link_cycles, report=report)
        return result, report

    def softmax(self, a: DeviceTensor, *,
                placement: str = "paged",
                execute: bool = True,
                stack: Optional[int] = None,
                channels: Optional[Sequence[int]] = None,
                after: Optional[Sequence[OpHandle]] = None
                ) -> Union[Tuple[DeviceTensor, RuntimeReport], OpHandle]:
        """Column softmax (axis 0), *in place* on a resident handle — the
        attention epilogue between the score and context GEMVs.

        Cost model: exactly two mul-class elementwise passes per shard
        (the exponentiation pass, then the normalize multiply; the
        cross-page max/sum reduction rides the paper's in-memory
        accumulation dataflow and is folded into the second pass) and
        **zero transfers** — the operand is expected resident (the kept
        score output; a miss ships it in honestly and marks it) and the
        result overwrites the same resident boxes, so the probabilities
        are consumed on-device by the context GEMV without ever touching
        the host.  Numerics: FP32 softmax written back to the handle's
        FP16 host mirror (cross-checked by DecodeOffload numeric mode).
        """
        if not isinstance(a, DeviceTensor):
            raise TypeError(
                "softmax operates in place on a DeviceTensor handle "
                "(keep_output=True score GEMM result); got "
                f"{type(a).__name__}")
        m, c = a.shape
        assert not execute or a.values is not None, \
            "analytic (shape-only) DeviceTensor requires execute=False"
        if self.faults is not None:
            stack, channels = self.faults.on_op(stack, channels)
        shards = self._shards(placement, m, c, 1, stack, channels)

        op_devs = self._op_devices(stack, channels)
        marks = {d.channel_id: len(d.events) for d in op_devs}
        before = {d.channel_id: d.snapshot() for d in op_devs}
        link_before = self._link_before()
        lead_in: Dict[int, int] = {}
        shipped: Dict[int, Set] = {}
        link_seen: Optional[Dict] = {} if self._cluster else None
        for s in shards:
            flat = self._flat(s)
            dev = self.stack[flat]
            a_ships = self._ship_in(dev, a, s.a_box, shipped, "A",
                                    link_seen)
            if flat not in lead_in:
                i0, i1, c0, c1 = next(ew_tiles(s.rows, s.ks))
                lead_in[flat] = transfer_cycles(
                    (i1 - i0) * (c1 - c0) * int(a_ships) * BYTES_PER_ELEM)
            for _ in range(2):
                agg = cost_mod.ew_shard_cost("mul", s.rows, s.ks)
                dev.charge_analytic(agg.cycles, agg.flops, agg.commands)
                dev.events.append(("instr", ShardSpan("mul", s.rows, s.ks)))
            # in place: result stays resident on the same boxes, no d2h

        if execute:
            vals = a.resolve().astype(np.float32)
            e = np.exp(vals - vals.max(axis=0, keepdims=True))
            a.values[...] = (e / e.sum(axis=0, keepdims=True)).astype(F16)

        report = self._finish("softmax", (m, c), placement, before,
                              lead_in, link_before=link_before,
                              devices=op_devs)
        if self.metrics is not None:
            self._note_op(report)
        if self.faults is not None:
            self._fault_epilogue(report, None)
        if self.timeline is not None:
            return self._submit_async(
                "softmax",
                {cr.channel: cr.busy_cycles for cr in report.per_channel},
                self._link_cycles_async(report.host_link_cycles,
                                        link_before), marks,
                reads=(a.uid,), writes=(a.uid,),
                after=after, report=report, result=a)
        if self.profile is not None:
            self.profile.on_op(
                "softmax",
                {cr.channel: cr.busy_cycles for cr in report.per_channel},
                report.host_link_cycles, report=report)
        return a, report


# ---------------------------------------------------------------------------
# Convenience entry points (the end-to-end PIM-mode API)
# ---------------------------------------------------------------------------


def pim_gemm(a: jnp.ndarray, b: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True,
             engine: str = "batched", stacks: int = 1
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """C = A @ B entirely in PIM mode on a fresh ``channels``-wide stack
    (or ``stacks`` x ``channels`` cluster)."""
    return PIMRuntime(channels=channels, engine=engine, stacks=stacks).gemm(
        a, b, placement=placement, execute=execute)


def pim_gemv(a: jnp.ndarray, x: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True,
             engine: str = "batched", stacks: int = 1
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """y = A @ x entirely in PIM mode on a fresh ``channels``-wide stack
    (or ``stacks`` x ``channels`` cluster)."""
    return PIMRuntime(channels=channels, engine=engine, stacks=stacks).gemv(
        a, x, placement=placement, execute=execute)
