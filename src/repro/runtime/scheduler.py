"""Command-queue scheduler: dispatch one op across pseudo-channels.

The scheduler partitions a GEMM/GEMV/element-wise op according to a
placement policy (:mod:`repro.runtime.placement`), enqueues each shard's
command stream on its pseudo-channel's engine, and reports *makespan*
semantics: channels run asynchronously, so wall-clock time is the maximum
per-channel busy time, never the sum.

Per-channel busy time models transfer/compute overlap the way a
double-buffered host DMA behaves on real PIM parts (PrIM's lesson that
host<->PIM traffic dominates unless overlapped):

    busy = lead_in + max(compute, h2d - lead_in) + d2h

where ``lead_in`` is the transfer time of the channel's *first* operand
tile pair (nothing to overlap with yet), the remaining input traffic
streams behind compute, and results drain after the last PEP retires.

Shards that split K produce FP16 partial products; the scheduler ships
each partial back to the host (accounted as d2h traffic) and reduces them
in ascending-k order — the host-side reduction that balanced placement
trades for utilization.

Both execution modes charge *identical* ledgers (property-tested):

* ``execute=True``  — numerics run on each channel's :class:`AMEEngine`
  (order-exact FP16); output-space placements are bit-exact with a
  single-channel run.
* ``execute=False`` — analytic: only the cost model runs, for large-shape
  sweeps (the benchmark channel-scaling section).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cost as cost_mod
from repro.core.engine import (
    InstrRecord,
    ew_on_engine,
    ew_tiles,
    gemm_on_engine,
    gemm_tiles,
)
from repro.core.isa import PIM_FREQ_HZ
from repro.runtime.device import PIMDevice, PIMStack, transfer_cycles
from repro.runtime.placement import Shard, get_placement, validate_cover

F16 = np.float16
BYTES_PER_ELEM = 2  # FP16


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelReport:
    """One pseudo-channel's share of an op."""

    channel: int
    compute_cycles: float
    flops: int
    commands: int
    h2d_bytes: int
    d2h_bytes: int
    h2d_cycles: int
    d2h_cycles: int
    lead_in_cycles: int

    @property
    def busy_cycles(self) -> float:
        """Wall-clock busy time under the overlap model (module docstring)."""
        if self.compute_cycles == 0 and self.h2d_cycles == 0 \
                and self.d2h_cycles == 0:
            return 0.0
        stream = max(self.compute_cycles, self.h2d_cycles
                     - self.lead_in_cycles)
        return self.lead_in_cycles + stream + self.d2h_cycles

    def utilization(self, makespan: float) -> float:
        """Fraction of the op's wall-clock this channel spent computing."""
        return self.compute_cycles / makespan if makespan else 0.0


@dataclasses.dataclass(frozen=True)
class RuntimeReport:
    """Device-level account of one scheduled op."""

    op: str
    shape: Tuple[int, ...]
    placement: str
    channels: int                     # pseudo-channels in the stack
    per_channel: Tuple[ChannelReport, ...]

    @property
    def makespan_cycles(self) -> float:
        return max((c.busy_cycles for c in self.per_channel), default=0.0)

    @property
    def total_flops(self) -> int:
        return sum(c.flops for c in self.per_channel)

    @property
    def total_commands(self) -> int:
        return sum(c.commands for c in self.per_channel)

    @property
    def total_bytes(self) -> int:
        return sum(c.h2d_bytes + c.d2h_bytes for c in self.per_channel)

    @property
    def flop_per_cycle(self) -> float:
        """Effective throughput at makespan (the scaling headline)."""
        return self.total_flops / self.makespan_cycles

    @property
    def gflops(self) -> float:
        return self.flop_per_cycle * PIM_FREQ_HZ / 1e9

    @property
    def seconds(self) -> float:
        return self.makespan_cycles / PIM_FREQ_HZ

    def utilizations(self) -> List[float]:
        mk = self.makespan_cycles
        return [c.utilization(mk) for c in self.per_channel]

    def summary(self) -> str:
        us = self.utilizations()
        busy = [c for c in self.per_channel if c.busy_cycles > 0]
        return (f"{self.op} {'x'.join(map(str, self.shape))} "
                f"[{self.placement}, {self.channels}ch, {len(busy)} busy]: "
                f"makespan={self.makespan_cycles:.0f}cyc "
                f"{self.gflops:.1f}GFLOP/s "
                f"util(min/mean/max)={min(us):.2f}/"
                f"{sum(us) / len(us):.2f}/{max(us):.2f} "
                f"bytes={self.total_bytes}")


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


class PIMRuntime:
    """Schedules ops onto a :class:`PIMStack` and accounts them."""

    def __init__(self, channels: int = 1, stack: Optional[PIMStack] = None):
        self.stack = stack if stack is not None else PIMStack(channels)

    # -- internals -----------------------------------------------------------

    def _record_instrs(self, dev: PIMDevice, n_before: int) -> None:
        for rec in dev.engine.instrs[n_before:]:
            dev.events.append(("instr", rec))

    def _finish(self, op: str, shape: Tuple[int, ...], placement: str,
                before: Dict[int, "object"],
                lead_in: Dict[int, int]) -> RuntimeReport:
        reports = []
        for dev in self.stack:
            b = before[dev.channel_id]
            reports.append(ChannelReport(
                channel=dev.channel_id,
                compute_cycles=dev.compute_cycles - b.cycles,
                flops=dev.compute_flops - b.flops,
                commands=dev.compute_commands - b.commands,
                h2d_bytes=dev.xfer.h2d_bytes - b.h2d_bytes,
                d2h_bytes=dev.xfer.d2h_bytes - b.d2h_bytes,
                h2d_cycles=dev.xfer.h2d_cycles - b.h2d_cycles,
                d2h_cycles=dev.xfer.d2h_cycles - b.d2h_cycles,
                lead_in_cycles=lead_in.get(dev.channel_id, 0)))
        return RuntimeReport(op=op, shape=shape, placement=placement,
                             channels=len(self.stack),
                             per_channel=tuple(reports))

    # -- GEMM / GEMV ---------------------------------------------------------

    def gemm(self, a: jnp.ndarray, b: jnp.ndarray, *,
             placement: str = "row-striped",
             execute: bool = True
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
        """C = A(m,k) @ B(k,n) partitioned across the stack's channels."""
        m, k = a.shape
        k2, n = b.shape
        assert k == k2, (a.shape, b.shape)
        shards = get_placement(placement)(m, k, n, len(self.stack))
        validate_cover(shards, m, k, n)

        before = {d.channel_id: d.snapshot() for d in self.stack}
        lead_in: Dict[int, int] = {}
        out = np.zeros((m, n), F16) if execute else None
        partials: Dict[Tuple[int, int, int, int],
                       List[Tuple[int, np.ndarray]]] = {}

        for s in shards:
            dev = self.stack[s.channel]
            if s.channel not in lead_in:
                i0, i1, j0, j1, c0, c1 = next(gemm_tiles(s.rows, s.ks, s.ns))
                lead_in[s.channel] = transfer_cycles(
                    ((i1 - i0) * (c1 - c0) + (c1 - c0) * (j1 - j0))
                    * BYTES_PER_ELEM)
            dev.host_to_pim(s.rows * s.ks * BYTES_PER_ELEM)   # A shard
            dev.host_to_pim(s.ks * s.ns * BYTES_PER_ELEM)     # B shard
            if execute:
                n_before = len(dev.engine.instrs)
                sub = gemm_on_engine(dev.engine,
                                     a[s.m0:s.m1, s.k0:s.k1],
                                     b[s.k0:s.k1, s.n0:s.n1])
                self._record_instrs(dev, n_before)
                if s.is_partial(k):
                    partials.setdefault((s.m0, s.m1, s.n0, s.n1), []) \
                        .append((s.k0, sub))
                else:
                    out[s.m0:s.m1, s.n0:s.n1] = sub
            else:
                for i0, i1, j0, j1, c0, c1 in gemm_tiles(s.rows, s.ks, s.ns):
                    rep = cost_mod.mfmacc_cost(i1 - i0, c1 - c0, j1 - j0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr",
                         InstrRecord("mac", i1 - i0, c1 - c0, j1 - j0)))
            dev.pim_to_host(s.rows * s.ns * BYTES_PER_ELEM)   # C (or partial)

        if execute:
            # host-side reduction of K-split partials, ascending-k FP16
            for (m0, m1, n0, n1), parts in partials.items():
                acc: Optional[np.ndarray] = None
                for _, arr in sorted(parts, key=lambda t: t[0]):
                    acc = arr if acc is None else (acc + arr).astype(F16)
                out[m0:m1, n0:n1] = acc

        report = self._finish("gemm", (m, k, n), placement, before, lead_in)
        return (jnp.asarray(out) if execute else None), report

    def gemv(self, a: jnp.ndarray, x: jnp.ndarray, *,
             placement: str = "row-striped",
             execute: bool = True
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
        """y = A @ x (the MPC-Wrapper comparison workload), as N=1 GEMM."""
        y, rep = self.gemm(a, x[:, None], placement=placement,
                           execute=execute)
        rep = dataclasses.replace(rep, op="gemv")
        return (y[:, 0] if y is not None else None), rep

    # -- element-wise --------------------------------------------------------

    def elementwise(self, kind: str, a: jnp.ndarray, b: jnp.ndarray, *,
                    placement: str = "row-striped",
                    execute: bool = True
                    ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
        """out = a <kind> b partitioned over the (M, C) output grid.

        Placements reuse the GEMM shard geometry with the column axis in
        the K slot and N=1; a K-split shard is just a column slab here, so
        every placement is an exact output partition (no reduction).
        """
        assert kind in ("add", "sub", "mul")
        assert a.shape == b.shape
        m, c = a.shape
        shards = get_placement(placement)(m, c, 1, len(self.stack))
        validate_cover(shards, m, c, 1)

        before = {d.channel_id: d.snapshot() for d in self.stack}
        lead_in: Dict[int, int] = {}
        out = np.zeros((m, c), F16) if execute else None

        for s in shards:
            dev = self.stack[s.channel]
            if s.channel not in lead_in:
                i0, i1, c0, c1 = next(ew_tiles(s.rows, s.ks))
                lead_in[s.channel] = transfer_cycles(
                    2 * (i1 - i0) * (c1 - c0) * BYTES_PER_ELEM)
            dev.host_to_pim(2 * s.rows * s.ks * BYTES_PER_ELEM)  # both operands
            if execute:
                n_before = len(dev.engine.instrs)
                sub = ew_on_engine(dev.engine, kind,
                                   a[s.m0:s.m1, s.k0:s.k1],
                                   b[s.m0:s.m1, s.k0:s.k1])
                self._record_instrs(dev, n_before)
                out[s.m0:s.m1, s.k0:s.k1] = sub
            else:
                for i0, i1, c0, c1 in ew_tiles(s.rows, s.ks):
                    rep = cost_mod.elementwise_cost(kind, i1 - i0, c1 - c0)
                    dev.charge_analytic(rep.cycles, rep.flops, rep.commands)
                    dev.events.append(
                        ("instr", InstrRecord(kind, i1 - i0, c1 - c0)))
            dev.pim_to_host(s.rows * s.ks * BYTES_PER_ELEM)

        report = self._finish(f"ew-{kind}", (m, c), placement, before,
                              lead_in)
        return (jnp.asarray(out) if execute else None), report


# ---------------------------------------------------------------------------
# Convenience entry points (the end-to-end PIM-mode API)
# ---------------------------------------------------------------------------


def pim_gemm(a: jnp.ndarray, b: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """C = A @ B entirely in PIM mode on a fresh ``channels``-wide stack."""
    return PIMRuntime(channels=channels).gemm(a, b, placement=placement,
                                              execute=execute)


def pim_gemv(a: jnp.ndarray, x: jnp.ndarray, channels: int = 1,
             placement: str = "row-striped", execute: bool = True
             ) -> Tuple[Optional[jnp.ndarray], RuntimeReport]:
    """y = A @ x entirely in PIM mode on a fresh ``channels``-wide stack."""
    return PIMRuntime(channels=channels).gemv(a, x, placement=placement,
                                              execute=execute)
