"""Device-level PIM runtime: the layer between per-channel engines and
workloads.

Layers (bottom-up): ISA -> PEP -> channel interpreter -> AMEEngine (one
pseudo-channel) -> **this runtime** (multi-pseudo-channel stack).  See
``docs/runtime.md``.

  device     — PIMStack / PIMDevice: 16 pseudo-channels, each an
               independent AMEEngine + host<->PIM transfer accounting
               + per-channel operand-residency tables (optionally
               capacity-bounded with LRU spill)
  cluster    — PIMCluster: N stacks behind one scheduler and one shared
               host link; inter-stack traffic charged at link bandwidth
  placement  — pluggable data-placement policies (row-striped, 2d-block,
               AMD-style balanced) + operand-footprint boxes + the
               leading stack axis of cluster decompositions
  residency  — DeviceTensor handles: operands/outputs resident per
               channel, zero h2d on reuse (PIMRuntime.place)
  scheduler  — PIMRuntime: partitions GEMM/GEMV/element-wise ops per the
               placement, dispatches per-channel command streams
               asynchronously (makespan = max over channels), overlaps
               transfers with PEP execution, reports RuntimeReport
  timeline   — async dependency-aware op timeline (async_mode=True):
               OpHandle futures, per-channel + per-link clocks, shard
               starts at max(dep retire, channel free, link free)
  trace      — HBM-PIMulator-compatible command-trace emitter + parser
               (resident reuses and async TSTART/TEND schedule markers
               round-trip as replay-neutral comments)
"""
from repro.runtime.cluster import (
    HOST_LINK_BANDWIDTH_BYTES_PER_S,
    HOST_LINK_BYTES_PER_CYCLE,
    HostLinkLedger,
    PIMCluster,
    host_link_cycles,
)
from repro.runtime.device import (
    CHANNEL_BANDWIDTH_BYTES_PER_S,
    PIMDevice,
    PIMStack,
    TRANSFER_BYTES_PER_COMMAND,
    transfer_cycles,
)
from repro.runtime.kvcache import KVCacheManager
from repro.runtime.placement import (
    PLACEMENTS,
    Shard,
    balanced,
    block_2d,
    box_contains,
    cluster_shards,
    get_placement,
    paged,
    placement_shards,
    row_striped,
    shard_mac_passes,
    stack_restricted_shards,
    subset_shards,
    validate_cover,
)
from repro.runtime.residency import (
    BYTES_PER_ELEM,
    KV_BLOCK_TOKENS,
    DeviceTensor,
    PagedTensor,
    box_bytes,
)
from repro.runtime.scheduler import (
    ENGINE_MODES,
    ChannelReport,
    PIMRuntime,
    RuntimeReport,
    pim_gemm,
    pim_gemv,
)
from repro.runtime.timeline import OpHandle, Timeline
from repro.runtime.trace import (
    TraceStats,
    dump_trace,
    emit_trace,
    parse_trace,
    strip_timestamps,
)

__all__ = [
    "HOST_LINK_BANDWIDTH_BYTES_PER_S", "HOST_LINK_BYTES_PER_CYCLE",
    "HostLinkLedger", "PIMCluster", "host_link_cycles",
    "CHANNEL_BANDWIDTH_BYTES_PER_S", "PIMDevice", "PIMStack",
    "TRANSFER_BYTES_PER_COMMAND", "transfer_cycles",
    "PLACEMENTS", "Shard", "balanced", "block_2d", "box_contains",
    "cluster_shards", "get_placement", "paged", "placement_shards",
    "row_striped", "shard_mac_passes", "stack_restricted_shards",
    "subset_shards", "validate_cover",
    "BYTES_PER_ELEM", "KV_BLOCK_TOKENS", "DeviceTensor", "PagedTensor",
    "box_bytes", "KVCacheManager",
    "ENGINE_MODES", "ChannelReport", "PIMRuntime", "RuntimeReport",
    "pim_gemm", "pim_gemv",
    "OpHandle", "Timeline",
    "TraceStats", "dump_trace", "emit_trace", "parse_trace",
    "strip_timestamps",
]
