"""Device-level PIM runtime: the layer between per-channel engines and
workloads.

Layers (bottom-up): ISA -> PEP -> channel interpreter -> AMEEngine (one
pseudo-channel) -> **this runtime** (multi-pseudo-channel stack).  See
``docs/runtime.md``.

  device     — PIMStack / PIMDevice: 16 pseudo-channels, each an
               independent AMEEngine + host<->PIM transfer accounting
               + per-channel operand-residency tables
  placement  — pluggable data-placement policies (row-striped, 2d-block,
               AMD-style balanced) + operand-footprint boxes
  residency  — DeviceTensor handles: operands/outputs resident per
               channel, zero h2d on reuse (PIMRuntime.place)
  scheduler  — PIMRuntime: partitions GEMM/GEMV/element-wise ops per the
               placement, dispatches per-channel command streams
               asynchronously (makespan = max over channels), overlaps
               transfers with PEP execution, reports RuntimeReport
  trace      — HBM-PIMulator-compatible command-trace emitter + parser
               (resident reuses round-trip as replay-neutral comments)
"""
from repro.runtime.device import (
    CHANNEL_BANDWIDTH_BYTES_PER_S,
    PIMDevice,
    PIMStack,
    TRANSFER_BYTES_PER_COMMAND,
    transfer_cycles,
)
from repro.runtime.placement import (
    PLACEMENTS,
    Shard,
    balanced,
    block_2d,
    box_contains,
    get_placement,
    placement_shards,
    row_striped,
    shard_mac_passes,
    validate_cover,
)
from repro.runtime.residency import BYTES_PER_ELEM, DeviceTensor, box_bytes
from repro.runtime.scheduler import (
    ENGINE_MODES,
    ChannelReport,
    PIMRuntime,
    RuntimeReport,
    pim_gemm,
    pim_gemv,
)
from repro.runtime.trace import TraceStats, dump_trace, emit_trace, parse_trace

__all__ = [
    "CHANNEL_BANDWIDTH_BYTES_PER_S", "PIMDevice", "PIMStack",
    "TRANSFER_BYTES_PER_COMMAND", "transfer_cycles",
    "PLACEMENTS", "Shard", "balanced", "block_2d", "box_contains",
    "get_placement", "placement_shards", "row_striped", "shard_mac_passes",
    "validate_cover",
    "BYTES_PER_ELEM", "DeviceTensor", "box_bytes",
    "ENGINE_MODES", "ChannelReport", "PIMRuntime", "RuntimeReport",
    "pim_gemm", "pim_gemv",
    "TraceStats", "dump_trace", "emit_trace", "parse_trace",
]
