"""Data-placement policies: partitioning one op across pseudo-channels.

A placement maps a GEMM/GEMV iteration space (M, K, N) onto pseudo-channels
as a list of :class:`Shard` — axis-aligned boxes that form a *disjoint exact
cover* of the M x K x N compute cuboid (property-tested).  Channel-level
placement, not kernel code, decides whether multi-channel PIM scales (AMD's
*Balanced Data Placement for GEMV Acceleration with PIM*, 2024) — hence
placements are pluggable and named:

* ``row-striped``  — contiguous runs of 128-row blocks per channel, full K
  and N.  Pure output partitioning: bit-exact with a single-channel run,
  but starves channels when M / 128 < channels (skinny GEMV).
* ``2d-block``     — channels factored into a near-square (pr x pc) grid
  over M x N, full K.  Also pure output partitioning; for GEMM
  512x4096x512 on 16 channels every channel gets exactly the paper's
  128x4096x128 max tile.
* ``balanced``     — AMD-style: equalize per-channel MAC passes.  With at
  least one row block per channel this is LPT (longest-processing-time)
  assignment of row blocks; with fewer blocks than channels it splits K
  (AAM-aligned) so every channel works, at the price of a host-side
  reduction of FP16 partials (accounted by the scheduler).

Shards with ``k0 > 0`` or ``k1 < K`` are *partial* products; the scheduler
reduces them on the host in ascending-k order.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, List, Tuple

from repro.core.engine import gemm_tiles
from repro.core.isa import AAM_BLOCKS, ROWNUM


@dataclasses.dataclass(frozen=True)
class Shard:
    """One channel's axis-aligned box of the (M, K, N) iteration space.

    ``stack`` is the leading placement axis of a multi-stack cluster
    (``channel`` is then local to that stack); bare single-stack
    decompositions keep the default ``stack=0`` with cluster-flat ==
    local channel ids, so every pre-cluster call site is unchanged.
    """

    channel: int
    m0: int
    m1: int
    k0: int
    k1: int
    n0: int
    n1: int
    stack: int = 0

    @property
    def rows(self) -> int:
        return self.m1 - self.m0

    @property
    def ks(self) -> int:
        return self.k1 - self.k0

    @property
    def ns(self) -> int:
        return self.n1 - self.n0

    @property
    def flops(self) -> int:
        return 2 * self.rows * self.ks * self.ns

    @property
    def volume(self) -> int:
        return self.rows * self.ks * self.ns

    def is_partial(self, k: int) -> bool:
        """True if this shard computes a partial product needing reduction."""
        return self.k0 > 0 or self.k1 < k

    # -- operand footprints (2D boxes in each operand's own coordinates) ----
    #
    # The residency layer (repro.runtime.residency) keys per-channel
    # resident regions by these boxes, so "is this shard's A slice already
    # on its channel?" is a containment check against the same geometry the
    # scheduler transfers.

    @property
    def a_box(self) -> Tuple[int, int, int, int]:
        """Footprint of this shard in the A operand: (m0, m1, k0, k1)."""
        return (self.m0, self.m1, self.k0, self.k1)

    @property
    def b_box(self) -> Tuple[int, int, int, int]:
        """Footprint of this shard in the B operand: (k0, k1, n0, n1)."""
        return (self.k0, self.k1, self.n0, self.n1)

    @property
    def out_box(self) -> Tuple[int, int, int, int]:
        """Footprint of this shard in the output: (m0, m1, n0, n1)."""
        return (self.m0, self.m1, self.n0, self.n1)


def box_contains(outer: Tuple[int, int, int, int],
                 inner: Tuple[int, int, int, int]) -> bool:
    """True if 2D box ``inner`` lies entirely inside ``outer``."""
    return (outer[0] <= inner[0] and inner[1] <= outer[1]
            and outer[2] <= inner[2] and inner[3] <= outer[3])


def shard_mac_passes(s: Shard) -> int:
    """Exact MAC-PEP loop passes the engine issues for this shard."""
    return sum(math.ceil((c1 - c0) / AAM_BLOCKS) * (j1 - j0)
               for _, _, j0, j1, c0, c1 in gemm_tiles(s.rows, s.ks, s.ns))


def validate_cover(shards: List[Shard], m: int, k: int, n: int) -> None:
    """Assert the shards are a disjoint exact cover of M x K x N."""
    vol = 0
    for s in shards:
        assert 0 <= s.m0 < s.m1 <= m and 0 <= s.k0 < s.k1 <= k \
            and 0 <= s.n0 < s.n1 <= n, f"shard out of bounds: {s}"
        vol += s.volume
    assert vol == m * k * n, f"cover volume {vol} != {m * k * n}"
    for i, a in enumerate(shards):         # disjointness: no box overlap
        for b in shards[i + 1:]:
            if (a.m0 < b.m1 and b.m0 < a.m1 and a.k0 < b.k1
                    and b.k0 < a.k1 and a.n0 < b.n1 and b.n0 < a.n1):
                raise AssertionError(f"overlapping shards: {a} / {b}")


def _row_blocks(m: int) -> List[range]:
    return [range(i0, min(i0 + ROWNUM, m)) for i0 in range(0, m, ROWNUM)]


def _chunks(total: int, parts: int) -> List[int]:
    """Split ``total`` into ``parts`` near-equal non-negative integers."""
    q, r = divmod(total, parts)
    return [q + (1 if i < r else 0) for i in range(parts)]


def row_striped(m: int, k: int, n: int, channels: int) -> List[Shard]:
    """Contiguous runs of 128-row blocks per channel; full K, full N."""
    blocks = _row_blocks(m)
    sizes = _chunks(len(blocks), min(channels, len(blocks)))
    shards, b = [], 0
    for ch, nb in enumerate(sizes):
        if nb == 0:
            continue
        m0 = blocks[b].start
        m1 = blocks[b + nb - 1].stop
        shards.append(Shard(ch, m0, m1, 0, k, 0, n))
        b += nb
    return shards


def block_2d(m: int, k: int, n: int, channels: int) -> List[Shard]:
    """Near-square (pr x pc) channel grid over M x N; full K per shard."""
    blocks = _row_blocks(m)
    pr = max(1, min(int(math.sqrt(channels)), len(blocks)))
    while channels % pr:
        pr -= 1
    pc = min(channels // pr, n)
    row_sizes = _chunks(len(blocks), pr)
    col_sizes = _chunks(n, pc)
    shards, ch, b = [], 0, 0
    for rsz in row_sizes:
        if rsz == 0:
            continue
        m0, m1 = blocks[b].start, blocks[b + rsz - 1].stop
        b += rsz
        n0 = 0
        for csz in col_sizes:
            if csz == 0:
                continue
            shards.append(Shard(ch, m0, m1, 0, k, n0, n0 + csz))
            ch += 1
            n0 += csz
    return shards


def balanced(m: int, k: int, n: int, channels: int) -> List[Shard]:
    """Equalize per-channel MAC passes (AMD balanced placement).

    With >= 1 row block per channel: LPT assignment of row blocks to the
    least-loaded channel (ties broken by channel id), which also handles
    ragged last blocks.  With fewer blocks than channels: split each
    block's K range across its share of channels, AAM-aligned, so every
    channel contributes — the scheduler reduces the FP16 partials.
    """
    blocks = _row_blocks(m)
    if len(blocks) >= channels:
        load = [0] * channels
        shards: List[Shard] = []
        order = sorted(blocks, key=lambda blk: -Shard(
            0, blk.start, blk.stop, 0, k, 0, n).volume)
        for blk in order:
            ch = min(range(channels), key=lambda c: (load[c], c))
            s = Shard(ch, blk.start, blk.stop, 0, k, 0, n)
            load[ch] += shard_mac_passes(s)
            shards.append(s)
        return sorted(shards, key=lambda s: (s.channel, s.m0))

    # fewer row blocks than channels: split K, AAM_BLOCKS-aligned
    shares = _chunks(channels, len(blocks))
    kgroups = math.ceil(k / AAM_BLOCKS)
    shards, ch = [], 0
    for blk, share in zip(blocks, shares):
        share = max(1, min(share, kgroups))
        g0 = 0
        for gsz in _chunks(kgroups, share):
            if gsz == 0:
                continue
            k0 = g0 * AAM_BLOCKS
            k1 = min((g0 + gsz) * AAM_BLOCKS, k)
            shards.append(Shard(ch, blk.start, blk.stop, k0, k1, 0, n))
            ch += 1
            g0 += gsz
    return shards


def paged(m: int, k: int, n: int, channels: int) -> List[Shard]:
    """Block-cyclic placement for *growing* operands (the KV cache).

    ``row-striped``/``balanced`` re-balance the whole operand whenever M
    (or K) grows past a block boundary, so the block->channel assignment
    of the *prefix* moves and every decode step re-ships context that is
    already resident.  ``paged`` fixes each 128-sized block to a channel
    by index — growth appends new blocks without touching old ones, so
    resident prefix boxes hit forever:

    * M > ROWNUM: one shard per 128-row block, ``channel = block % C``,
      full K and N (a K cache ``(ctx, head_dim)`` growing along rows).
    * M <= ROWNUM: 128-column K groups (AAM-aligned; 128 % AAM_BLOCKS
      == 0), ``channel = group % C`` (a transposed V cache
      ``(head_dim, ctx)`` growing along columns); the K-split partials
      are host-reduced by the scheduler like ``balanced``'s.

    The two cases compose: the score GEMV's output row block *b* and the
    context GEMV's K group *b* land on the same channel, so a kept score
    output is consumed in place by the context op with zero traffic.
    """
    blocks = _row_blocks(m)
    if len(blocks) > 1:
        return [Shard(i % channels, blk.start, blk.stop, 0, k, 0, n)
                for i, blk in enumerate(blocks)]
    kgroups = [range(k0, min(k0 + ROWNUM, k)) for k0 in range(0, k, ROWNUM)]
    return [Shard(g % channels, 0, m, grp.start, grp.stop, 0, n)
            for g, grp in enumerate(kgroups)]


PLACEMENTS: Dict[str, Callable[[int, int, int, int], List[Shard]]] = {
    "row-striped": row_striped,
    "2d-block": block_2d,
    "balanced": balanced,
    "paged": paged,
}


def get_placement(name: str) -> Callable[[int, int, int, int], List[Shard]]:
    try:
        return PLACEMENTS[name]
    except KeyError:
        raise KeyError(f"unknown placement {name!r}; "
                       f"available: {sorted(PLACEMENTS)}") from None


def placement_shards(policy: str, m: int, k: int, n: int,
                     channels: int) -> Tuple[Shard, ...]:
    """Memoized, cover-validated shard decomposition.

    Placement functions are pure in ``(policy, m, k, n, channels)``, and
    the serve loop's decode path recomputes the identical decomposition
    every step — so the scheduler resolves shards through this cache.
    Returns an immutable tuple (callers must not mutate shard lists), with
    :func:`validate_cover` run once per distinct key instead of per op.

    ``paged`` operands *grow*: a KV cache whose M (or K) dimension changes
    every decode step would mint a fresh cache entry per step and a
    32k-token decode would pin thousands of dead decompositions.  Paged
    decompositions therefore bypass memoization entirely (they are cheap
    — one shard per block, constructively disjoint, so no O(shards^2)
    cover validation either) and the lru_cache only ever holds
    fixed-shape keys.
    """
    if policy == "paged":
        return tuple(paged(m, k, n, channels))
    return _placement_shards_cached(policy, m, k, n, channels)


@functools.lru_cache(maxsize=4096)
def _placement_shards_cached(policy: str, m: int, k: int, n: int,
                             channels: int) -> Tuple[Shard, ...]:
    shards = tuple(get_placement(policy)(m, k, n, channels))
    validate_cover(list(shards), m, k, n)
    return shards


def cluster_shards(policy: str, m: int, k: int, n: int, stacks: int,
                   channels_per_stack: int) -> Tuple[Shard, ...]:
    """Memoized ``(stack, channel)`` decomposition across a cluster.

    The placement policy runs over the *flat* channel space
    (``stacks * channels_per_stack`` — so a reshape of the same total
    channel count produces the identical shard geometry, hence makespan
    parity), then each flat channel id splits into the leading stack
    axis: contiguous channel runs map to contiguous stacks.  Which boxes
    land with channels of *different* stacks is exactly what the
    scheduler's host-link ledger charges.

    Like :func:`placement_shards`, ``paged`` keys (growing KV shapes)
    bypass the lru_cache.
    """
    if policy == "paged":
        return _cluster_shards_impl(policy, m, k, n, stacks,
                                    channels_per_stack)
    return _cluster_shards_cached(policy, m, k, n, stacks,
                                  channels_per_stack)


def _cluster_shards_impl(policy: str, m: int, k: int, n: int, stacks: int,
                         channels_per_stack: int) -> Tuple[Shard, ...]:
    flat = placement_shards(policy, m, k, n, stacks * channels_per_stack)
    return tuple(dataclasses.replace(
        s, stack=s.channel // channels_per_stack,
        channel=s.channel % channels_per_stack) for s in flat)


_cluster_shards_cached = functools.lru_cache(maxsize=4096)(
    _cluster_shards_impl)


def stack_restricted_shards(policy: str, m: int, k: int, n: int,
                            stack: int,
                            channels_per_stack: int) -> Tuple[Shard, ...]:
    """Memoized decomposition of one op onto a *single* stack of a
    cluster (the decode-offload regime: each layer's weights live on
    their home stack, re-decomposed every step).  Channel ids are local
    to ``stack``.  ``paged`` keys bypass the lru_cache."""
    if policy == "paged":
        return _stack_restricted_impl(policy, m, k, n, stack,
                                      channels_per_stack)
    return _stack_restricted_cached(policy, m, k, n, stack,
                                    channels_per_stack)


def _stack_restricted_impl(policy: str, m: int, k: int, n: int, stack: int,
                           channels_per_stack: int) -> Tuple[Shard, ...]:
    flat = placement_shards(policy, m, k, n, channels_per_stack)
    return tuple(dataclasses.replace(s, stack=stack) for s in flat)


_stack_restricted_cached = functools.lru_cache(maxsize=4096)(
    _stack_restricted_impl)


def subset_shards(policy: str, m: int, k: int, n: int,
                  flat_channels: Tuple[int, ...],
                  channels_per_stack: int) -> Tuple[Shard, ...]:
    """Memoized decomposition of one op onto an explicit *subset* of a
    stack's (or cluster's) flat channel ids.

    The async scheduler runs independent ops of one dependency level on
    disjoint channel groups — q/k/v of a decode layer concurrently on
    their home stack's channels — so the placement policy runs over
    ``len(flat_channels)`` virtual channels and each virtual id maps to
    its flat id (then splits into ``(stack, channel)``).  The same
    subset used for ``place`` and the consuming ops yields identical
    shard geometry, so residency hits exactly as on full-width ops.

    ``paged`` keys (growing KV shapes) bypass the lru_cache.
    """
    if policy == "paged":
        return _subset_shards_impl(policy, m, k, n, flat_channels,
                                   channels_per_stack)
    return _subset_shards_cached(policy, m, k, n, flat_channels,
                                 channels_per_stack)


def _subset_shards_impl(policy: str, m: int, k: int, n: int,
                        flat_channels: Tuple[int, ...],
                        channels_per_stack: int) -> Tuple[Shard, ...]:
    if len(set(flat_channels)) != len(flat_channels):
        raise ValueError(f"duplicate channel ids in subset {flat_channels}")
    flat = placement_shards(policy, m, k, n, len(flat_channels))
    out = []
    for s in flat:
        f = flat_channels[s.channel]
        out.append(dataclasses.replace(
            s, stack=f // channels_per_stack, channel=f % channels_per_stack))
    return tuple(out)


_subset_shards_cached = functools.lru_cache(maxsize=4096)(
    _subset_shards_impl)
