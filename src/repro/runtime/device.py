"""Device-level model of one Aquabolt-XL HBM-PIM stack.

A stack exposes :data:`~repro.core.isa.PSEUDO_CHANNELS` = 16 pseudo-channels
(4 dies x 4), each with its own 8 PIM units executing an independent command
stream.  The paper evaluates a single pseudo-channel and names multi-channel
scaling as future work; this module is that missing layer:

* :class:`PIMDevice` — one pseudo-channel: an :class:`~repro.core.engine.
  AMEEngine` (compute ledger) plus a host<->PIM transfer ledger.  Transfers
  are charged at the pseudo-channel command rate: one 32-byte bus transaction
  per column command (the same bus the HBM-PIMulator trace format addresses
  with its 5-bit column field), i.e. ``ceil(bytes / 32)`` cycles at the
  250 MHz bus clock.
* :class:`PIMStack` — the 16-channel device: indexing, reset, and aggregate
  accounting.  The *makespan* semantics (total time = max over channels, not
  sum) live in :mod:`repro.runtime.scheduler`, which owns dispatch order.

Channels do not share PIM-visible state: all cross-channel data movement goes
through the host and is accounted as transfers.  Multiple stacks behind one
host link are :class:`repro.runtime.cluster.PIMCluster`; a stack constructed
with ``stack_id=s`` numbers its devices with *cluster-flat* channel ids
(``s * channels + local``) so ledgers, reports, and traces stay unambiguous
across the cluster.

Residency capacity: ``capacity_bytes`` bounds the per-channel residency
table (default ``None`` = unbounded, today's behavior).  Adding a resident
region past the bound evicts least-recently-used *tensors* first; evicted
bytes are counted as ``spill_bytes`` (the re-ship exposure) and the actual
re-transfer is charged naturally when the evicted operand next misses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.core.engine import AMEEngine
from repro.core.isa import PIM_FREQ_HZ, PSEUDO_CHANNELS
from repro.runtime.placement import box_contains

#: bytes moved per column command on one pseudo-channel bus (32-byte
#: transaction granularity — one GRF entry / half a DRAM burst)
TRANSFER_BYTES_PER_COMMAND = 32

#: FP16 operand element size — all runtime transfers/residency are FP16
BYTES_PER_ELEM = 2


def box_bytes(box: Tuple[int, int, int, int]) -> int:
    """FP16 bytes of one (r0, r1, c0, c1) operand box."""
    return (box[1] - box[0]) * (box[3] - box[2]) * BYTES_PER_ELEM

#: per-pseudo-channel host<->PIM bandwidth implied by the command model
CHANNEL_BANDWIDTH_BYTES_PER_S = TRANSFER_BYTES_PER_COMMAND * PIM_FREQ_HZ


def transfer_cycles(nbytes: int) -> int:
    """Bus cycles to move ``nbytes`` over one pseudo-channel."""
    return math.ceil(nbytes / TRANSFER_BYTES_PER_COMMAND)


@dataclasses.dataclass
class TransferLedger:
    """Host<->PIM traffic of one pseudo-channel."""

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    h2d_cycles: int = 0
    d2h_cycles: int = 0

    @property
    def total_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes

    @property
    def total_cycles(self) -> int:
        return self.h2d_cycles + self.d2h_cycles


@dataclasses.dataclass
class DeviceSnapshot:
    """Ledger totals of one device at a point in time (for per-op deltas)."""

    cycles: float
    flops: int
    commands: int
    h2d_bytes: int
    d2h_bytes: int
    h2d_cycles: int
    d2h_cycles: int
    reuse_bytes: int = 0
    dedupe_bytes: int = 0
    spill_bytes: int = 0


class PIMDevice:
    """One pseudo-channel: leaf engine + transfer ledger + event stream.

    ``events`` records the device-visible history in dispatch order —
    ``("h2d"|"d2h", nbytes)`` transfer markers and ``("instr", InstrRecord)``
    entries appended by the scheduler after each shard executes — and is
    what :mod:`repro.runtime.trace` serializes to a command trace.

    Analytic (cost-only) scheduling charges ``analytic_*`` counters instead
    of running the engine; :attr:`compute_cycles` etc. always report the sum
    of both paths so mixed use stays consistent.
    """

    def __init__(self, channel_id: int,
                 capacity_bytes: Optional[int] = None):
        self.channel_id = channel_id
        self.capacity_bytes = capacity_bytes
        # fail-stop flag set by repro.faults.FaultInjector; a failed
        # channel is excluded from new placement decompositions and its
        # residency table has been wiped (shards lost)
        self.failed = False
        self.engine = AMEEngine()
        self.xfer = TransferLedger()
        self.events: List[Tuple[str, object]] = []
        self.analytic_cycles = 0.0
        self.analytic_flops = 0
        self.analytic_commands = 0
        # operand residency: tensor uid -> resident 2D boxes (r0, r1, c0, c1)
        # in that tensor's own coordinates.  Owned by the scheduler /
        # repro.runtime.residency; the device just stores and queries.
        # Dict insertion order doubles as the LRU order (oldest first);
        # _touch moves a uid to the back on every hit.
        self.resident: Dict[int, List[Tuple[int, int, int, int]]] = {}
        # uids that must not be evicted: kept outputs whose d2h drain is
        # still pending — on hardware, spilling them would lose the only
        # copy of the result.  Unpinned when the handle drains/evicts.
        self.pinned: Set[int] = set()
        self.reuse_bytes = 0    # h2d avoided by cross-op operand residency
        self.dedupe_bytes = 0   # h2d avoided by within-op slice dedupe
        self.spill_bytes = 0    # resident bytes evicted under capacity
        # async-timeline channel clock (repro.runtime.timeline): the
        # cycle this channel next comes free.  Only an async_mode
        # runtime advances it; the serialized mode leaves it at 0.
        self.tl_free = 0.0

    # -- compute ledger ------------------------------------------------------

    @property
    def compute_cycles(self) -> float:
        return self.engine.total_cycles + self.analytic_cycles

    @property
    def compute_flops(self) -> int:
        return self.engine.total_flops + self.analytic_flops

    @property
    def compute_commands(self) -> int:
        return self.engine.total_commands + self.analytic_commands

    def charge_analytic(self, cycles: float, flops: int,
                        commands: int) -> None:
        self.analytic_cycles += cycles
        self.analytic_flops += flops
        self.analytic_commands += commands

    # -- transfers -----------------------------------------------------------

    def host_to_pim(self, nbytes: int) -> int:
        """Account a host->PIM transfer; returns its bus cycles."""
        cyc = transfer_cycles(nbytes)
        self.xfer.h2d_bytes += nbytes
        self.xfer.h2d_cycles += cyc
        self.events.append(("h2d", nbytes))
        return cyc

    def pim_to_host(self, nbytes: int) -> int:
        """Account a PIM->host transfer; returns its bus cycles."""
        cyc = transfer_cycles(nbytes)
        self.xfer.d2h_bytes += nbytes
        self.xfer.d2h_cycles += cyc
        self.events.append(("d2h", nbytes))
        return cyc

    def note_reuse(self, nbytes: int) -> None:
        """Account a resident-operand reuse: zero bus traffic, event only.

        ``nbytes`` is the h2d transfer *avoided* — what the fresh-transfer
        path would have shipped for the same shard.
        """
        self.reuse_bytes += nbytes
        self.events.append(("reuse", nbytes))

    def note_dedupe(self, nbytes: int) -> None:
        """Account a within-op repeated-slice dedupe (e.g. the GEMV x
        vector across same-channel K-split shards): zero bus traffic.

        Kept separate from :meth:`note_reuse` so residency invariants
        ("reuse == weight bytes") stay exact on both the fresh and the
        resident path; the trace marker is the same ``reuse`` event.
        """
        self.dedupe_bytes += nbytes
        self.events.append(("reuse", nbytes))

    # -- residency table -----------------------------------------------------

    def _touch(self, uid: int) -> None:
        """Move ``uid`` to the most-recently-used end of the LRU order."""
        boxes = self.resident.pop(uid)
        self.resident[uid] = boxes

    def add_resident(self, uid: int,
                     box: Tuple[int, int, int, int],
                     pin: bool = False) -> bool:
        """Record that ``box`` of tensor ``uid`` now lives on this channel.

        Under a ``capacity_bytes`` bound, least-recently-used *other*
        unpinned tensors are evicted first (their bytes counted as spill
        and marked in the event stream); a box that cannot fit even alone
        — or cannot fit without evicting pinned (undrained-output) data —
        is not recorded at all (streamed through, re-shipped next use).
        ``pin=True`` additionally pins ``uid`` (kept outputs awaiting
        their deferred d2h).  Returns whether the box is now resident.

        A box that *contains* already-resident boxes of the same tensor
        supersedes them (they are absorbed rather than double-counted) —
        the growing-trailing-page case of a :class:`~repro.runtime.
        residency.PagedTensor`, where each re-mark extends the previous
        page box by the newly appended tokens.
        """
        boxes = self.resident.get(uid)
        if boxes:
            kept_boxes = [b for b in boxes if not box_contains(box, b)]
            if len(kept_boxes) != len(boxes):
                self.resident[uid] = kept_boxes
        nbytes = box_bytes(box)
        cap = self.capacity_bytes
        if cap is not None:
            if nbytes > cap:
                return False
            need = self.resident_bytes + nbytes - cap
            # refuse before evicting anything if eviction cannot free
            # enough (pinned data never counts) — a doomed insert must
            # not cost other tensors their residency
            if need > 0:
                evictable = sum(self.resident_bytes_of(u)
                                for u in self.resident
                                if u not in self.pinned)
                if evictable < need:
                    return False
            while self.resident_bytes + nbytes > cap:
                # oldest other unpinned tensor first; the incoming uid's
                # own older boxes only as a last resort; never pinned data
                victim = next((u for u in self.resident
                               if u != uid and u not in self.pinned), uid)
                self._spill(victim)
        self.resident.setdefault(uid, []).append(box)
        if pin:
            self.pinned.add(uid)
        self._touch(uid)
        return True

    def unpin(self, uid: int) -> None:
        """Make ``uid`` evictable again (its pending outputs drained)."""
        self.pinned.discard(uid)

    def _spill(self, uid: int) -> None:
        """Evict tensor ``uid``: count its bytes as spill (the re-ship the
        next miss will charge) and mark the trace."""
        nbytes = self.resident_bytes_of(uid)
        self.resident.pop(uid, None)
        self.spill_bytes += nbytes
        self.events.append(("spill", nbytes))

    def has_resident(self, uid: int,
                     box: Tuple[int, int, int, int]) -> bool:
        """True if ``box`` is contained in a resident region of ``uid``."""
        hit = any(box_contains(b, box)
                  for b in self.resident.get(uid, ()))
        if hit:
            self._touch(uid)
        return hit

    def drop_resident(self, uid: int) -> None:
        """Forget all of tensor ``uid``'s regions (eviction, no traffic)."""
        self.resident.pop(uid, None)
        self.pinned.discard(uid)

    def drop_resident_box(self, uid: int,
                          box: Tuple[int, int, int, int]) -> int:
        """Forget the resident regions of ``uid`` contained in ``box``
        (paged KV eviction: one page, not the whole tensor).  Returns the
        bytes dropped; no spill/traffic accounting — the KV manager
        charges its own eviction markers and the eventual re-ship.
        """
        boxes = self.resident.get(uid)
        if not boxes:
            return 0
        kept = [b for b in boxes if not box_contains(box, b)]
        dropped = (sum(box_bytes(b) for b in boxes)
                   - sum(box_bytes(b) for b in kept))
        if kept:
            self.resident[uid] = kept
        else:
            self.resident.pop(uid)
        return dropped

    def resident_bytes_of(self, uid: int) -> int:
        """Bytes of tensor ``uid`` resident on this channel."""
        return sum(box_bytes(b) for b in self.resident.get(uid, ()))

    @property
    def resident_bytes(self) -> int:
        """Bytes of operand data currently resident on this channel."""
        return sum(box_bytes(b) for boxes in self.resident.values()
                   for b in boxes)

    # -- snapshots (per-op deltas for RuntimeReport) -------------------------

    def snapshot(self) -> DeviceSnapshot:
        return DeviceSnapshot(
            cycles=self.compute_cycles, flops=self.compute_flops,
            commands=self.compute_commands,
            h2d_bytes=self.xfer.h2d_bytes, d2h_bytes=self.xfer.d2h_bytes,
            h2d_cycles=self.xfer.h2d_cycles, d2h_cycles=self.xfer.d2h_cycles,
            reuse_bytes=self.reuse_bytes, dedupe_bytes=self.dedupe_bytes,
            spill_bytes=self.spill_bytes)


class PIMStack:
    """An HBM-PIM stack: up to 16 independent pseudo-channels.

    ``stack_id`` places the stack inside a :class:`~repro.runtime.cluster.
    PIMCluster`: devices are numbered with cluster-flat channel ids
    (``stack_id * channels + local``) while ``__getitem__`` stays local
    (0-based within the stack).  A bare stack (``stack_id=0``) numbers
    devices 0..channels-1 exactly as before.
    """

    def __init__(self, channels: int = PSEUDO_CHANNELS, stack_id: int = 0,
                 capacity_bytes: Optional[int] = None):
        assert 1 <= channels <= PSEUDO_CHANNELS, \
            f"a stack has at most {PSEUDO_CHANNELS} pseudo-channels"
        self.stack_id = stack_id
        self.capacity_bytes = capacity_bytes
        self.devices = [PIMDevice(stack_id * channels + i, capacity_bytes)
                        for i in range(channels)]

    def __len__(self) -> int:
        return len(self.devices)

    def __getitem__(self, ch: int) -> PIMDevice:
        return self.devices[ch]

    def __iter__(self) -> Iterator[PIMDevice]:
        return iter(self.devices)

    # -- aggregates ----------------------------------------------------------

    @property
    def total_flops(self) -> int:
        return sum(d.compute_flops for d in self.devices)

    @property
    def total_bytes(self) -> int:
        return sum(d.xfer.total_bytes for d in self.devices)

    @property
    def resident_bytes(self) -> int:
        return sum(d.resident_bytes for d in self.devices)

    @property
    def busy_cycles(self) -> float:
        """Sum of per-channel busy time (NOT wall-clock; see scheduler)."""
        return sum(d.compute_cycles + d.xfer.total_cycles
                   for d in self.devices)

    @property
    def spill_bytes(self) -> int:
        return sum(d.spill_bytes for d in self.devices)

    def reset(self) -> None:
        self.__init__(len(self.devices), self.stack_id, self.capacity_bytes)
