"""Multi-stack PIM cluster: N HBM-PIM stacks behind one host link.

The paper evaluates one pseudo-channel; :class:`~repro.runtime.device.
PIMStack` scaled that to 16.  The next seam up — the ROADMAP's
"multi-stack sharding" item — is several stacks behind one
:class:`~repro.runtime.scheduler.PIMRuntime`, and what changes there is
not compute but *data movement*: AMD's balanced-placement study and the
PrIM benchmarking work both show cross-device traffic and placement, not
per-unit throughput, decide whether multi-device PIM scales.

:class:`PIMCluster` therefore adds exactly one piece of hardware to the
model: the **shared host link** every stack's DRAM traffic converges on
(the CPU-side interconnect — PCIe-class, nothing like per-stack HBM
bandwidth).  Addressing grows a leading stack axis — ``(stack, channel)``
— with a *flat* view (``cluster[stack * C + channel]``) so the scheduler
and residency layers index devices uniformly; devices carry their flat id
(:class:`PIMStack` with ``stack_id``), so ledgers and traces stay
unambiguous.

The host-link ledger charges only traffic that exists *because* data
crosses stack boundaries — a single-stack cluster is byte-identical
(ledgers and traces) to a bare stack:

* **cross-stack operand movement** — an operand box shipped h2d to
  channels of more than one stack within one op (or one ``place``):
  every copy beyond the first stack's crosses the link;
* **K-split partial drains** — a reduction group whose partials come
  from more than one stack must converge at the host over the link;
  every partial from a non-home stack (home = the stack of the group's
  first-dispatched shard) charges its d2h bytes on the link.

Link time is charged at :data:`HOST_LINK_BYTES_PER_CYCLE` (32 GB/s at
the 250 MHz PIM clock — PCIe-gen4-x16-class) and reported separately
from per-channel busy time: the channel makespan keeps its meaning
(fixed-total-channel reshapes stay makespan-parity), and
``RuntimeReport.cluster_makespan_cycles`` folds the link in as a second
serialization axis.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, List, Optional, Tuple

from repro.core.isa import PIM_FREQ_HZ, PSEUDO_CHANNELS
from repro.runtime.device import PIMDevice, PIMStack

#: host-link bytes per PIM cycle: 32 GB/s shared link at 250 MHz —
#: PCIe-gen4-x16-class, 4x one pseudo-channel's 32 B/cycle command bus
HOST_LINK_BYTES_PER_CYCLE = 128

#: the link bandwidth that implies
HOST_LINK_BANDWIDTH_BYTES_PER_S = HOST_LINK_BYTES_PER_CYCLE * PIM_FREQ_HZ


def host_link_cycles(nbytes: int) -> int:
    """PIM-clock cycles ``nbytes`` occupies the shared host link."""
    return math.ceil(nbytes / HOST_LINK_BYTES_PER_CYCLE)


@dataclasses.dataclass
class HostLinkLedger:
    """Inter-stack traffic over the cluster's shared host link.

    ``events`` keeps (kind, nbytes) in charge order — ``"xstack"`` for
    cross-stack operand movement, ``"drain"`` for cross-stack K-split
    partial gathers — and is what the trace emitter serializes as
    ``# HOSTLINK`` marker lines.  Fault injection (:mod:`repro.faults`)
    adds three recovery/perturbation kinds: ``"reupload"`` (lost
    resident shards re-shipped / failover weight migration),
    ``"retry"`` (transient-corruption retransmits incl. backoff pause),
    and ``"degrade"`` (bandwidth-degradation windows; the count slot
    carries the *extra cycles*, since no new bytes move).  The serving
    simulator (:class:`repro.serve.loop.TrafficServer`) adds two
    phase-contention kinds: ``"prefill"`` (host-prefilled KV handed off
    to PIM-resident pages) and ``"acts"`` (per-decode-step activation
    shipping) — the traffic disaggregation studies charge both as busy
    windows on this same link so prefill and decode contend.
    """

    #: event kinds `charge` accepts (degrade goes through charge_raw
    #: only — its cycle cost is not a function of nbytes)
    KINDS = ("xstack", "drain", "retry", "reupload", "prefill", "acts")

    bytes: int = 0
    cycles: int = 0
    events: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    # async-timeline link clock (repro.runtime.timeline): the cycle the
    # shared link next comes free.  Only an async_mode runtime advances
    # it; serialized mode keeps link time on its own axis instead
    # (RuntimeReport.cluster_makespan_cycles).
    tl_free: float = 0.0
    # repro.obs metrics registry (attached via PIMRuntime(metrics=));
    # excluded from ==/repr so instrumented ledgers stay equal to bare
    # ones — the profiling-off byte-identity invariant
    metrics: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)
    # repro.faults.FaultInjector (attached via PIMRuntime(faults=));
    # excluded from == for the same reason — an injector with an empty
    # plan must leave the ledger ==-equal to a bare one
    faults: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)
    # metric-name prefix: the shared link keeps "link"; a switched
    # cluster labels its per-stack ledgers "link<s>".  Excluded from ==
    # so labeled ledgers compare by traffic, not by name.
    label: str = dataclasses.field(
        default="link", compare=False, repr=False)

    def charge_raw(self, kind: str, nbytes: int, cyc: int) -> int:
        """Record one link event at an explicit cycle cost — the base
        accounting step :meth:`charge` and the fault injector's
        retry/degrade perturbations share (never re-enters the fault
        hook, so injected events cannot recurse)."""
        self.bytes += nbytes
        self.cycles += cyc
        self.events.append((kind, nbytes))
        if self.metrics is not None:
            self.metrics.counter(
                f"{self.label}.{kind}_bytes", unit="bytes",
                help=f"host-link bytes charged as {kind!r}").inc(nbytes)
            self.metrics.counter(
                f"{self.label}.cycles", unit="cycles",
                help="host-link occupancy charged").inc(cyc)
        return cyc

    def charge(self, kind: str, nbytes: int) -> int:
        assert kind in self.KINDS, kind
        cyc = self.charge_raw(kind, nbytes, host_link_cycles(nbytes))
        if self.faults is not None:
            self.faults.on_link_charge(self, kind, nbytes, cyc)
        return cyc


class PIMCluster:
    """N :class:`PIMStack`\\ s behind one scheduler and one host link.

    Quacks like a stack for the flat parts — ``len`` is the total channel
    count, ``cluster[flat]`` and iteration reach every device in
    ``(stack, channel)`` order — so :class:`~repro.runtime.residency.
    DeviceTensor` and the scheduler's ledger walks run unchanged.  The
    stack axis is explicit where it matters: :meth:`device` addresses by
    ``(stack, channel)``, :meth:`stack_of` recovers a flat id's stack,
    and :attr:`link` is the shared host-link ledger.
    """

    def __init__(self, stacks: int = 1, channels: int = PSEUDO_CHANNELS,
                 capacity_bytes: Optional[int] = None,
                 link_topology: str = "shared"):
        if link_topology not in ("shared", "switched"):
            raise ValueError(f"unknown link_topology {link_topology!r} "
                             f"(expected 'shared' or 'switched')")
        assert stacks >= 1, "a cluster has at least one stack"
        self.channels_per_stack = channels
        self.link_topology = link_topology
        self.stacks = [PIMStack(channels, stack_id=s,
                                capacity_bytes=capacity_bytes)
                       for s in range(stacks)]
        self.link = HostLinkLedger()
        # "switched": one private link per stack behind a host-side
        # switch; ``link`` remains the switch's host uplink for traffic
        # with no single-stack attribution (serve-loop prefill/acts
        # broadcast).  "shared" keeps the single ledger — bit-identical
        # to the pre-topology model.
        self.links: Optional[List[HostLinkLedger]] = (
            [HostLinkLedger(label=f"link{s}") for s in range(stacks)]
            if link_topology == "switched" else None)

    # -- addressing ----------------------------------------------------------

    @property
    def n_stacks(self) -> int:
        return len(self.stacks)

    def __len__(self) -> int:
        return self.n_stacks * self.channels_per_stack

    def __getitem__(self, flat: int) -> PIMDevice:
        s, c = divmod(flat, self.channels_per_stack)
        return self.stacks[s].devices[c]

    def __iter__(self) -> Iterator[PIMDevice]:
        return itertools.chain.from_iterable(
            s.devices for s in self.stacks)

    def device(self, stack: int, channel: int) -> PIMDevice:
        """The device at explicit ``(stack, channel)`` coordinates."""
        return self.stacks[stack].devices[channel]

    def stack_of(self, flat: int) -> int:
        """Stack index owning flat channel id ``flat``."""
        return flat // self.channels_per_stack

    def flat(self, stack: int, channel: int) -> int:
        """Flat channel id of ``(stack, channel)``."""
        return stack * self.channels_per_stack + channel

    # -- link topology -------------------------------------------------------

    def all_links(self) -> List[HostLinkLedger]:
        """Every ledger traffic can land on: the shared link (or switch
        uplink) first, then the per-stack links (switched only)."""
        return [self.link] + (self.links or [])

    def link_for(self, stack: Optional[int]) -> HostLinkLedger:
        """The ledger a transfer attributed to ``stack`` occupies:
        the per-stack link under ``link_topology="switched"``, else (or
        when the transfer has no single-stack attribution) the shared
        link / switch uplink."""
        if self.links is None or stack is None:
            return self.link
        return self.links[stack]

    def link_totals(self) -> Tuple[int, int]:
        """(bytes, cycles) summed over every link ledger — the figures
        ``RuntimeReport.host_link_bytes/cycles`` report regardless of
        topology."""
        links = self.all_links()
        return (sum(l.bytes for l in links), sum(l.cycles for l in links))

    # -- aggregates (mirror PIMStack's) --------------------------------------

    @property
    def total_flops(self) -> int:
        return sum(s.total_flops for s in self.stacks)

    @property
    def total_bytes(self) -> int:
        return sum(s.total_bytes for s in self.stacks)

    @property
    def resident_bytes(self) -> int:
        return sum(s.resident_bytes for s in self.stacks)

    @property
    def spill_bytes(self) -> int:
        return sum(s.spill_bytes for s in self.stacks)

    @property
    def busy_cycles(self) -> float:
        """Sum of per-channel busy time across stacks (NOT wall-clock)."""
        return sum(s.busy_cycles for s in self.stacks)

    def reset(self) -> None:
        cap = self.stacks[0].capacity_bytes
        self.__init__(self.n_stacks, self.channels_per_stack, cap,
                      link_topology=self.link_topology)
