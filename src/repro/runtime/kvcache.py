"""Paged KV-cache residency: the growing operand of attention decode.

DecodeOffload made the decode *weights* resident; the other decode
bandwidth sink is the per-step attention score/context GEMVs against a
KV cache that grows one token per step.  This module makes that cache a
first-class resident operand:

* Per request, per layer, per kv head, a K cache ``(tokens, head_dim)``
  and a transposed V cache ``(head_dim, tokens)`` live as
  :class:`~repro.runtime.residency.PagedTensor` handles growing in
  :data:`~repro.runtime.residency.KV_BLOCK_TOKENS`-token pages.
* The per-step K/V append is a **resident elementwise write**: only the
  new token's bytes cross the bus (charged on the owning channel, marked
  ``# KVAPPEND`` in the trace), and re-marking the grown trailing-page
  box supersedes the old one — the prefix is never re-shipped, so
  steady-state per-step h2d is independent of context length.
* Page ``i`` is owned by channel ``chans[i % len(chans)]`` — exactly the
  ``paged`` placement policy's block-cyclic assignment, so the score
  GEMV (``K @ q``), the in-place softmax epilogue, and the context GEMV
  (``V^T @ probs``) all hit residency page-for-page as the context
  grows.
* Under a ``capacity_bytes`` budget, appends evict the **oldest
  non-trailing pages of the coldest request** (deterministic: requests
  ordered by last-decoded step, pages ascending; ``# KVEVICT`` markers,
  zero traffic now).  Evicted pages are restored before the victim
  request next decodes — real h2d plus a host-link ``reupload`` charge
  on clusters — so 32k+ contexts under capacity pressure are honestly
  modeled.  Pages lost to injected channel failures need no handling
  here: the wiped residency misses at the next GEMV and
  ``FaultInjector.on_reship`` charges the recovery.

Numerics are unchanged by any of this (the host mirrors are never
dropped), so DecodeOffload's numeric mode cross-checks attention-on-PIM
outputs against the XLA FP32 reference across evictions and faults.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.device import BYTES_PER_ELEM, box_bytes
from repro.runtime.placement import box_contains
from repro.runtime.residency import KV_BLOCK_TOKENS, PagedTensor


class _RequestKV:
    """One request's KV tensors: ``k[layer][head]`` / ``vt[layer][head]``."""

    __slots__ = ("rid", "k", "vt", "tokens", "last_step", "evicted")

    def __init__(self, rid: Hashable, stack, n_layers: int,
                 n_kv_heads: int, head_dim: int, numeric: bool):
        self.rid = rid
        self.k = [[PagedTensor(stack, head_dim, grow_axis=0,
                               numeric=numeric)
                   for _ in range(n_kv_heads)] for _ in range(n_layers)]
        self.vt = [[PagedTensor(stack, head_dim, grow_axis=1,
                                numeric=numeric)
                    for _ in range(n_kv_heads)] for _ in range(n_layers)]
        self.tokens = 0
        self.last_step = 0          # manager clock at last decode
        self.evicted: set = set()   # page indices currently off-device

    @property
    def num_blocks(self) -> int:
        return -(-self.tokens // KV_BLOCK_TOKENS)

    def tensors(self):
        for layer_k, layer_vt in zip(self.k, self.vt):
            for tk, tv in zip(layer_k, layer_vt):
                yield tk
                yield tv


class KVCacheManager:
    """Owns every request's paged KV residency on one runtime.

    ``channels_for_layer(layer) -> flat channel ids`` supplies the
    channel subset each layer's pages cycle over — the same subset the
    caller runs that layer's attention GEMVs on (home stack channels,
    minus failed ones), so page owners and ``paged``-placement shard
    channels coincide and residency hits page-for-page.

    ``capacity_bytes`` bounds the *total* resident KV bytes across all
    requests (``None`` = unbounded).  The floor is the per-request
    trailing pages — those are never evicted (the decode step is about
    to grow them) — so a budget below one page per tensor stays over
    budget gracefully rather than thrashing.
    """

    def __init__(self, rt, *, n_layers: int, n_kv_heads: int,
                 head_dim: int,
                 channels_for_layer: Callable[[int], Sequence[int]],
                 capacity_bytes: Optional[int] = None,
                 numeric: bool = False, metrics=None):
        if not 1 <= head_dim <= KV_BLOCK_TOKENS:
            raise ValueError(
                f"head_dim {head_dim} must be in [1, {KV_BLOCK_TOKENS}] "
                f"so one KV page spans exactly one placement block — use "
                f"a reduced config")
        self.rt = rt
        self.n_layers = n_layers
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.channels_for_layer = channels_for_layer
        self.capacity_bytes = capacity_bytes
        self.numeric = numeric
        self.metrics = metrics
        self._reqs: Dict[Hashable, _RequestKV] = {}
        self._clock = 0
        self._present = 0           # resident KV bytes, manager's ledger
        self.append_bytes = 0
        self.evict_bytes = 0
        self.restore_bytes = 0
        self.evictions = 0          # page-evict events (per tensor page)

    # -- request lifecycle ---------------------------------------------------

    def request(self, rid: Hashable) -> _RequestKV:
        st = self._reqs.get(rid)
        if st is None:
            st = self._reqs[rid] = _RequestKV(
                rid, self.rt.stack, self.n_layers, self.n_kv_heads,
                self.head_dim, self.numeric)
        return st

    def begin_decode(self, rid: Hashable) -> _RequestKV:
        """Mark ``rid`` as the currently decoding (hottest) request and
        restore any pages evicted under capacity pressure."""
        st = self.request(rid)
        self._clock += 1
        st.last_step = self._clock
        if st.evicted:
            self._restore(st)
        return st

    def tokens(self, rid: Hashable) -> int:
        st = self._reqs.get(rid)
        return st.tokens if st is not None else 0

    def tensors(self, rid: Hashable, layer: int,
                head: int) -> Tuple[PagedTensor, PagedTensor]:
        st = self._reqs[rid]
        return st.k[layer][head], st.vt[layer][head]

    def release(self, rid: Hashable) -> int:
        """Drop a finished request's KV entirely (capacity reclaim, no
        traffic).  Returns the resident bytes freed."""
        st = self._reqs.pop(rid, None)
        if st is None:
            return 0
        freed = 0
        for b in range(st.num_blocks):
            if b not in st.evicted:
                freed += self._block_bytes(st, b)
        for t in st.tensors():
            t.evict()
        self._present -= freed
        return freed

    # -- appends (the per-step resident write) -------------------------------

    def append_tokens(self, rid: Hashable, layer: int, count: int,
                      k_vals: Optional[Sequence[np.ndarray]] = None,
                      v_vals: Optional[Sequence[np.ndarray]] = None,
                      after=None):
        """Grow one layer's K/V by ``count`` tokens across all kv heads.

        Only the new tokens' bytes are charged (h2d on each touched
        page's owner channel, ``# KVAPPEND``-marked); the grown trailing
        page is re-marked resident, superseding its old box.  ``k_vals``
        / ``v_vals`` are per-head ``(count, head_dim)`` / ``(head_dim,
        count)`` numeric payloads.  On an async runtime the append is
        submitted as one timeline op (returned; the attention GEMVs
        reading these tensors then start after the write lands);
        serialized runtimes return ``None``.
        """
        st = self.request(rid)
        if after is not None and not isinstance(after, (list, tuple)):
            after = (after,)        # a bare OpHandle chains too
        chans = tuple(sorted(self.channels_for_layer(layer)))
        marks = {c: len(self.rt.stack[c].events) for c in chans}
        busy: Dict[int, float] = {}
        uids: List[int] = []
        appended = 0
        # this layer's own extent, not st.tokens: layers append one at a
        # time within a step, so the request-level count lags until the
        # last layer lands
        lo = st.k[layer][0].tokens
        for j in range(self.n_kv_heads):
            pairs = ((st.k[layer][j],
                      None if k_vals is None else k_vals[j]),
                     (st.vt[layer][j],
                      None if v_vals is None else v_vals[j]))
            for t, vals in pairs:
                t.append(count, vals)
                uids.append(t.uid)
                b0 = lo // KV_BLOCK_TOKENS
                b1 = (t.tokens - 1) // KV_BLOCK_TOKENS
                for b in range(b0, b1 + 1):
                    blo = b * KV_BLOCK_TOKENS
                    bhi = min(blo + KV_BLOCK_TOKENS, t.tokens)
                    nb = (bhi - max(blo, lo)) * t.fixed * BYTES_PER_ELEM
                    owner = chans[b % len(chans)]
                    dev = self.rt.stack[owner]
                    busy[owner] = busy.get(owner, 0.0) \
                        + dev.host_to_pim(nb)
                    dev.events.append(("kvappend", nb))
                    t.mark_resident(owner, t.block_box(b))
                    appended += nb
                    self._present += nb
        self.append_bytes += appended
        st.tokens = max(st.tokens, lo + count)
        handle = None
        if self.rt.timeline is not None:
            handle = self.rt._submit_async(
                "kvappend", busy, 0, marks, reads=(), writes=tuple(uids),
                after=after, report=None, result=None)
        if self.metrics is not None:
            self.metrics.counter(
                "kv.append_bytes", unit="bytes",
                help="new-token KV bytes written in place").inc(appended)
        self._enforce_capacity()
        return handle

    # -- capacity: paged eviction / restore ----------------------------------

    def _block_bytes(self, st: _RequestKV, b: int) -> int:
        """Resident bytes of page ``b`` across all of one request's
        tensors (K and V^T of every layer and head)."""
        span = min((b + 1) * KV_BLOCK_TOKENS, st.tokens) \
            - b * KV_BLOCK_TOKENS
        return (span * self.head_dim * BYTES_PER_ELEM
                * 2 * self.n_kv_heads * self.n_layers)

    def _enforce_capacity(self) -> None:
        if self.capacity_bytes is None:
            return
        while self._present > self.capacity_bytes:
            victim: Optional[Tuple[_RequestKV, int]] = None
            for st in sorted(self._reqs.values(),
                             key=lambda s: (s.last_step, str(s.rid))):
                # only pages every tensor has materialized: mid-prefill
                # (layers append one at a time) the laggards' pages
                # don't exist yet, so the request is briefly immune
                full = min((t.tokens for t in st.tensors()), default=0)
                nblocks = -(-full // KV_BLOCK_TOKENS)
                cand = [b for b in range(nblocks - 1)
                        if b not in st.evicted]
                if cand:
                    victim = (st, cand[0])
                    break
            if victim is None:
                return      # only trailing pages left: over budget, stable
            self._evict_block(*victim)

    def _evict_block(self, st: _RequestKV, b: int) -> None:
        """Drop page ``b`` of every tensor of ``st`` (oldest page of the
        coldest request): residency forgotten, ``# KVEVICT``-marked, zero
        traffic now — the restore pays the re-ship."""
        for layer in range(self.n_layers):
            chans = tuple(sorted(self.channels_for_layer(layer)))
            owner = chans[b % len(chans)]
            dev = self.rt.stack[owner]
            for j in range(self.n_kv_heads):
                for t in (st.k[layer][j], st.vt[layer][j]):
                    box = t.block_box(b)
                    nb = box_bytes(box)
                    dev.drop_resident_box(t.uid, box)
                    dev.events.append(("kvevict", nb))
                    self.evict_bytes += nb
                    self._present -= nb
                    self.evictions += 1
        st.evicted.add(b)
        if self.metrics is not None:
            self.metrics.counter(
                "kv.evictions", unit="pages",
                help="KV pages evicted under capacity pressure").inc(
                2 * self.n_kv_heads * self.n_layers)

    def _restore(self, st: _RequestKV) -> None:
        """Re-ship every evicted page of ``st`` before it decodes again:
        real h2d on each page's owner plus a host-link ``reupload``
        charge on clusters (the host re-carries the pages from its
        mirror, like lost weights)."""
        link = getattr(self.rt.stack, "link", None)
        marks_all: Dict[int, int] = {}
        busy: Dict[int, float] = {}
        uids: List[int] = []
        restored = 0
        for b in sorted(st.evicted):
            for layer in range(self.n_layers):
                chans = tuple(sorted(self.channels_for_layer(layer)))
                owner = chans[b % len(chans)]
                dev = self.rt.stack[owner]
                marks_all.setdefault(owner, len(dev.events))
                for j in range(self.n_kv_heads):
                    for t in (st.k[layer][j], st.vt[layer][j]):
                        box = t.block_box(b)
                        nb = box_bytes(box)
                        # a GEMV that ran between the evict and this
                        # restore already re-shipped the page at its
                        # residency miss — reclaim it on the ledger
                        # without paying the transfer twice
                        if any(box_contains(rb, box)
                               for rb in dev.resident.get(t.uid, ())):
                            self._present += nb
                            continue
                        busy[owner] = busy.get(owner, 0.0) \
                            + dev.host_to_pim(nb)
                        if link is not None:
                            link.charge("reupload", nb)
                        t.mark_resident(owner, box)
                        uids.append(t.uid)
                        restored += nb
                        self._present += nb
        self.restore_bytes += restored
        st.evicted.clear()
        if self.rt.timeline is not None:
            self.rt._submit_async(
                "kvrestore", busy, 0, marks_all, reads=(),
                writes=tuple(uids), after=None, report=None, result=None)
        if self.metrics is not None:
            self.metrics.counter(
                "kv.restore_bytes", unit="bytes",
                help="evicted KV pages re-shipped before decode").inc(
                restored)

    # -- reporting -----------------------------------------------------------

    @property
    def resident_kv_bytes(self) -> int:
        """The manager's ledger of on-device KV bytes (what the capacity
        budget is enforced against)."""
        return self._present

    def summary(self) -> Dict:
        return {
            "requests": len(self._reqs),
            "tokens": {str(st.rid): st.tokens
                       for st in self._reqs.values()},
            "resident_kv_bytes": self._present,
            "append_bytes": self.append_bytes,
            "evict_bytes": self.evict_bytes,
            "restore_bytes": self.restore_bytes,
            "evictions": self.evictions,
        }
