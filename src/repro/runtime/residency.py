"""Operand residency: device-resident tensor handles for the PIM runtime.

PrIM's central lesson is that host<->PIM transfer dominates real PIM
workloads unless data stays resident.  The scheduler's default path
re-ships every operand shard per op — correct accounting for one-shot
ops, but wrong for the serve-loop regime where the same weight matrix is
reused every decode step.  This module is the residency layer:

* :class:`DeviceTensor` — a handle to a host array whose shards live on
  the stack's pseudo-channels.  The handle records *which* 2D boxes of
  the tensor are resident on *which* channel (mirrored into each
  :class:`~repro.runtime.device.PIMDevice`'s residency table); the
  scheduler consults it per shard and charges **zero** h2d for resident
  regions, appending a ``reuse`` event so traces stay replayable.
* :func:`place` — eagerly uploads an array's shards per a placement
  policy (the "load the weights once" step), charging the one-time h2d
  and returning the handle.  Handles may also be created lazily: a miss
  during an op transfers the shard *and* marks it resident, so repeated
  ops converge to zero weight traffic either way.

Outputs can stay resident too (``keep_output=True`` on the scheduler
ops): the op then charges no d2h for exact-cover output shards; the
drain is deferred until :meth:`DeviceTensor.to_host`, and a chained op
consuming the handle on the same channel boxes never pays it at all —
the GEMM->elementwise epilogue fusion the ROADMAP names.

Numerics are unchanged by residency: ``execute=True`` runs the same
per-channel engines over the same host mirror, so resident-handle
outputs are bit-exact with the fresh-transfer path (property-tested).
Analytic handles (shape-only, ``values=None``) support paper-scale
sweeps without materializing weights.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.runtime.device import BYTES_PER_ELEM, PIMStack, box_bytes

Box = Tuple[int, int, int, int]

_uid = itertools.count(1)


class DeviceTensor:
    """A 2D tensor with per-channel shard residency on a :class:`PIMStack`
    (or a :class:`~repro.runtime.cluster.PIMCluster`, addressed through
    its flat channel view — residency tables are per-device either way).

    ``values`` is the host mirror (FP16) that execute-mode engines compute
    from — residency changes *accounting*, never numerics.  ``values`` is
    ``None`` for analytic (shape-only) handles, which only cost-model
    sweeps may consume.

    ``pending_d2h`` holds output boxes computed on-device but not yet
    drained to the host; :meth:`to_host` charges their d2h then returns
    the mirror.

    ``copy=True`` (the default, and what :meth:`PIMRuntime.place` uses)
    snapshots the caller's array: on real hardware resident data cannot
    change without a transfer, so later host-side mutation of the source
    must not leak into the "resident" copy.  The scheduler's own
    ``keep_output`` handles pass ``copy=False`` — they deliberately alias
    the op's output buffer so the host-side K-split reduction lands in
    the mirror.
    """

    def __init__(self, stack: PIMStack, shape: Tuple[int, int],
                 values: Optional[np.ndarray] = None, copy: bool = True):
        if len(shape) != 2:
            raise ValueError(
                f"DeviceTensor models 2D operands; got shape {shape} — "
                f"reshape/flatten to (rows, cols) before placing")
        self.uid = next(_uid)
        self.stack = stack
        self.shape = tuple(shape)
        if values is None:
            self.values = None
        elif copy:
            self.values = np.array(values, np.float16, copy=True)
        else:
            self.values = np.asarray(values, np.float16)
        self.pending_d2h: List[Tuple[int, Box]] = []   # (channel, box)

    # -- residency queries / updates (delegate to the device tables) --------

    def is_resident(self, channel: int, box: Box) -> bool:
        return self.stack[channel].has_resident(self.uid, box)

    def mark_resident(self, channel: int, box: Box,
                      pin: bool = False) -> bool:
        """Record residency; under a device capacity bound the device may
        refuse (box streamed, not resident) or evict LRU tensors first.
        ``pin=True`` protects the region from eviction until
        :meth:`to_host` drains it (kept outputs — the only copy of a
        result lives on-channel until then).  Returns whether the box is
        now resident."""
        return self.stack[channel].add_resident(self.uid, box, pin=pin)

    @property
    def resident_bytes(self) -> int:
        """Total bytes of this tensor resident across all channels
        (> host size when placements replicate regions)."""
        return sum(d.resident_bytes_of(self.uid) for d in self.stack)

    # -- host materialization ------------------------------------------------

    def to_host(self) -> Optional[jnp.ndarray]:
        """Drain pending output shards (charged as d2h) and return the
        host array (``None`` for analytic handles).  Drained regions
        become evictable again (unpinned)."""
        for channel, box in self.pending_d2h:
            dev = self.stack[channel]
            dev.pim_to_host(box_bytes(box))
            dev.unpin(self.uid)
        self.pending_d2h = []
        return jnp.asarray(self.values) if self.values is not None else None

    def evict(self) -> None:
        """Drop all residency (capacity reclaim).  No traffic is charged;
        un-drained outputs are lost unless :meth:`to_host` ran first."""
        for dev in self.stack:
            dev.drop_resident(self.uid)
        self.pending_d2h = []

    def resolve(self) -> np.ndarray:
        """Host mirror for execute-mode engines; rejects analytic handles."""
        assert self.values is not None, \
            "analytic (shape-only) DeviceTensor cannot be executed " \
            "numerically; pass execute=False or place a real array"
        return self.values

    def __repr__(self) -> str:
        mode = "analytic" if self.values is None else "numeric"
        return (f"DeviceTensor(uid={self.uid}, shape={self.shape}, "
                f"{mode}, resident_bytes={self.resident_bytes})")
