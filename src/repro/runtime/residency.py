"""Operand residency: device-resident tensor handles for the PIM runtime.

PrIM's central lesson is that host<->PIM transfer dominates real PIM
workloads unless data stays resident.  The scheduler's default path
re-ships every operand shard per op — correct accounting for one-shot
ops, but wrong for the serve-loop regime where the same weight matrix is
reused every decode step.  This module is the residency layer:

* :class:`DeviceTensor` — a handle to a host array whose shards live on
  the stack's pseudo-channels.  The handle records *which* 2D boxes of
  the tensor are resident on *which* channel (mirrored into each
  :class:`~repro.runtime.device.PIMDevice`'s residency table); the
  scheduler consults it per shard and charges **zero** h2d for resident
  regions, appending a ``reuse`` event so traces stay replayable.
* :func:`place` — eagerly uploads an array's shards per a placement
  policy (the "load the weights once" step), charging the one-time h2d
  and returning the handle.  Handles may also be created lazily: a miss
  during an op transfers the shard *and* marks it resident, so repeated
  ops converge to zero weight traffic either way.

Outputs can stay resident too (``keep_output=True`` on the scheduler
ops): the op then charges no d2h for exact-cover output shards; the
drain is deferred until :meth:`DeviceTensor.to_host`, and a chained op
consuming the handle on the same channel boxes never pays it at all —
the GEMM->elementwise epilogue fusion the ROADMAP names.

Numerics are unchanged by residency: ``execute=True`` runs the same
per-channel engines over the same host mirror, so resident-handle
outputs are bit-exact with the fresh-transfer path (property-tested).
Analytic handles (shape-only, ``values=None``) support paper-scale
sweeps without materializing weights.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.isa import ROWNUM
from repro.runtime.device import BYTES_PER_ELEM, PIMStack, box_bytes

Box = Tuple[int, int, int, int]

_uid = itertools.count(1)

#: Tokens per KV page.  Equal to ROWNUM so one K-cache page is exactly one
#: 128-row placement block (and one transposed-V page one 128-column K
#: group) under the ``paged`` placement policy — page boxes and shard
#: operand boxes coincide, which is what makes the residency containment
#: check hit without geometry translation.
KV_BLOCK_TOKENS = ROWNUM


class DeviceTensor:
    """A 2D tensor with per-channel shard residency on a :class:`PIMStack`
    (or a :class:`~repro.runtime.cluster.PIMCluster`, addressed through
    its flat channel view — residency tables are per-device either way).

    ``values`` is the host mirror (FP16) that execute-mode engines compute
    from — residency changes *accounting*, never numerics.  ``values`` is
    ``None`` for analytic (shape-only) handles, which only cost-model
    sweeps may consume.

    ``pending_d2h`` holds output boxes computed on-device but not yet
    drained to the host; :meth:`to_host` charges their d2h then returns
    the mirror.

    ``copy=True`` (the default, and what :meth:`PIMRuntime.place` uses)
    snapshots the caller's array: on real hardware resident data cannot
    change without a transfer, so later host-side mutation of the source
    must not leak into the "resident" copy.  The scheduler's own
    ``keep_output`` handles pass ``copy=False`` — they deliberately alias
    the op's output buffer so the host-side K-split reduction lands in
    the mirror.
    """

    def __init__(self, stack: PIMStack, shape: Tuple[int, int],
                 values: Optional[np.ndarray] = None, copy: bool = True):
        if len(shape) != 2:
            raise ValueError(
                f"DeviceTensor models 2D operands; got shape {shape} — "
                f"reshape/flatten to (rows, cols) before placing")
        self.uid = next(_uid)
        self.stack = stack
        self.shape = tuple(shape)
        if values is None:
            self.values = None
        elif copy:
            self.values = np.array(values, np.float16, copy=True)
        else:
            self.values = np.asarray(values, np.float16)
        self.pending_d2h: List[Tuple[int, Box]] = []   # (channel, box)

    # -- residency queries / updates (delegate to the device tables) --------

    def is_resident(self, channel: int, box: Box) -> bool:
        return self.stack[channel].has_resident(self.uid, box)

    def mark_resident(self, channel: int, box: Box,
                      pin: bool = False) -> bool:
        """Record residency; under a device capacity bound the device may
        refuse (box streamed, not resident) or evict LRU tensors first.
        ``pin=True`` protects the region from eviction until
        :meth:`to_host` drains it (kept outputs — the only copy of a
        result lives on-channel until then).  Returns whether the box is
        now resident."""
        return self.stack[channel].add_resident(self.uid, box, pin=pin)

    @property
    def resident_bytes(self) -> int:
        """Total bytes of this tensor resident across all channels
        (> host size when placements replicate regions)."""
        return sum(d.resident_bytes_of(self.uid) for d in self.stack)

    # -- host materialization ------------------------------------------------

    def to_host(self) -> Optional[jnp.ndarray]:
        """Drain pending output shards (charged as d2h) and return the
        host array (``None`` for analytic handles).  Drained regions
        become evictable again (unpinned)."""
        for channel, box in self.pending_d2h:
            dev = self.stack[channel]
            dev.pim_to_host(box_bytes(box))
            dev.unpin(self.uid)
        self.pending_d2h = []
        return jnp.asarray(self.values) if self.values is not None else None

    def evict(self) -> None:
        """Drop all residency (capacity reclaim).  No traffic is charged;
        un-drained outputs are lost unless :meth:`to_host` ran first."""
        for dev in self.stack:
            dev.drop_resident(self.uid)
        self.pending_d2h = []

    def resolve(self) -> np.ndarray:
        """Host mirror for execute-mode engines; rejects analytic handles."""
        assert self.values is not None, \
            "analytic (shape-only) DeviceTensor cannot be executed " \
            "numerically; pass execute=False or place a real array"
        return self.values

    def __repr__(self) -> str:
        mode = "analytic" if self.values is None else "numeric"
        return (f"DeviceTensor(uid={self.uid}, shape={self.shape}, "
                f"{mode}, resident_bytes={self.resident_bytes})")


class PagedTensor(DeviceTensor):
    """A :class:`DeviceTensor` that *grows* along one axis in fixed
    :data:`KV_BLOCK_TOKENS`-sized pages — the KV-cache operand shape.

    A K cache is ``(tokens, head_dim)`` growing along axis 0; a V cache
    is stored transposed ``(head_dim, tokens)`` growing along axis 1 so
    the context GEMV ``probs @ V`` runs as ``V^T``-resident K-split
    shards.  Either way the *fixed* axis must fit one placement block
    (``head_dim <= ROWNUM``) so each page's box coincides with exactly
    one ``paged``-placement shard operand box.

    Growth is an *append*, never a re-layout: page ``i`` keeps its box
    and (under ``paged`` placement) its channel forever, so the resident
    prefix is never re-shipped.  Only the trailing partial page's box
    changes as it fills; re-marking it resident supersedes the old
    contained box (see ``PIMDevice.add_resident``).  The host mirror is
    kept in a capacity buffer grown page-at-a-time, with ``values``
    exposed as the logical-extent view.
    """

    def __init__(self, stack: PIMStack, fixed: int, grow_axis: int = 0,
                 numeric: bool = False):
        if grow_axis not in (0, 1):
            raise ValueError(f"grow_axis must be 0 or 1, got {grow_axis}")
        if not 1 <= fixed <= ROWNUM:
            raise ValueError(
                f"fixed dim {fixed} must be in [1, {ROWNUM}] so a page "
                f"spans exactly one placement block")
        shape = (0, fixed) if grow_axis == 0 else (fixed, 0)
        super().__init__(stack, shape, values=None)
        self.grow_axis = grow_axis
        self.fixed = fixed
        self.numeric = numeric
        self.tokens = 0
        self._buf: Optional[np.ndarray] = None   # capacity >= tokens

    @property
    def num_blocks(self) -> int:
        return -(-self.tokens // KV_BLOCK_TOKENS)

    def block_box(self, idx: int) -> Box:
        """Operand-coordinate box of page ``idx`` at the current extent
        (the trailing page's box grows until the page fills)."""
        lo = idx * KV_BLOCK_TOKENS
        hi = min(lo + KV_BLOCK_TOKENS, self.tokens)
        assert lo < hi, f"page {idx} empty at {self.tokens} tokens"
        if self.grow_axis == 0:
            return (lo, hi, 0, self.fixed)
        return (0, self.fixed, lo, hi)

    def append(self, count: int,
               values: Optional[np.ndarray] = None) -> int:
        """Grow the logical extent by ``count`` tokens and return the
        index of the first page touched by the new entries.  ``values``
        (``(count, fixed)`` or ``(fixed, count)`` matching ``grow_axis``)
        fills the numeric mirror; accounting (h2d of the new entries,
        residency re-mark) is the KV manager's job, not this handle's.
        """
        if count <= 0:
            raise ValueError(f"append count must be positive, got {count}")
        first_block = self.tokens // KV_BLOCK_TOKENS
        lo, self.tokens = self.tokens, self.tokens + count
        if self.numeric:
            cap = -(-self.tokens // KV_BLOCK_TOKENS) * KV_BLOCK_TOKENS
            full = ((cap, self.fixed) if self.grow_axis == 0
                    else (self.fixed, cap))
            if self._buf is None or self._buf.shape[self.grow_axis] < cap:
                buf = np.zeros(full, np.float16)
                if self._buf is not None:
                    if self.grow_axis == 0:
                        buf[:lo] = self._buf[:lo]
                    else:
                        buf[:, :lo] = self._buf[:, :lo]
                self._buf = buf
            if values is not None:
                new = np.asarray(values, np.float16)
                if self.grow_axis == 0:
                    self._buf[lo:self.tokens] = new
                else:
                    self._buf[:, lo:self.tokens] = new
            self.values = (self._buf[:self.tokens] if self.grow_axis == 0
                           else self._buf[:, :self.tokens])
        self.shape = ((self.tokens, self.fixed) if self.grow_axis == 0
                      else (self.fixed, self.tokens))
        return first_block

    def __repr__(self) -> str:
        mode = "numeric" if self.numeric else "analytic"
        return (f"PagedTensor(uid={self.uid}, shape={self.shape}, "
                f"axis={self.grow_axis}, blocks={self.num_blocks}, {mode}, "
                f"resident_bytes={self.resident_bytes})")
