"""Attention: GQA/MQA, qk-norm, RoPE, sliding windows, MLA, KV caches.

Memory-linear by construction: training/prefill attention is a chunked
online-softmax scan over KV blocks (the pure-jnp twin of the Pallas flash
kernel — same math, lowered by XLA for the dry-run), so 32k prefill never
materializes a T x T score matrix.  Decode uses the same routine with Tq=1
against the cache.

Sharding posture (single/multi-pod mesh): q heads shard on 'model'; KV
tensors shard on heads when divisible, else on head_dim (partial scores are
then all-reduced over 'model' — a small (B,H,Tq,Tk)-free collective since
only the contraction dim is sharded).  See sharding/rules.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    Backend, XLA, apply_norm, dense, dense_init, norm_init, out_constrain,
    rope,
)
from repro.sharding.context import constrain

NEG = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention (pure jnp; GQA-aware)
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      chunk: int = 1024, q_chunk: int = 512, q_offset=0,
                      kv_positions: Optional[jnp.ndarray] = None,
                      kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q (B,Tq,H,D), k/v (B,Tk,Hkv,Dv?) -> (B,Tq,H,Dv).

    Memory-linear in BOTH directions: an outer scan over q blocks wraps the
    inner online-softmax scan over KV blocks, so the largest live score
    tensor is (B, q_chunk, H, chunk).

    ``q_offset``: absolute position of q[0] (scalar or (B,)).
    ``kv_positions``: absolute positions of cache slots (B,Tk) for rolling
    caches; defaults to 0..Tk-1.  ``kv_valid``: scalar/(B,) count of valid
    cache slots (defaults to all).
    """
    b, tq, h, d = q.shape
    if tq > q_chunk:
        pad = (-tq) % q_chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nq = (tq + pad) // q_chunk
        qb = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
        offs = jnp.broadcast_to(jnp.asarray(q_offset), (b,))

        def qstep(_, inp):
            qi, off = inp
            out = chunked_attention(
                qi, k, v, causal=causal, window=window, chunk=chunk,
                q_chunk=q_chunk, q_offset=off, kv_positions=kv_positions,
                kv_valid=kv_valid)
            return None, out

        _, outs = jax.lax.scan(
            qstep, None,
            (qb, offs[None, :] + jnp.arange(nq)[:, None] * q_chunk))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, tq + pad, h, -1)
        return out[:, :tq]
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale = d ** -0.5
    chunk = min(chunk, tk)
    pad = (-tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_positions is not None:
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                                   constant_values=2 ** 30)
    nb = (tk + pad) // chunk
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(tk + pad)[None], (b, tk + pad))
    if kv_valid is None:
        kv_valid = jnp.full((b,), tk, jnp.int32)
    else:
        kv_valid = jnp.broadcast_to(jnp.asarray(kv_valid, jnp.int32), (b,))
    qpos = (jnp.broadcast_to(jnp.asarray(q_offset), (b,))[:, None]
            + jnp.arange(tq)[None, :])                       # (B, Tq)

    qg = q.reshape(b, tq, hkv, g, d).astype(jnp.float32)
    kc = k.reshape(b, nb, chunk, hkv, d).astype(jnp.float32)
    vc = v.reshape(b, nb, chunk, hkv, dv).astype(jnp.float32)
    pc = kv_positions.reshape(b, nb, chunk)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb, slot0 = inp                              # (B,chunk,Hkv,D)...
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb) * scale  # (B,Tq,Hkv,g,chunk)
        kpos = pb[:, None, None, None, :]                    # (B,1,1,1,chunk)
        qp = qpos[:, :, None, None, None]
        slot = slot0 + jnp.arange(kb.shape[1])
        ok = slot[None, :, None] < kv_valid[:, None, None]   # (B,chunk,1)
        mask = jnp.transpose(ok, (0, 2, 1))[:, :, None, None, :]
        mask = mask & (kpos >= 0)          # -1 marks unwritten cache slots
        if causal:
            mask = mask & (kpos <= qp)
        if window > 0:
            mask = mask & (kpos > qp - window)
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((b, tq, hkv, g), NEG, jnp.float32)
    l0 = jnp.zeros((b, tq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, tq, hkv, g, dv), jnp.float32)
    # checkpoint the chunk body: backward recomputes scores instead of the
    # scan saving per-chunk (B,Tq,H,chunk) residuals — this is what keeps
    # 32k attention memory-linear end to end
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False), (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2), jnp.arange(nb) * chunk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, tq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard GQA attention module
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ArchConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype, bias=cfg.attn_bias),
        "wk": dense_init(ks[1], d, hkv * hd, dtype, bias=cfg.attn_bias),
        "wv": dense_init(ks[2], d, hkv * hd, dtype, bias=cfg.attn_bias),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = norm_init(hd, dtype)
        p["knorm"] = norm_init(hd, dtype)
    return p


def make_cache(cfg: ArchConfig, batch: int, length: int, dtype,
               layers: Optional[int] = None):
    """Standard KV cache (rolling when sliding_window > 0)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.sliding_window:
        length = min(length, cfg.sliding_window)
    shape = (batch, length, hkv, hd)
    if layers is not None:
        shape = (layers,) + shape
    pshape = shape[:-2]
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full(pshape, -1, jnp.int32),   # absolute position per slot
    }


def attention_apply(p, x, cfg: ArchConfig, *, positions, cache=None,
                    backend: Backend = XLA, causal=True,
                    chunk: int = 1024) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B,T,d).  Training/prefill: cache is None or gets filled.
    Decode: T==1, cache is read+updated (rolling for SWA)."""
    b, t, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = dense(p["wq"], x, backend).reshape(b, t, h, hd)
    k = dense(p["wk"], x, backend).reshape(b, t, hkv, hd)
    v = dense(p["wv"], x, backend).reshape(b, t, hkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["qnorm"], q, cfg.norm_eps)
        k = apply_norm(p["knorm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)

    new_cache = None
    if cache is None:
        out = chunked_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window, chunk=chunk,
                                q_offset=positions[:, 0])
    elif t > 1:
        # prefill into the cache (rolling tail for SWA)
        clen = cache["k"].shape[1]
        kk, vv, pp = k, v, jnp.broadcast_to(positions, (b, t))
        if t >= clen:
            kk, vv, pp = k[:, -clen:], v[:, -clen:], pp[:, -clen:]
            slot = jnp.zeros((b,), jnp.int32)
        else:
            slot = jnp.zeros((b,), jnp.int32)
        newk = jax.lax.dynamic_update_slice(cache["k"], kk.astype(cache["k"].dtype),
                                            (0, 0, 0, 0))
        newv = jax.lax.dynamic_update_slice(cache["v"], vv.astype(cache["v"].dtype),
                                            (0, 0, 0, 0))
        npos = jax.lax.dynamic_update_slice(
            cache["pos"], pp.astype(jnp.int32), (0, 0))
        new_cache = {"k": newk, "v": newv, "pos": npos}
        out = chunked_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window, chunk=chunk,
                                q_offset=positions[:, 0])
    else:
        # decode: write the new kv into its slot, attend over the cache
        from repro.sharding.context import current_mesh
        mesh = current_mesh()
        msize = mesh.shape.get("model", 1) if mesh else 1
        heads_shardable = hkv % max(msize, 1) == 0
        clen = cache["k"].shape[1]
        pos = positions[:, 0] if positions.ndim > 1 else positions  # (B,)
        slot = (pos % clen) if cfg.sliding_window else pos
        bi = jnp.arange(b)
        newk = cache["k"].at[bi, slot].set(k[:, 0].astype(cache["k"].dtype))
        newv = cache["v"].at[bi, slot].set(v[:, 0].astype(cache["v"].dtype))
        npos = cache["pos"].at[bi, slot].set(pos.astype(jnp.int32))
        new_cache = {"k": newk, "v": newv, "pos": npos}
        kv_valid = jnp.minimum(pos + 1, clen)
        if heads_shardable:
            kk = constrain(newk, "batch", None, "model", None)
            vv = constrain(newv, "batch", None, "model", None)
        else:
            # KV heads don't divide the model axis: shard head_dim on both
            # q and kv so the score contraction is over the sharded dim —
            # a small all-reduce of (B,H,Tk) partials instead of per-chunk
            # cache all-gathers
            q = constrain(q, "batch", None, None, "model")
            kk = constrain(newk, "batch", None, None, "model")
            vv = constrain(newv, "batch", None, None, "model")
        out = chunked_attention(
            q, kk, vv,
            causal=True, window=cfg.sliding_window, chunk=chunk,
            q_offset=pos, kv_positions=npos,
            kv_valid=None if not cfg.sliding_window else kv_valid)
    out = constrain(out, "batch", None, "model", None)
    y = dense(p["wo"], out.reshape(b, t, h * hd), backend)
    return out_constrain(y, cfg.policy), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank q/kv with compressed latent cache
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "qnorm": norm_init(m.q_lora_rank, dtype),
        "wuq": dense_init(ks[1], m.q_lora_rank, h * qd, dtype),
        "wdkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kvnorm": norm_init(m.kv_lora_rank, dtype),
        "wkr": dense_init(ks[3], d, m.qk_rope_dim, dtype),
        "wuk": dense_init(ks[4], m.kv_lora_rank, h * m.qk_nope_dim, dtype),
        "wuv": dense_init(ks[5], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def mla_make_cache(cfg: ArchConfig, batch: int, length: int, dtype,
                   layers: Optional[int] = None):
    m = cfg.mla
    shape_c = (batch, length, m.kv_lora_rank)
    shape_r = (batch, length, m.qk_rope_dim)
    if layers is not None:
        shape_c = (layers,) + shape_c
        shape_r = (layers,) + shape_r
    return {"ckv": jnp.zeros(shape_c, dtype),
            "kr": jnp.zeros(shape_r, dtype)}


def mla_apply(p, x, cfg: ArchConfig, *, positions, cache=None,
              backend: Backend = XLA, chunk: int = 1024):
    m = cfg.mla
    b, t, d = x.shape
    h = cfg.n_heads
    nd, rd, vd = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim

    q = dense(p["wuq"], apply_norm(p["qnorm"], dense(p["wdq"], x, backend),
                                   cfg.norm_eps), backend)
    q = q.reshape(b, t, h, nd + rd)
    qn, qr = q[..., :nd], q[..., nd:]
    qr = rope(qr, positions, cfg.rope_theta)
    ckv = apply_norm(p["kvnorm"], dense(p["wdkv"], x, backend), cfg.norm_eps)
    kr = rope(dense(p["wkr"], x, backend)[:, :, None, :], positions,
              cfg.rope_theta)[:, :, 0]                        # shared head

    new_cache = None
    if cache is not None and t == 1:
        pos = positions[:, 0] if positions.ndim > 1 else positions
        bi = jnp.arange(b)
        ckv_c = cache["ckv"].at[bi, pos].set(ckv[:, 0].astype(cache["ckv"].dtype))
        kr_c = cache["kr"].at[bi, pos].set(kr[:, 0].astype(cache["kr"].dtype))
        new_cache = {"ckv": ckv_c, "kr": kr_c}
        ckv_all, kr_all = ckv_c, kr_c
    else:
        ckv_all, kr_all = ckv, kr
        if cache is not None:  # prefill fills the cache
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
                "kr": jax.lax.dynamic_update_slice(
                    cache["kr"], kr.astype(cache["kr"].dtype), (0, 0, 0)),
            }

    # absorbed form: fold W_uk into q, attend directly against the latent —
    # the compressed cache is both k and v (reduction-free: no per-head KV
    # expansion is ever materialized for decode)
    wuk = p["wuk"]["w"].astype(q.dtype).reshape(m.kv_lora_rank, h, nd)
    q_lat = jnp.einsum("bthn,rhn->bthr", qn, wuk)             # (B,T,H,r)
    qq = jnp.concatenate([q_lat, qr], -1)                     # (B,T,H,r+rd)
    qq = constrain(qq, "batch", None, "model", None)
    kk = jnp.concatenate([ckv_all, kr_all], -1)[:, :, None, :]  # (B,Tk,1,r+rd)
    # gather the latent KV across the seq dim ONCE per layer (with SP the
    # inputs arrive seq-sharded; without this, every KV-chunk slice in the
    # attention scan triggers its own gather)
    kk = constrain(kk, "batch", None, None, None)
    ckv_all = constrain(ckv_all, "batch", None, None)
    scale_fix = ((nd + rd) ** -0.5) / ((m.kv_lora_rank + rd) ** -0.5)
    out = chunked_attention(
        qq * scale_fix, kk, ckv_all[:, :, None, :], causal=True, chunk=chunk,
        q_offset=(positions[:, 0] if positions.ndim > 1 else positions),
        kv_valid=None)                                        # (B,T,H,r)
    wuv = p["wuv"]["w"].astype(q.dtype).reshape(m.kv_lora_rank, h, vd)
    out = jnp.einsum("bthr,rhv->bthv", out, wuv)
    y = dense(p["wo"], out.reshape(b, t, h * vd), backend)
    return out_constrain(y, cfg.policy), new_cache
