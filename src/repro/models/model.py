"""LModel — the public model facade: init / loss / prefill / decode.

Covers all assigned families behind one interface:
  dense | moe (+MLA/MTP) | vlm (patch-embed stub) | hybrid | ssm |
  audio (encoder-only, masked frame prediction).

Losses compute cross-entropy in token chunks so full (tokens x vocab)
logits are never materialized (vocab is 'model'-sharded; the padded vocab
tail is masked out of the logsumexp).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.layers import (
    Backend, XLA, apply_norm, dense, dense_init, embed_init, norm_init,
)
from repro.sharding.context import constrain


def _family_fns(cfg: ArchConfig):
    if cfg.family == "ssm":
        return tf.ssm_stack_init, tf.ssm_stack_apply
    if cfg.family == "hybrid":
        return tf.hybrid_init, tf.hybrid_apply
    return tf.decoder_init, tf.decoder_apply


def init(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = cfg.param_dtype_()
    ks = jax.random.split(key, 5)
    stack_init, _ = _family_fns(cfg)
    p: Dict[str, Any] = {
        "stack": stack_init(ks[0], cfg, dtype),
        "final_norm": norm_init(cfg.d_model, dtype, cfg.norm),
    }
    if cfg.modality == "audio_frames":
        p["mask_emb"] = jax.random.normal(ks[1], (cfg.d_model,), dtype) * 0.02
        p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded, dtype)
    else:
        p["embed"] = embed_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded, dtype)
    if cfg.mtp:
        p["mtp_proj"] = dense_init(ks[3], cfg.d_model, cfg.d_model, dtype)
        p["mtp_norm"] = norm_init(cfg.d_model, dtype, cfg.norm)
    return p


def _sinusoidal(t: int, d: int, dtype):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[:, :d]
    return pe.astype(dtype)


def _head_weight(p, cfg: ArchConfig, dtype):
    if cfg.modality != "audio_frames" and cfg.tie_embeddings:
        return p["embed"]["table"].astype(dtype).T       # (d, Vp)
    return p["head"]["w"].astype(dtype)


def _embed_inputs(p, batch: Dict, cfg: ArchConfig):
    """Returns (h0 (B,T,d), positions (B,T), text_offset)."""
    cd = cfg.compute_dtype_()
    if cfg.modality == "audio_frames":
        h = batch["frames"].astype(cd)
        if "mask" in batch:  # masked-prediction training
            h = jnp.where(batch["mask"][..., None],
                          p["mask_emb"].astype(cd)[None, None], h)
        b, t = h.shape[:2]
        off = 0
    else:
        emb = p["embed"]["table"].astype(cd)
        h = emb[batch["tokens"]]
        if cfg.modality == "vision_text":
            v = batch["vision_embeds"].astype(cd)
            h = jnp.concatenate([v, h], axis=1)
            off = v.shape[1]
        else:
            off = 0
        b, t = h.shape[:2]
    if cfg.pos_embed == "sinusoidal":
        h = h + _sinusoidal(t, cfg.d_model, cd)[None]
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    h = constrain(h, "batch", None, None)
    return h, positions, off


def _chunked_ce(h, head_w, targets, mask, cfg: ArchConfig,
                n_chunks: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy without materializing (B,T,V) logits.

    h (B,T,d); targets/mask (B,T).  Returns (sum_loss, sum_mask)."""
    b, t, d = h.shape
    nc = n_chunks if t % n_chunks == 0 else 1
    tc = t // nc
    vp = head_w.shape[-1]
    vmask = (jnp.arange(vp) < cfg.vocab_size)

    hs = h.reshape(b, nc, tc, d).transpose(1, 0, 2, 3)
    tg = targets.reshape(b, nc, tc).transpose(1, 0, 2)
    mk = mask.reshape(b, nc, tc).transpose(1, 0, 2)

    def body(carry, xs):
        hc, tgc, mkc = xs
        logits = jnp.einsum("btd,dv->btv", hc, head_w.astype(hc.dtype)
                            ).astype(jnp.float32)
        logits = jnp.where(vmask[None, None], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tgc[..., None], -1)[..., 0]
        loss = jnp.sum((lse - ll) * mkc)
        return (carry[0] + loss, carry[1] + jnp.sum(mkc)), None

    # checkpoint: backward recomputes per-chunk logits rather than the scan
    # saving them stacked (which would materialize the full (B,T,V) logits)
    (loss, denom), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.float32(0), jnp.float32(0)), (hs, tg, mk))
    return loss, denom


def loss_fn(params, batch: Dict, cfg: ArchConfig,
            backend: Backend = XLA) -> Tuple[jnp.ndarray, Dict]:
    """Scalar training loss + metrics for any family/modality."""
    _, stack_apply = _family_fns(cfg)
    h, positions, off = _embed_inputs(params, batch, cfg)
    causal = not cfg.encoder_only
    h, _, aux = stack_apply(params["stack"], h, cfg, positions=positions,
                            caches=None, backend=backend, causal=causal)
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)

    cd = cfg.compute_dtype_()
    head_w = _head_weight(params, cfg, cd)

    if cfg.modality == "audio_frames":
        targets = batch["targets"]
        mask = batch["mask"].astype(jnp.float32)
        ht = h
    else:
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(targets, jnp.float32) if mask is None \
            else mask.astype(jnp.float32)
        ht = h[:, off:] if off else h                       # text positions

    loss_sum, denom = _chunked_ce(ht, head_w, targets, mask, cfg)
    loss = loss_sum / jnp.maximum(denom, 1.0)
    metrics = {"ce": loss, "aux": aux, "tokens": denom}

    if cfg.mtp:
        # multi-token prediction: predict t+2 from a projected hidden state
        h2 = apply_norm(params["mtp_norm"],
                        dense(params["mtp_proj"], ht, backend), cfg.norm_eps)
        t2 = jnp.roll(targets, -1, axis=1)
        m2 = mask * (jnp.arange(targets.shape[1]) <
                     targets.shape[1] - 1).astype(jnp.float32)[None]
        l2, d2 = _chunked_ce(h2, head_w, t2, m2, cfg)
        mtp = l2 / jnp.maximum(d2, 1.0)
        loss = loss + 0.3 * mtp
        metrics["mtp"] = mtp

    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_caches(cfg: ArchConfig, batch: int, length: int):
    dtype = cfg.compute_dtype_()
    if cfg.family == "ssm":
        return tf.ssm_make_states(cfg, batch, dtype)
    if cfg.family == "hybrid":
        return tf.hybrid_make_caches(cfg, batch, length, dtype)
    return tf.decoder_make_caches(cfg, batch, length, dtype)


def prefill(params, batch: Dict, cfg: ArchConfig, cache_len: int,
            backend: Backend = XLA) -> Tuple[jnp.ndarray, Any]:
    """Encode the prompt, fill caches, return last-position logits."""
    _, stack_apply = _family_fns(cfg)
    h, positions, _ = _embed_inputs(params, batch, cfg)
    b = h.shape[0]
    caches = make_caches(cfg, b, cache_len)
    causal = not cfg.encoder_only
    h, caches, _ = stack_apply(params["stack"], h, cfg, positions=positions,
                               caches=caches, backend=backend, causal=causal,
                               remat=False)
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)
    cd = cfg.compute_dtype_()
    logits = (h[:, -1] @ _head_weight(params, cfg, cd)).astype(jnp.float32)
    logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                       logits, -1e30)
    return logits, caches


def decode_step(params, tokens, positions, caches, cfg: ArchConfig,
                backend: Backend = XLA) -> Tuple[jnp.ndarray, Any]:
    """One token per sequence.  tokens (B,1) int32, positions (B,) int32."""
    _, stack_apply = _family_fns(cfg)
    cd = cfg.compute_dtype_()
    h = params["embed"]["table"].astype(cd)[tokens]          # (B,1,d)
    if cfg.pos_embed == "sinusoidal":
        raise NotImplementedError("encoder-only archs have no decode step")
    pos2 = positions[:, None]
    h, caches, _ = stack_apply(params["stack"], h, cfg, positions=pos2,
                               caches=caches, backend=backend, causal=True,
                               remat=False)
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0] @ _head_weight(params, cfg, cd)).astype(jnp.float32)
    logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                       logits, -1e30)
    return logits, caches


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
