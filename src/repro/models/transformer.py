"""Blocks and stacks: decoder/encoder transformer, MoE, SSM, Zamba2 hybrid.

All stacks scan over layer-stacked parameters (compact HLO at 61-80 layers)
with optional per-layer remat.  Decode caches are layer-stacked pytrees
threaded through the same scans.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Backend, XLA, apply_norm, dense, dense_init, mlp, mlp_init, norm_init,
)
from repro.sharding.context import constrain


def constrain_sp(h):
    return constrain(h, "batch", "model", None)


def _remat_policy(cfg: ArchConfig):
    """'full': recompute everything in backward (min memory).  'dots': save
    matmul outputs — backward re-runs neither the forward GEMMs nor the
    forward collectives, trading memory for the dominant roofline terms.
    'save_collectives': save only the post-all-reduce block outputs (two
    d-sized tensors per layer) so the backward recompute never re-runs the
    forward collectives — the memory-term price of 'dots' without saving
    the f-sized hidden tensors."""
    if cfg.policy.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if cfg.policy.remat_policy == "save_collectives":
        return jax.checkpoint_policies.save_only_these_names("blk_out")
    return None


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# attention + (mlp | moe) block
# ---------------------------------------------------------------------------


def block_init(key, cfg: ArchConfig, dtype, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg.d_model, dtype, cfg.norm),
         "ln2": norm_init(cfg.d_model, dtype, cfg.norm)}
    if cfg.mla is not None:
        p["attn"] = attn_mod.mla_init(k1, cfg, dtype)
    else:
        p["attn"] = attn_mod.attn_init(k1, cfg, dtype)
    if use_moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def block_apply(p, h, cfg: ArchConfig, *, positions, cache=None,
                backend: Backend = XLA, causal=True):
    x = apply_norm(p["ln1"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = attn_mod.mla_apply(p["attn"], x, cfg,
                                          positions=positions, cache=cache,
                                          backend=backend)
    else:
        a, new_cache = attn_mod.attention_apply(
            p["attn"], x, cfg, positions=positions, cache=cache,
            backend=backend, causal=causal)
    a = jax.ad_checkpoint.checkpoint_name(a, "blk_out")
    h = h + a
    x = apply_norm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_mod.moe_apply(p["moe"], x, cfg, backend)
    else:
        y, aux = mlp(p["mlp"], x, cfg.act, backend,
                     policy=cfg.policy), jnp.float32(0)
    h = h + jax.ad_checkpoint.checkpoint_name(y, "blk_out")
    if cfg.policy.sp and h.shape[1] > 1:
        # sequence-parallel residual stream: the per-layer saved residual
        # stack shards its seq dim over 'model' (Megatron-SP posture); XLA
        # inserts the all-gather at the next block's attention
        h = constrain_sp(h)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# uniform stack (dense / vlm / audio / moe-with-leading-dense)
# ---------------------------------------------------------------------------


def _scan_blocks(params_stack, h, cfg, *, positions, caches, backend, causal,
                 remat: bool):
    fn = functools.partial(block_apply, cfg=cfg, positions=positions,
                           backend=backend, causal=causal)

    def body(carry, xs):
        p, c = xs
        out, nc, aux = fn(p, carry, cache=c)
        return out, (nc, aux)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False,
                              policy=_remat_policy(cfg))
    h, (new_caches, auxs) = jax.lax.scan(body, h, (params_stack, caches))
    return h, new_caches, jnp.sum(auxs)


def decoder_init(key, cfg: ArchConfig, dtype):
    """Transformer decoder (and encoder — causal flag at apply time)."""
    fd = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    fd = min(fd, cfg.n_layers)
    nm = cfg.n_layers - fd
    k1, k2 = jax.random.split(key)
    p = {}
    if fd:
        p["dense_stack"] = _stack_init(
            k1, fd, lambda k: block_init(k, cfg, dtype, use_moe=False))
    if nm:
        p["moe_stack"] = _stack_init(
            k2, nm, lambda k: block_init(k, cfg, dtype, use_moe=True))
    return p


def decoder_make_caches(cfg: ArchConfig, batch: int, length: int, dtype):
    fd = min(cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers,
             cfg.n_layers)
    nm = cfg.n_layers - fd
    mk = (attn_mod.mla_make_cache if cfg.mla is not None
          else attn_mod.make_cache)
    c = {}
    if fd:
        c["dense_stack"] = mk(cfg, batch, length, dtype, layers=fd)
    if nm:
        c["moe_stack"] = mk(cfg, batch, length, dtype, layers=nm)
    return c


def decoder_apply(p, h, cfg: ArchConfig, *, positions, caches=None,
                  backend: Backend = XLA, causal=True, remat=None):
    remat = cfg.policy.remat if remat is None else remat
    new_caches, aux = {}, jnp.float32(0)
    for name in ("dense_stack", "moe_stack"):
        if name not in p:
            continue
        n = jax.tree_util.tree_leaves(p[name])[0].shape[0]
        cs = caches.get(name) if caches else _none_stack(n)
        h, nc, a = _scan_blocks(p[name], h, cfg, positions=positions,
                                caches=cs, backend=backend, causal=causal,
                                remat=remat and caches is None)
        if caches is not None:
            new_caches[name] = nc
        aux = aux + a
    return h, (new_caches if caches is not None else None), aux


def _none_stack(n: int):
    return None


# ---------------------------------------------------------------------------
# SSM stack (mamba2)
# ---------------------------------------------------------------------------


def ssm_stack_init(key, cfg: ArchConfig, dtype):
    def one(k):
        kk = jax.random.split(k, 2)
        return {"ln": norm_init(cfg.d_model, dtype, cfg.norm),
                "mamba": ssm_mod.mamba_init(kk[0], cfg, dtype)}
    return {"ssm_stack": _stack_init(key, cfg.n_layers, one)}


def ssm_make_states(cfg: ArchConfig, batch: int, dtype):
    return {"ssm_stack": ssm_mod.mamba_make_state(cfg, batch, dtype,
                                                  layers=cfg.n_layers)}


def ssm_stack_apply(p, h, cfg: ArchConfig, *, positions, caches=None,
                    backend: Backend = XLA, remat=None, **_):
    remat = cfg.policy.remat if remat is None else remat

    def body(carry, xs):
        lp, st = xs
        x = apply_norm(lp["ln"], carry, cfg.norm_eps)
        y, ns = ssm_mod.mamba_apply(lp["mamba"], x, cfg, state=st,
                                    backend=backend)
        return carry + y, ns

    if remat and caches is None:
        body = jax.checkpoint(body, prevent_cse=False)
    cs = caches["ssm_stack"] if caches else None
    h, ns = jax.lax.scan(body, h, (p["ssm_stack"], cs))
    return h, ({"ssm_stack": ns} if caches is not None else None), jnp.float32(0)


# ---------------------------------------------------------------------------
# Zamba2 hybrid: mamba backbone + shared attention blocks every k layers
# ---------------------------------------------------------------------------


def hybrid_init(key, cfg: ArchConfig, dtype):
    hy = cfg.hybrid
    d = cfg.d_model
    every = hy.shared_every
    groups = cfg.n_layers // every
    tail = cfg.n_layers % every
    ks = jax.random.split(key, 6)

    def mamba_one(k):
        return {"ln": norm_init(d, dtype, cfg.norm),
                "mamba": ssm_mod.mamba_init(k, cfg, dtype)}

    def shared_one(k):
        kk = jax.random.split(k, 3)
        return {
            "in_proj": dense_init(kk[0], 2 * d, d, dtype),
            "block": block_init(kk[1], cfg, dtype, use_moe=False),
        }

    p = {
        "groups": _stack_init(ks[0], groups * every, mamba_one),
        "shared": _stack_init(ks[1], hy.n_shared_blocks, shared_one),
        # per-application LoRA on the shared input projection
        "lora_a": jax.random.normal(ks[2], (groups, 2 * d, hy.lora_rank),
                                    dtype) * (2 * d) ** -0.5,
        "lora_b": jnp.zeros((groups, hy.lora_rank, d), dtype),
    }
    if tail:
        p["tail"] = _stack_init(ks[3], tail, mamba_one)
    return p


def hybrid_make_caches(cfg: ArchConfig, batch: int, length: int, dtype):
    hy = cfg.hybrid
    groups = cfg.n_layers // hy.shared_every
    tail = cfg.n_layers % hy.shared_every
    c = {
        "groups": ssm_mod.mamba_make_state(cfg, batch, dtype,
                                           layers=groups * hy.shared_every),
        "shared_kv": attn_mod.make_cache(cfg, batch, length, dtype,
                                         layers=groups),
    }
    if tail:
        c["tail"] = ssm_mod.mamba_make_state(cfg, batch, dtype, layers=tail)
    return c


def hybrid_apply(p, h, cfg: ArchConfig, *, positions, caches=None,
                 backend: Backend = XLA, remat=None, **_):
    """Outer scan over groups; each group = ``shared_every`` mamba layers +
    one application of a shared attention block (round-robin over the
    distinct shared blocks, with per-application LoRA on its input proj)."""
    hy = cfg.hybrid
    remat = cfg.policy.remat if remat is None else remat
    every = hy.shared_every
    groups = cfg.n_layers // every
    e0 = h                                                   # original embeds

    gp = jax.tree.map(
        lambda x: x.reshape(groups, every, *x.shape[1:]), p["groups"])
    gc = (jax.tree.map(lambda x: x.reshape(groups, every, *x.shape[1:]),
                       caches["groups"]) if caches else None)
    kvc = caches["shared_kv"] if caches else None
    shared_ids = jnp.arange(groups) % hy.n_shared_blocks

    def mamba_body(carry, xs):
        lp, st = xs
        x = apply_norm(lp["ln"], carry, cfg.norm_eps)
        y, ns = ssm_mod.mamba_apply(lp["mamba"], x, cfg, state=st,
                                    backend=backend)
        return carry + y, ns

    def group_body(carry, xs):
        hcur = carry
        glp, gst, la, lb, sid, kv = xs
        hcur, gns = jax.lax.scan(mamba_body, hcur, (glp, gst))
        sp = jax.tree.map(lambda x: x[sid], p["shared"])
        cat = jnp.concatenate([hcur, jnp.broadcast_to(e0, hcur.shape)], -1)
        w = sp["in_proj"]["w"].astype(cat.dtype) + (
            la.astype(cat.dtype) @ lb.astype(cat.dtype))
        xin = cat @ w
        y, nkv, _ = block_apply(sp["block"], xin, cfg, positions=positions,
                                cache=kv, backend=backend, causal=True)
        return hcur + (y - xin), (gns, nkv)   # residual on the block's delta

    if remat and caches is None:
        group_body = jax.checkpoint(group_body, prevent_cse=False)

    h, (gns, nkv) = jax.lax.scan(
        group_body, h,
        (gp, gc if gc is not None else None, p["lora_a"], p["lora_b"],
         shared_ids, kvc))
    new_caches = None
    if caches is not None:
        new_caches = {
            "groups": jax.tree.map(
                lambda x: x.reshape(groups * every, *x.shape[2:]), gns),
            "shared_kv": nkv,
        }
    if "tail" in p:
        tc = caches["tail"] if caches else None
        body = mamba_body
        if remat and caches is None:
            body = jax.checkpoint(mamba_body, prevent_cse=False)
        h, tns = jax.lax.scan(body, h, (p["tail"], tc))
        if caches is not None:
            new_caches["tail"] = tns
    return h, new_caches, jnp.float32(0)
