"""Mixture-of-Experts with grouped one-hot dispatch (Switch/T5X style).

Tokens are split into small groups (dim G, sharded over the DP axes); each
group dispatches into a per-group, per-expert capacity buffer via one-hot
einsums.  This formulation shards cleanly under SPMD:

   combine  (G, S, E, C)    G on 'data'   (E on 'model' for EP)
   buffers  (E, G, C, d)    the G<->E transpose IS the EP all-to-all

unlike scatter-based dispatch, whose arbitrary flat indices force the
partitioner to replicate the buffer.  Dispatch-einsum overhead is
2*s*E*C_g*d FLOPs ~ a few % of expert compute for C_g ~ 1.25*S*k/E.

Sharding modes (cfg.moe.sharding):
  * "ep": experts shard 'model' (E % 16 == 0; deepseek).
  * "tp": expert-internal tensor parallelism (mixtral: 8 experts on a
    16-way axis); buffers stay token-sharded, expert d_ff shards 'model'.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Backend, XLA, dense_init, mlp, mlp_init, out_constrain
from repro.sharding.context import constrain

GROUP_SIZE = 256


def moe_init(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = d ** -0.5

    def expert_bank(k):
        k1, k2, k3 = jax.random.split(k, 3)
        e, f = m.num_experts, m.d_ff_expert
        return {
            "wi": jax.random.normal(k1, (e, d, f), dtype) * scale,
            "wg": jax.random.normal(k2, (e, d, f), dtype) * scale,
            "wo": jax.random.normal(k3, (e, f, d), dtype) * f ** -0.5,
        }

    p = {"router": dense_init(ks[0], d, m.num_experts, dtype),
         "experts": expert_bank(ks[1])}
    if m.n_shared:
        p["shared"] = mlp_init(ks[2], d, m.d_ff_expert * m.n_shared,
                               cfg.act, dtype)
    return p


def _group(s: int, target: int = GROUP_SIZE) -> int:
    g = max(1, s // target)
    while s % g:
        g -= 1
    return g


def moe_apply(p, x, cfg: ArchConfig,
              backend: Backend = XLA) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,T,d) -> (y, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    s = b * t
    e, k = m.num_experts, m.top_k
    g = _group(s)
    sg = s // g
    cap = max(int(m.capacity_factor * sg * k / e), 1)
    ep = m.sharding == "ep"

    xg = x.reshape(g, sg, d)
    xg = constrain(xg, "batch", None, None)
    logits = (xg @ p["router"]["w"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)                     # (G,S,E)
    gate_vals, idx = jax.lax.top_k(probs, k)               # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)

    # load-balance auxiliary (Switch-style), computed pre-drop
    frac = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1, 2))
    aux = m.aux_loss_weight * e * jnp.sum(frac * probs.mean((0, 1)))

    # position-in-expert with earlier top-k choices taking priority.
    # NOTE (known, standard artifact): capacity dropping is *not causal* at
    # train time — whether a token keeps its slot depends on other tokens in
    # the group, including later positions (the k-th-choice offset counts
    # the whole group's earlier-choice acceptances, as in Switch/T5X).
    # Decode has no future tokens, so serving is unaffected; see
    # tests/test_model_properties.py::test_causality (MoE runs with ample
    # capacity to assert causality of the *network* itself).
    # combine/dispatch ride in the compute dtype (bf16): their cotangents
    # are what the EP backward all-reduces — f32 here doubles that term
    cdt = x.dtype
    combine = jnp.zeros((g, sg, e, cap), cdt)
    base = jnp.zeros((g, 1, e), jnp.float32)
    for i in range(k):
        oh = jax.nn.one_hot(idx[..., i], e, dtype=jnp.float32)  # (G,S,E)
        pos = jnp.cumsum(oh, axis=1) - oh + base
        ok = (pos < cap).astype(jnp.float32) * oh
        slot = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                              dtype=cdt)                       # (G,S,E,C)
        combine = combine + (gate_vals[..., i, None, None].astype(cdt)
                             * (ok[..., None].astype(cdt) * slot))
        base = base + ok.sum(1, keepdims=True)   # accepted so far per expert
    combine = constrain(combine, "batch", None,
                        "model" if ep else None, None)
    dispatch = (combine > 0).astype(x.dtype)

    # dispatch: (G,S,E,C) x (G,S,d) -> (E,G,C,d) — the EP all-to-all
    buf = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    buf = constrain(buf, "model" if ep else None, "batch", None, None)

    w = p["experts"]
    h = jnp.einsum("egcd,edf->egcf", buf, w["wi"].astype(x.dtype))
    hg = jnp.einsum("egcd,edf->egcf", buf, w["wg"].astype(x.dtype))
    h = jax.nn.silu(hg) * h
    h = constrain(h, "model" if ep else None, "batch", None,
                  None if ep else "model")
    out = jnp.einsum("egcf,efd->egcd", h, w["wo"].astype(x.dtype))
    out = constrain(out, "model" if ep else None, "batch", None, None)

    y = jnp.einsum("gsec,egcd->gsd", combine, out)
    y = y.reshape(b, t, d)
    if m.n_shared:
        y = y + mlp(p["shared"], x, cfg.act, backend, policy=cfg.policy)
    return out_constrain(y, cfg.policy), aux
