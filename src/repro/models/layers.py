"""Shared neural layers, functional style: init(key,...) -> pytree,
apply(params, x, ...) -> y.  All matmuls route through the backend so the
paper's AME GEMM path is a first-class, swappable substrate."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding.context import constrain


@dataclasses.dataclass(frozen=True)
class Backend:
    """Routes dense compute: 'xla' (einsum; used for dry-run lowering) or
    'pallas' (the AME output-stationary kernels, interpret on CPU)."""

    mode: str = "xla"

    def matmul(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """(..., K) @ (K, N) with f32 accumulation."""
        if self.mode == "pallas":
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            return ops.gemm(x2, w, use_pallas=True,
                            out_dtype=x.dtype).reshape(*lead, w.shape[-1])
        return jnp.matmul(x, w, preferred_element_type=jnp.float32
                          ).astype(x.dtype)


XLA = Backend("xla")
PALLAS = Backend("pallas")


# -- dense -------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, backend: Backend = XLA):
    y = backend.matmul(x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# -- norms -------------------------------------------------------------------


def norm_init(d: int, dtype, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:            # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- rotary ------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., T, H, D) rotated by position.  positions (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -- mlp ---------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, d_ff, dtype),
                "wg": dense_init(k2, d, d_ff, dtype),
                "wo": dense_init(k3, d_ff, d, dtype)}
    return {"wi": dense_init(k1, d, d_ff, dtype),
            "wo": dense_init(k2, d_ff, d, dtype)}


def out_constrain(y, policy):
    """Block-output sharding per TP dataflow:

    * allgather (the paper's reduction-free dataflow) and ame_pim (the
      PIM-cluster flavor sharing its mesh posture): stay feature-sharded
      on 'model' — no partial-sum reduction exists on the model axis.
    * allreduce + SP: constrain straight to the seq-sharded residual layout
      so SPMD emits a reduce-scatter (S link bytes) instead of all-reduce
      (2S) followed by a slice.
    * allreduce: replicate => the Megatron all-reduce.
    """
    from repro.configs.base import OUTPUT_SHARDED_TP_MODES
    if policy.tp_mode in OUTPUT_SHARDED_TP_MODES:
        return constrain(y, "batch", None, "model")
    if policy.sp and policy.sp_rs and y.ndim == 3 and y.shape[1] > 1:
        return constrain(y, "batch", "model", None)
    return constrain(y, "batch", None, None)


def mlp(p, x, act: str, backend: Backend = XLA, tp_mode: str = "allreduce",
        policy=None):
    """Gated/plain MLP.  Sharding posture depends on the TP dataflow —
    see :func:`out_constrain`."""
    from repro.configs.base import Policy
    policy = policy or Policy(tp_mode=tp_mode)
    h = dense(p["wi"], x, backend)
    if act == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x, backend)) * h
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["wg"], x, backend)) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "model")
    y = dense(p["wo"], h, backend)
    return out_constrain(y, policy)


# -- embedding ----------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * d ** -0.5}


def embed(p, tokens, compute_dtype):
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p, x, backend: Backend = XLA):
    """Logits against the (possibly tied) embedding table."""
    return backend.matmul(x, p["table"].astype(x.dtype).T)
