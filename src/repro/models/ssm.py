"""Mamba2 (SSD) block — the state-space arch whose recurrent state update is
the paper's outer-product accumulation (rank-1 updates into a resident
accumulator; DESIGN.md §5).

Train/prefill run the chunked SSD scan (kernels/ssd_scan or its jnp twin);
decode advances the recurrence one step with O(1) state:
  conv_state (B, d_conv-1, conv_dim), ssm_state (B, H, N, P).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import Backend, XLA, apply_norm, dense, dense_init, norm_init, out_constrain
from repro.sharding.context import constrain


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nheads, conv_dim


def mamba_init(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nheads  # z,x,B,C,dt
    return {
        "in_proj": dense_init(ks[0], d, d_proj, dtype),
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_dim), dtype)
        * s.d_conv ** -0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((nheads,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": norm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype),
    }


def _split(cfg: ArchConfig, proj):
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xc, bc, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn], -1)
    return z, xc, bc, cc, dt


def mamba_make_state(cfg: ArchConfig, batch: int, dtype,
                     layers: Optional[int] = None) -> Dict:
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    cs = (batch, s.d_conv - 1, conv_dim)
    ss = (batch, nheads, s.d_state, s.head_dim)
    if layers is not None:
        cs, ss = (layers,) + cs, (layers,) + ss
    return {"conv": jnp.zeros(cs, dtype), "ssm": jnp.zeros(ss, jnp.float32)}


def mamba_apply(p, u, cfg: ArchConfig, *, state: Optional[Dict] = None,
                backend: Backend = XLA) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """u (B,T,d).  state=None: full-sequence scan (train/prefill).
    state given with T==1: single recurrent decode step."""
    s = cfg.ssm
    b, t, d = u.shape
    d_inner, nheads, conv_dim = _dims(cfg)
    g, n, hp = s.n_groups, s.d_state, s.head_dim
    proj = dense(p["in_proj"], u, backend)
    z, xc, bc, cc, dt = _split(cfg, proj)
    xbc = jnp.concatenate([xc, bc, cc], -1)                  # conv'd together

    new_state = None
    if state is None:
        pad = jnp.zeros((b, s.d_conv - 1, conv_dim), xbc.dtype)
        seq = jnp.concatenate([pad, xbc], 1)
    else:
        seq = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], 1)
        new_conv = seq[:, -(s.d_conv - 1):]
    # causal depthwise conv, width d_conv
    conv = sum(seq[:, i:i + t] * p["conv_w"][i].astype(xbc.dtype)
               for i in range(s.d_conv))
    conv = jax.nn.silu(conv + p["conv_b"].astype(xbc.dtype))
    xs, bs, cs_ = jnp.split(conv, [d_inner, d_inner + g * n], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                     # (B,T,H)
    log_a = -jnp.exp(p["a_log"])[None, None, :] * dt         # (B,T,H) <= 0
    xh = xs.reshape(b, t, nheads, hp)
    xh = constrain(xh, "batch", None, "model", None)
    bg = bs.reshape(b, t, g, n)
    cg = cs_.reshape(b, t, g, n)
    rep = nheads // g

    if state is None or t > 1:
        # chunked SSD over the whole sequence (heads batched)
        xdt = (xh * dt[..., None])
        bh_rep = jnp.repeat(bg, rep, 2)
        # 4-D (B,H,T,*) keeps heads a shardable 'model' axis — flattening
        # (B*H) would force replication or per-layer resharding
        x4 = constrain(xdt.transpose(0, 2, 1, 3), "batch", "model", None, None)
        la4 = constrain(log_a.transpose(0, 2, 1), "batch", "model", None)
        b4 = constrain(bh_rep.transpose(0, 2, 1, 3), "batch", "model", None,
                       None)
        c4 = constrain(jnp.repeat(cg, rep, 2).transpose(0, 2, 1, 3),
                       "batch", "model", None, None)
        y = ops.ssd4(x4, la4.astype(jnp.float32), b4, c4,
                     use_pallas=(backend.mode == "pallas"), chunk=s.chunk)
        y = constrain(y, "batch", "model", None, None)
        y = y.transpose(0, 2, 1, 3)                            # (B,T,H,P)
        if state is not None:
            # prefill: closed-form final state (log_a <= 0 so the cumulative
            # weights exp(cum_T - cum_t) never overflow):
            #   S = a_total * S_in + sum_t exp(cum_T - cum_t) b_t (x*dt)_t
            cum = jnp.cumsum(log_a.astype(jnp.float32), axis=1)  # (B,T,H)
            wts = jnp.exp(cum[:, -1:] - cum)                     # (B,T,H)
            s_new = jnp.einsum("bthn,bthp->bhnp",
                               bh_rep.astype(jnp.float32) * wts[..., None],
                               xdt.astype(jnp.float32))
            s_new = s_new + jnp.exp(cum[:, -1])[..., None, None] * state["ssm"]
            new_state = {"conv": new_conv.astype(state["conv"].dtype),
                         "ssm": s_new}
    else:
        # one-step recurrence: S = a*S + dt*x (outer) B ; y = C @ S
        ssm_prev = state["ssm"]                              # (B,H,N,P) f32
        a1 = jnp.exp(log_a[:, 0])                            # (B,H)
        bx = jnp.einsum(
            "bhn,bhp->bhnp", jnp.repeat(bg[:, 0], rep, 1).astype(jnp.float32),
            (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32))
        ssm_new = a1[..., None, None] * ssm_prev + bx
        ch = jnp.repeat(cg[:, 0], rep, 1).astype(jnp.float32)  # (B,H,N)
        y = jnp.einsum("bhn,bhnp->bhp", ch, ssm_new)[:, None]  # (B,1,H,P)
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "ssm": ssm_new}

    y = y.astype(u.dtype) + (p["d_skip"].astype(u.dtype)[None, None, :, None]
                             * xh)
    y = y.reshape(b, t, d_inner)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y, backend)
    return out_constrain(out, cfg.policy), new_state
