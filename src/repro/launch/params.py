"""Parameter accounting without allocation (eval_shape over init)."""
from __future__ import annotations

import math

import jax

from repro.configs.base import ArchConfig
from repro.models import model as lm


def param_shapes(cfg: ArchConfig):
    """Abstract pytree of parameter ShapeDtypeStructs (no allocation)."""
    return jax.eval_shape(lambda k: lm.init(cfg, k), jax.random.PRNGKey(0))


def count_params(cfg: ArchConfig) -> int:
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(param_shapes(cfg)))


def param_bytes(cfg: ArchConfig) -> int:
    return sum(math.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(param_shapes(cfg)))
