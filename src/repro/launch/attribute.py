import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
"""Profiling-by-static-analysis: attribute a cell's roofline terms to
instructions (the dry-run 'profiler' — there is no wall clock on CPU).

  PYTHONPATH=src python -m repro.launch.attribute --arch command-r-35b \
      --shape train_4k [--what coll|mem] [--top 15] [--set tp_mode=allgather]
"""
import argparse
import re

from repro.configs import SHAPES, get
from repro.launch import hloanalysis as ha


def apply_overrides(cfg, sets):
    for kv in sets or []:
        k, v = kv.split("=", 1)
        if v in ("True", "true", "False", "false"):
            v = v.lower() == "true"
        elif v.isdigit():
            v = int(v)
        cfg = cfg.with_policy(**{k: v})
    return cfg


def compile_cell(arch, shape, sets=None, mesh_kind="single"):
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as steps_mod
    cfg = apply_overrides(get(arch), sets)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    kind, fn, shapes, _ = steps_mod.make_step_for(cfg, mesh, SHAPES[shape])
    return fn.lower(*shapes).compile()


def attribute(hlo: str, what: str = "coll", top: int = 15):
    comps = ha.parse_hlo(hlo)
    mult = ha._multipliers(comps)
    seq = {comps["__entry__"].name} if "__entry__" in comps else set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in ha._SEQUENTIAL_CALLERS or ins.op == "while":
                for nm in ha._called_names(ins.line):
                    seq.add(nm)
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            base = ins.op.replace("-start", "")
            if what == "coll":
                if base in ha.COLLECTIVES and not ins.op.endswith("-done"):
                    kindc, link = ha._collective_link_bytes(ins)
                    if link:
                        rows.append((m * link, m, link, kindc, ins))
            else:
                if comp.name not in seq or ins.op in ha.NO_TRAFFIC_OPS \
                        or ins.op.endswith("-done"):
                    continue
                opds = ins.operands()
                if ins.op == "fusion" and len(opds) <= 1 and \
                        re.match(r"^(convert|copy)[._]", ins.name):
                    continue
                io = ins.out_bytes()
                sizes = []
                for opd in opds:
                    part = comp.shapes.get(opd)
                    if part:
                        s = sum(ha._shape_bytes(sm)
                                for sm in ha._SHAPE_RE.finditer(part))
                        sizes.append(s)
                        io += s
                if "dynamic-update-slice" in ins.op or \
                        ins.name.startswith("dynamic-update-slice"):
                    if sizes:
                        io = max(io - 2 * max(sizes), 0)
                if io:
                    rows.append((m * io, m, io, ins.op, ins))
    rows.sort(key=lambda r: -r[0])
    out = []
    for tot, m, each, kindc, ins in rows[:top]:
        mm = re.search(r'op_name="([^"]+)"', ins.line)
        opn = (mm.group(1) if mm else ins.name)[-100:]
        out.append(f"{tot/1e9:10.2f}GB  m={m:7.0f} each={each/1e6:9.2f}MB "
                   f"{kindc:16s} {opn}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--what", default="coll", choices=["coll", "mem"])
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()
    compiled = compile_cell(args.arch, args.shape, args.set)
    hlo = compiled.as_text()
    rep = ha.analyze(hlo)
    print(f"flops={rep.flops:.4g} hbm={rep.hbm_bytes:.4g} "
          f"link={rep.collective_link_bytes:.4g}")
    for line in attribute(hlo, args.what, args.top):
        print(line)


if __name__ == "__main__":
    main()
