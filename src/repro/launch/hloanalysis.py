"""Static analyzer for post-SPMD HLO: trip-count-aware FLOPs, HBM traffic,
and per-chip collective link bytes — the §Roofline term extractor.

Why not ``compiled.cost_analysis()``: XLA counts while-loop bodies ONCE,
but every layer scan / microbatch scan / KV-chunk scan is a counted loop.
We parse ``compiled.as_text()`` structurally instead:

* computations -> instructions (with a per-computation symbol table of
  operand shapes, so `dot` contraction sizes are resolvable);
* a call-graph walk (while/fusion/call/conditional/reduce/sort/scatter)
  propagates an execution multiplier, reading loop trip counts from the
  ``known_trip_count`` backend_config XLA attaches to counted loops;
* FLOPs: 2*prod(out)*prod(contracted) for dots; 1 op/elem for arithmetic
  elementwise/reduce ops;
* HBM bytes: for every *top-level* instruction in sequential computations
  (entry, loop bodies, branches), bytes = output + operand sizes — fusion
  boundaries are exactly the materialization points on TPU;
* collectives: ring-model link bytes x multiplier.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8,
               "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\]{},.\- ])*?)\s*([\w\-]+)\(")
# match only the computation name before its parameter list — params may
# contain tuple types with nested parens (every while body does)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLED_ONE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CALLED_MANY_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_names(line: str):
    names = [m.group(1) for m in _CALLED_ONE_RE.finditer(line)]
    for m in _CALLED_MANY_RE.finditer(line):
        names.extend(n.strip().lstrip("%") for n in m.group(1).split(","))
    return names
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "logistic",
    "floor", "ceil", "round-nearest-afz", "clamp", "select", "compare",
    "and", "or", "xor", "not", "remainder", "atan2", "cbrt", "erf",
}
REDUCE_OPS = {"reduce", "reduce-window"}
NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "rng-get-and-update-state", "opt-barrier", "custom-call",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems(m) -> int:
    dims = m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(m) -> int:
    return _shape_elems(m) * DTYPE_BYTES[m.group(1)]


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_part: str
    rest: str
    line: str

    def out_bytes(self) -> int:
        return sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(self.out_part))

    def out_elems(self) -> int:
        return sum(_shape_elems(m) for m in _SHAPE_RE.finditer(self.out_part))

    def out_dims(self) -> List[int]:
        m = _SHAPE_RE.search(self.out_part)
        if not m or not m.group(2):
            return []
        return [int(d) for d in m.group(2).split(",") if d]

    def operands(self) -> List[str]:
        m = re.search(rf"\b{re.escape(self.op)}\(", self.line)
        if not m:
            return []
        depth, args, cur = 0, [], []
        for ch in self.line[m.end() - 1:]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append("".join(cur))
                    break
            if depth >= 1:
                cur.append(ch)
        names = []
        for a in "".join(args).split(","):
            mm = re.search(r"%([\w.\-]+)", a)
            if mm:
                names.append(mm.group(1))
        return names


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]           # symbol -> out_part (type text)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and (line.startswith("ENTRY")
                                         or line.startswith("%")) \
                and line.endswith("{"):
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        om = _OP_RE.match(rhs)
        if om:
            out_part, op = om.group(1), om.group(2)
        else:
            # ops without parens are rare; classify as unknown
            out_part, op = rhs, "unknown"
        cur.instrs.append(Instr(name, op, out_part, rhs, line))
        cur.shapes[name] = out_part
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation via call-graph walk."""
    mult: Dict[str, float] = {}
    entry = comps.get("__entry__")
    if entry is None:
        return {}

    def visit(comp: Computation, m: float):
        if m <= mult.get(comp.name, 0):
            return
        mult[comp.name] = m
        for ins in comp.instrs:
            called = _called_names(ins.line)
            if not called:
                continue
            child_m = m
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                child_m = m * (int(tm.group(1)) if tm else 1)
            for cn in called:
                if cn in comps:
                    visit(comps[cn], child_m)

    visit(entry, 1.0)
    return mult


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = ins.out_dims()
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    ops = ins.operands()
    if not ops:
        return 0.0
    lhs_part = comp.shapes.get(ops[0], "")
    lm = _SHAPE_RE.search(lhs_part)
    if lm is None:
        return 2.0 * math.prod(out) if out else 0.0
    lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
    contracted = 1
    if cdims and cdims.group(1):
        for d in cdims.group(1).split(","):
            contracted *= lhs_dims[int(d)]
    return 2.0 * math.prod(out) * contracted if out else 0.0


def _collective_link_bytes(ins: Instr) -> Tuple[str, float]:
    op = ins.op.replace("-start", "")
    out_b = ins.out_bytes()
    in_m = _SHAPE_RE.finditer(ins.rest[ins.rest.find("("):]
                              if "(" in ins.rest else "")
    in_b = sum(_shape_bytes(m) for m in in_m)
    gm = _GROUPS_IOTA_RE.search(ins.line)
    if gm:
        n = int(gm.group(2))
    else:
        gl = _GROUPS_LIST_RE.search(ins.line)
        n = len(gl.group(1).split(",")) if gl else 1
    if n <= 1:
        return op, 0.0
    frac = (n - 1) / n
    if op == "all-reduce":
        return op, 2 * out_b * frac
    if op == "all-gather":
        return op, out_b * frac
    if op == "reduce-scatter":
        return op, max(in_b, out_b) * frac
    if op == "all-to-all":
        return op, out_b * frac
    return op, float(out_b)          # collective-permute


@dataclasses.dataclass
class HLOReport:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_link_bytes: float = 0.0
    #: link bytes with f32-promoted bf16 collectives counted at bf16 width —
    #: XLA CPU float-normalization promotes bf16 cross-replica reductions to
    #: f32 (convert -> collective -> convert); TPU runs them native bf16
    collective_link_bytes_bf16: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    unknown_trip_loops: int = 0
    n_instructions: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)


def _promoted_from_bf16(ins: Instr, comp: Computation,
                        comps: Dict[str, Computation]) -> bool:
    """True if this f32 collective's operand is a convert-from-bf16 (directly
    or as a fusion whose root is such a convert)."""
    if "f32[" not in ins.out_part:
        return False
    for opd in ins.operands():
        d = next((i for i in comp.instrs if i.name == opd), None)
        if d is None:
            continue
        if d.op == "convert":
            inner = d.rest[d.rest.find("("):]
            if "bf16[" in inner:
                return True
            # operand shape not inline: resolve via symbol table
            for o2 in d.operands():
                if "bf16[" in comp.shapes.get(o2, ""):
                    return True
        if d.op == "fusion":
            for cn in _called_names(d.line):
                fc = comps.get(cn)
                if fc and fc.instrs:
                    root = fc.instrs[-1]
                    if root.op == "convert":
                        for o2 in root.operands():
                            if "bf16[" in fc.shapes.get(o2, ""):
                                return True
    return False


#: computations reached via fusion/reduce/etc. whose instrs are *inside* a
#: kernel — they contribute flops but not top-level HBM traffic
_SEQUENTIAL_CALLERS = {"while", "conditional", "call", "async-start"}


def analyze(text: str) -> HLOReport:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    rep = HLOReport(collectives={op: {"count": 0, "link_bytes": 0.0}
                                 for op in COLLECTIVES})

    # classify computations: sequential (entry/loop bodies/branches/calls)
    # vs fused (fusion/reduce/sort/scatter bodies)
    seq = {comps["__entry__"].name} if "__entry__" in comps else set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in _SEQUENTIAL_CALLERS or ins.op == "while":
                for nm in _called_names(ins.line):
                    seq.add(nm)

    seen = set()
    for comp in comps.values():
        if comp.name in seen:
            continue
        seen.add(comp.name)
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        sequential = comp.name in seq
        for ins in comp.instrs:
            rep.n_instructions += 1
            op = ins.op
            if op == "dot":
                df = m * _dot_flops(ins, comp)
                rep.dot_flops += df
                rep.flops += df
            elif op in ELEMENTWISE_OPS:
                rep.flops += m * ins.out_elems()
            elif op in REDUCE_OPS:
                rep.flops += m * ins.out_elems()
            elif op == "while" and not _TRIP_RE.search(ins.line):
                rep.unknown_trip_loops += 1
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                kind, link = _collective_link_bytes(ins)
                rep.collectives[kind]["count"] += m
                rep.collectives[kind]["link_bytes"] += m * link
                rep.collective_link_bytes += m * link
                corr = 0.5 if _promoted_from_bf16(ins, comp, comps) else 1.0
                rep.collective_link_bytes_bf16 += m * link * corr
            if sequential and op not in NO_TRAFFIC_OPS \
                    and not op.endswith("-done"):
                # CPU-only float-normalization artifacts: single-operand
                # convert/copy-of-bf16 fusions would not exist on TPU
                opds = ins.operands()
                if op == "fusion" and len(opds) <= 1 and \
                        re.match(r"^(convert|copy)[._]", ins.name):
                    continue
                io = ins.out_bytes()
                sizes = []
                for opd in opds:
                    part = comp.shapes.get(opd)
                    if part:
                        s = sum(_shape_bytes(sm)
                                for sm in _SHAPE_RE.finditer(part))
                        sizes.append(s)
                        io += s
                # in-place cache/carry updates: a dynamic-update-slice (or a
                # fusion rooted in one — scan-carry writes) reads and writes
                # only the updated slot, not the whole buffer (XLA aliases
                # the operand); drop the 2x full-buffer count
                if ("dynamic-update-slice" in ins.op
                        or ins.name.startswith("dynamic-update-slice")
                        or "dynamic_update_slice" in ins.line):
                    if sizes:
                        io = max(io - 2 * max(sizes), 0)
                rep.hbm_bytes += m * io
    return rep


def analyze_compiled(compiled) -> HLOReport:
    return analyze(compiled.as_text())
