"""Analytic MODEL_FLOPS per cell: 6*N*D train / 2*N*D inference, with
N_active for MoE — the §Roofline 'useful compute' yardstick."""
from __future__ import annotations

import math

import jax

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.params import param_shapes


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def active_params(cfg: ArchConfig) -> int:
    """Non-embedding parameters, with routed experts scaled by top_k/E."""
    shapes = param_shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        pstr = _path_str(path)
        n = math.prod(leaf.shape)
        if "embed/table" in pstr or "head/w" in pstr:
            continue
        if cfg.moe and "experts/" in pstr:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Whole-step useful FLOPs (all chips)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.modality == "vision_text":
            tokens = shape.global_batch * shape.seq_len  # patches+text = seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch        # decode: one token per seq
