"""Production mesh construction.

Single pod:  (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:   (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
data parallelism over DCN with compressed gradient sync (optim/compression).

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (sets "
            "--xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU tests (requires host-device override in conftest
    subprocess or few devices)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
