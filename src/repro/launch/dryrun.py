import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  Everything below is ordinary code.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and extracts the §Roofline
inputs:  cost_analysis FLOPs/bytes + collective bytes parsed from the
partitioned HLO.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import gc
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, all_names, applicable, get
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod
from repro.launch import hloanalysis

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             tp_mode: str | None = None) -> dict:
    cfg = get(arch)
    if tp_mode:
        cfg = cfg.with_policy(tp_mode=tp_mode)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": why}
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "tp_mode": cfg.policy.tp_mode}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        kind, fn, shapes, _specs = steps_mod.make_step_for(cfg, mesh, shape)
        rec["step"] = kind
        t0 = time.time()
        lowered = fn.lower(*shapes)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        # modern jax returns a list of per-computation dicts (older
        # releases returned the dict directly); normalize to one dict
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        rec["xla_flops_body_once"] = float(ca.get("flops", 0.0))
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
            "peak_bytes_per_device": (
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)),
        }
        hlo = compiled.as_text()
        # trip-count-aware static analysis (launch/hloanalysis.py):
        # per-device FLOPs, fusion-boundary HBM traffic, ring-model link bytes
        rep = hloanalysis.analyze(hlo)
        rec["flops"] = rep.flops
        rec["dot_flops"] = rep.dot_flops
        rec["hlo_bytes"] = rep.hbm_bytes
        rec["collectives"] = dict(
            rep.collectives,
            total_link_bytes=rep.collective_link_bytes,
            total_link_bytes_bf16=rep.collective_link_bytes_bf16)
        rec["unknown_trip_loops"] = rep.unknown_trip_loops
        rec["hlo_lines"] = hlo.count("\n")
        rec["ok"] = True
        del compiled, lowered, fn
        gc.collect()
    except Exception as e:  # a failure here is a bug in the system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def cell_path(arch, shape, mesh_kind, tp_mode=None) -> Path:
    tag = f".{tp_mode}" if tp_mode else ""
    return RESULTS_DIR / f"{arch}.{shape}.{mesh_kind}{tag}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tp-mode", default=None,
                    choices=[None, "allreduce", "allgather", "ame_pim"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in all_names() for s in SHAPES]
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            out = cell_path(arch, shape, mk, args.tp_mode)
            if out.exists() and not args.force:
                rec = json.loads(out.read_text())
                status = ("SKIP " + rec.get("skipped", "")) if "skipped" in rec \
                    else ("ok" if rec.get("ok") else "FAIL(cached)")
                print(f"[cached] {arch} {shape} {mk}: {status}")
                failures += int(not rec.get("ok", True) and "skipped" not in rec)
                continue
            rec = run_cell(arch, shape, mk, args.tp_mode)
            out.write_text(json.dumps(rec, indent=1))
            if "skipped" in rec:
                print(f"{arch} {shape} {mk}: SKIP ({rec['skipped']})")
            elif rec["ok"]:
                mem = rec["memory"]["peak_bytes_per_device"] / 2 ** 30
                print(f"{arch} {shape} {mk}: ok  {rec['step']} "
                      f"flops={rec['flops']:.3g} mem/dev={mem:.2f}GiB "
                      f"link={rec['collectives']['total_link_bytes']:.3g}B "
                      f"(compile {rec['compile_s']}s)")
            else:
                failures += 1
                print(f"{arch} {shape} {mk}: FAILED  {rec['error']}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
