"""Analytic per-device memory model (dtype-true).

The CPU dry-run's memory_analysis() is an upper bound: XLA's CPU float
normalization materializes f32 copies of bf16 buffers and the CPU scheduler
overlaps leaf updates.  This model computes what the same program holds on
a real TPU: parameters + gradients + optimizer moments (int8/factored
aware) + the saved residual stack + decode caches, all divided by their
actual shard counts.
"""
from __future__ import annotations

import math
from typing import Dict

import jax

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.params import param_shapes
from repro.sharding import rules
from repro.launch import hw


def _shards(spec, mesh_axes: Dict[str, int]) -> int:
    n = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= mesh_axes.get(a, 1)
    return n


def estimate(cfg: ArchConfig, shape: ShapeSpec,
             mesh_axes: Dict[str, int] | None = None) -> Dict[str, float]:
    mesh_axes = mesh_axes or {"data": 16, "model": 16}
    chips = math.prod(mesh_axes.values())

    class _FakeMesh:  # duck-typed for rules._axis_size / _fits
        def __init__(self, axes):
            self.shape = axes
            self.axis_names = tuple(axes)

    mesh = _FakeMesh(mesh_axes)
    shapes = param_shapes(cfg)
    pspecs = rules.param_pspecs(cfg, shapes, mesh)  # type: ignore[arg-type]

    pb = 0.0
    for leaf, spec in zip(jax.tree_util.tree_leaves(shapes),
                          jax.tree_util.tree_leaves(
                              pspecs, is_leaf=lambda x: hasattr(x, "index"))):
        pb += math.prod(leaf.shape) * leaf.dtype.itemsize / _shards(
            tuple(spec), mesh_axes)

    pol = cfg.policy
    out = {"params": pb}
    if shape.kind == "train":
        out["grads"] = pb  # accumulated in param dtype
        md = {"float32": 4, "bfloat16": 2, "int8": 1}[pol.moment_dtype]
        pdt = 2 if pol.param_dtype == "bfloat16" else 4
        out["m"] = pb / pdt * md
        out["v"] = (pb / pdt * 4 / 128 if pol.factored_v  # rank-1 stats
                    else pb / pdt * md)
        tokens_dev = (shape.global_batch * shape.seq_len
                      / (mesh_axes.get("data", 1) * mesh_axes.get("pod", 1))
                      / pol.microbatches)
        act = cfg.n_layers * tokens_dev * cfg.d_model * 2
        if pol.sp:
            act /= mesh_axes.get("model", 1)
        out["residuals"] = act
        # live intra-block tensors: MoE archs bound by the expert width
        # (+ the dispatch buffer), dense archs by the FFN hidden
        if cfg.moe:
            eff = max(cfg.moe.d_ff_expert, cfg.d_model)
            out["workingset"] = 2 * tokens_dev * eff * 4
            # dispatch buffer (E,G,C,d) shards experts on 'model' (EP) or
            # d_ff on 'model' (TP) — either way /model on top of /data
            out["moe_buffers"] = (tokens_dev * cfg.moe.top_k * cfg.d_model
                                  * 2 * cfg.moe.capacity_factor
                                  / mesh_axes.get("model", 1))
        else:
            out["workingset"] = 2 * tokens_dev * max(cfg.d_ff,
                                                     cfg.d_model * 4) * 4
    elif shape.kind == "prefill":
        tokens_dev = (shape.global_batch * shape.seq_len
                      / max(mesh_axes.get("data", 1), 1))
        out["workingset"] = 4 * tokens_dev * cfg.d_model * 2
        out["caches"] = _cache_bytes(cfg, shape, mesh_axes)
    else:
        out["caches"] = _cache_bytes(cfg, shape, mesh_axes)
        out["workingset"] = 64e6
    out["total"] = sum(out.values())
    out["fits_16g"] = out["total"] < hw.HBM_BYTES
    return out


def _cache_bytes(cfg: ArchConfig, shape: ShapeSpec,
                 mesh_axes: Dict[str, int]) -> float:
    b = shape.global_batch
    t = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window \
        else shape.seq_len
    dp = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    bshard = dp if b % dp == 0 else 1
    ms = mesh_axes.get("model", 1)
    if cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        per = nh * s.d_state * s.head_dim * 4 + (s.d_conv - 1) * (
            d_inner + 2 * s.n_groups * s.d_state) * 2
        return cfg.n_layers * b * per / bshard / min(ms, nh)
    if cfg.mla is not None:
        per = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
        return cfg.n_layers * b * t * per / bshard / min(ms, 16)
    kv = 2 * cfg.n_kv_heads * cfg.head_dim_ * 2
    layers = cfg.n_layers
    total = layers * b * t * kv / bshard / ms
    if cfg.hybrid is not None:
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        groups = cfg.n_layers // cfg.hybrid.shared_every
        ssm_b = cfg.n_layers * b * (nh * s.d_state * s.head_dim * 4) / bshard \
            / min(ms, nh)
        attn_b = groups * b * t * kv / bshard / min(ms, cfg.n_kv_heads)
        total = ssm_b + attn_b
    return total
