"""Compiled step builders: train / prefill / decode, fully sharded.

Each builder returns (jit_fn, arg_shapes, arg_shardings) so callers can
either execute (real training) or ``.lower().compile()`` against
ShapeDtypeStructs (the multi-pod dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models import model as lm
from repro.models.layers import XLA, Backend
from repro.optim import adamw
from repro.sharding import rules
from repro.sharding.context import use_mesh


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: lm.init(cfg, k), jax.random.PRNGKey(0))


def abstract_opt(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    ps = abstract_params(cfg)
    return jax.eval_shape(lambda p: adamw.init(p, opt_cfg), ps)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def _split_microbatches(batch: Dict, mb: int):
    return jax.tree.map(
        lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)


def make_train_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                    opt_cfg: adamw.AdamWConfig | None = None,
                    backend: Backend = XLA, donate: bool = True):
    opt_cfg = opt_cfg or adamw.from_policy(cfg.policy)
    mb = cfg.policy.microbatches
    accum_dtype = (jnp.bfloat16 if cfg.policy.param_dtype == "bfloat16"
                   else jnp.float32)

    def loss_fn(p, b):
        return lm.loss_fn(p, b, cfg, backend)

    def train_step(params, opt_state, batch):
        with use_mesh(mesh):
            if mb == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mbs = _split_microbatches(batch, mb)

                def body(acc, mbatch):
                    (l, mets), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(a.dtype), acc, g)
                    return acc, (l, mets)

                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params)
                grads, (ls, mets) = jax.lax.scan(body, acc0, mbs)
                grads = jax.tree.map(lambda g: g / mb, grads)
                loss = ls.mean()
                metrics = jax.tree.map(lambda x: x.mean(), mets)
            params2, opt2, om = adamw.apply(params, grads, opt_state, opt_cfg)
            metrics = dict(metrics, **om, loss_out=loss)
            return params2, opt2, metrics

    pshapes = abstract_params(cfg)
    oshapes = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pshapes)
    bshapes = input_specs(cfg, shape)
    pspec = rules.param_pspecs(cfg, pshapes, mesh)
    ospec = rules.opt_pspecs(cfg, oshapes, mesh)
    bspec = rules.batch_pspecs(cfg, bshapes, mesh)
    mspec = P()

    fn = jax.jit(
        train_step,
        in_shardings=(rules.to_named(pspec, mesh), rules.to_named(ospec, mesh),
                      rules.to_named(bspec, mesh)),
        out_shardings=(rules.to_named(pspec, mesh),
                       rules.to_named(ospec, mesh), None),
        donate_argnums=(0, 1) if donate else (),
    )
    return fn, (pshapes, oshapes, bshapes), (pspec, ospec, bspec)


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                      backend: Backend = XLA):
    cache_len = shape.seq_len

    def prefill_step(params, batch):
        with use_mesh(mesh):
            return lm.prefill(params, batch, cfg, cache_len=cache_len,
                              backend=backend)

    pshapes = abstract_params(cfg)
    bshapes = input_specs(cfg, shape)
    pspec = rules.param_pspecs(cfg, pshapes, mesh)
    bspec = rules.batch_pspecs(cfg, bshapes, mesh)
    cshapes = jax.eval_shape(
        lambda: lm.make_caches(cfg, shape.global_batch, cache_len))
    cspec = rules.cache_pspecs(cfg, cshapes, mesh)
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    lspec = P(baxes if shape.global_batch % (
        _prod(mesh, baxes)) == 0 else None, "model")

    fn = jax.jit(
        prefill_step,
        in_shardings=(rules.to_named(pspec, mesh), rules.to_named(bspec, mesh)),
        out_shardings=(NamedSharding(mesh, lspec), rules.to_named(cspec, mesh)),
    )
    return fn, (pshapes, bshapes), (pspec, bspec, cspec)


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_decode_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                     backend: Backend = XLA, donate: bool = True):
    cache_len = (min(shape.seq_len, cfg.sliding_window)
                 if cfg.sliding_window else shape.seq_len)

    def decode(params, tokens, positions, caches):
        with use_mesh(mesh):
            return lm.decode_step(params, tokens, positions, caches, cfg,
                                  backend=backend)

    b = shape.global_batch
    pshapes = abstract_params(cfg)
    pspec = rules.param_pspecs(cfg, pshapes, mesh)
    tshape = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    posshape = jax.ShapeDtypeStruct((b,), jnp.int32)
    cshapes = jax.eval_shape(lambda: lm.make_caches(cfg, b, cache_len))
    cspec = rules.cache_pspecs(cfg, cshapes, mesh)
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bax = baxes if b % _prod(mesh, baxes) == 0 else None
    tspec, posspec = P(bax, None), P(bax)
    lspec = P(bax, "model")

    fn = jax.jit(
        decode,
        in_shardings=(rules.to_named(pspec, mesh),
                      NamedSharding(mesh, tspec), NamedSharding(mesh, posspec),
                      rules.to_named(cspec, mesh)),
        out_shardings=(NamedSharding(mesh, lspec), rules.to_named(cspec, mesh)),
        donate_argnums=(3,) if donate else (),
    )
    shapes = (pshapes, tshape, posshape, cshapes)
    return fn, shapes, (pspec, tspec, posspec, cspec)


def make_step_for(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                  backend: Backend = XLA):
    """The step a given (arch x shape) cell lowers (train vs serve)."""
    if shape.kind == "train":
        return ("train_step",) + make_train_step(cfg, mesh, shape,
                                                 backend=backend)
    if shape.kind == "prefill":
        return ("prefill_step",) + make_prefill_step(cfg, mesh, shape,
                                                     backend=backend)
    return ("decode_step",) + make_decode_step(cfg, mesh, shape,
                                               backend=backend)
