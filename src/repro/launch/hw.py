"""Target-hardware constants (TPU v5e per chip) for roofline terms."""

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16 * 2 ** 30     # capacity per chip
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
