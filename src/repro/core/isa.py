"""AME and Aquabolt-XL PIM instruction-set definitions.

Two ISAs live here, mirroring the paper's §2.3 and §2.4:

* The **PIM ISA** — the minimal per-pseudo-channel instruction set of Samsung
  Aquabolt-XL (arithmetic ADD/MUL/MAD/MAC, data movement MOV/FILL, control
  NOP/JUMP/EXIT), executed by the 8 PIM units of a pseudo-channel in lock-step,
  one instruction per DRAM column command in AB-PIM mode.

* The **AME ISA** — the T-Head RISC-V Attached Matrix Extension subset the
  paper maps onto PIM: tile registers tr0-tr3, accumulation registers
  acc0-acc3, mtilem/k/n CSRs, element-wise mfadd/mfsub/mfmul, matrix
  mfmacc, and the load/store/move family resolved via a pointer table.

The paper's Table 1 mapping (which AME ops are PIM-supported) is encoded in
:data:`AME_TO_PIM` and enforced by :class:`UnsupportedOnPIM`.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware constants (paper §2.1 / Table 2)
# ---------------------------------------------------------------------------

SIMD_LANES = 16          #: FP16 lanes per PIM unit
PIM_UNITS = 8            #: PIM units per pseudo-channel (16 banks / 2)
EVEN_BANKS = PIM_UNITS   #: one even bank per PIM unit
ODD_BANKS = PIM_UNITS    #: one odd bank per PIM unit
GRF_REGS = 8             #: 256-bit registers in each of GRF_A / GRF_B
SRF_REGS = 8             #: scalar registers in each of SRF_A / SRF_M
CRF_ENTRIES = 32         #: microkernel slots (32 x 32-bit)
JUMP_MAX_ITERS = 256     #: 255-iteration counter => at most 256 loop passes
AAM_BLOCKS = 8           #: address-aligned mode: 8 consecutive 16xFP16 blocks
PIM_FREQ_HZ = 250e6      #: DRAM-core / PIM-unit / FPGA-bus frequency (250 MHz)
PSEUDO_CHANNELS = 16     #: PIM pseudo-channels per HBM stack (4 dies x 4)

# AME implementation-defined constants (paper Table 2)
ELEN = 16                            #: element size, bits (FP16)
ROWNUM = 128                         #: rows per tile = EVEN_BANKS * SIMD_LANES
TRLEN = 2 ** 16                      #: tile-register row length, bits
TILE_MAX_COLS = 4096                 #: TRLEN / ELEN
ALEN = 2 ** 23                       #: accumulation register size, bits
NUM_TILE_REGS = 4                    #: tr0-tr3
NUM_ACC_REGS = 4                     #: acc0-acc3
PEAK_FLOP_PER_CYCLE = 2 * SIMD_LANES * PIM_UNITS * 0.5  # nominal MAC peak...

# A MAC = 2 FLOP per lane per cycle across 8 units -> 256; the paper quotes a
# usable theoretical peak of 128 FLOP/cycle per pseudo-channel because each
# lane's multiplier+adder pair retires one MAC per *command* and commands
# interleave with data movement; we keep the paper's figure.
THEORETICAL_PEAK_FLOP_PER_CYCLE = 128.0


class PIMOpcode(enum.Enum):
    """Native Aquabolt-XL PIM opcodes (paper §2.3)."""

    ADD = "add"
    MUL = "mul"
    MAD = "mad"
    MAC = "mac"
    MOV = "mov"
    FILL = "fill"
    NOP = "nop"
    JUMP = "jump"
    EXIT = "exit"


ARITH_OPCODES = (PIMOpcode.ADD, PIMOpcode.MUL, PIMOpcode.MAD, PIMOpcode.MAC)
MOVE_OPCODES = (PIMOpcode.MOV, PIMOpcode.FILL)


class OperandSpace(enum.Enum):
    """Where a PIM operand lives."""

    GRF_A = "grf_a"
    GRF_B = "grf_b"
    SRF_A = "srf_a"
    SRF_M = "srf_m"
    EVEN_BANK = "even_bank"
    ODD_BANK = "odd_bank"
    ZERO = "zero"          # the reserved zero_vector region (paper Listing 1c)


@dataclasses.dataclass(frozen=True)
class Operand:
    """A PIM operand reference.

    ``index`` selects a register (GRF/SRF) or a 256-bit block address
    (banks; block-granular addressing — a block is 16 consecutive FP16).
    For SRF fills from a bank, ``lane`` selects the FP16 scalar inside the
    block.  ``broadcast=True`` marks the paper's single-bank-to-all-units
    broadcast routing (§2.3.2).  Bank operands are offset by the symbolic
    base ``base`` (resolved from the host command stream per loop pass —
    AAM) and advance by ``step`` per AAM sub-command (the listings' ``32*i``
    byte stride is one 256-bit block, i.e. ``step=1``; SRF scalar fills use
    the ``2*i`` byte stride, i.e. lane ``step=1``).
    """

    space: OperandSpace
    index: int = 0
    lane: Optional[int] = None
    broadcast: bool = False
    base: str = ""
    step: int = 0

    def __repr__(self) -> str:  # compact, for program listings
        s = self.space.value
        loc = f"{self.base}+{self.index}" if self.base else f"{self.index}"
        if self.lane is not None:
            return f"{s}[{loc}.{self.lane}]"
        return f"{s}[{loc}]" + ("!bcast" if self.broadcast else "")


@dataclasses.dataclass(frozen=True)
class PIMInstr:
    """One 32-bit PIM instruction (decoded form).

    ``aam`` marks address-aligned mode: the instruction is retired by 8
    consecutive column commands, the b-th advancing every bank-space operand
    by ``aam_stride`` blocks and every register operand index by 1.
    """

    op: PIMOpcode
    dst: Optional[Operand] = None
    src0: Optional[Operand] = None
    src1: Optional[Operand] = None
    aam: bool = False
    aam_stride: int = 1
    jump_iters: int = 0       # JUMP: number of *additional* passes (<= 255)
    jump_target: int = 0      # CRF index to jump back to

    def commands(self) -> int:
        """DRAM column commands needed to retire this instruction once."""
        if self.op is PIMOpcode.JUMP:
            return 0  # zero-cycle predecoded jump (paper §2.3.3)
        return AAM_BLOCKS if self.aam else 1

    def __repr__(self) -> str:
        if self.op is PIMOpcode.JUMP:
            return f"jump x{self.jump_iters} -> {self.jump_target}"
        parts = [self.op.value]
        for o in (self.dst, self.src0, self.src1):
            if o is not None:
                parts.append(repr(o))
        if self.aam:
            parts.append(f"(aam x{AAM_BLOCKS}, stride {self.aam_stride})")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# AME instruction surface (T-Head proposal subset used by the paper)
# ---------------------------------------------------------------------------


class AMEOp(enum.Enum):
    # configuration
    MSETTILEM = "msettilem"
    MSETTILEK = "msettilek"
    MSETTILEN = "msettilen"
    MRELEASE = "mrelease"
    # element-wise arithmetic (matrix-matrix and matrix-vector forms)
    MFADD_MM = "mfadd.h.mm"
    MFADD_MV = "mfadd.h.mv.i"
    MFSUB_MM = "mfsub.h.mm"
    MFSUB_MV = "mfsub.h.mv.i"
    MFMUL_MM = "mfmul.h.mm"
    MFMUL_MV = "mfmul.h.mv.i"
    MFMAX_MM = "mfmax.h.mm"
    MFMAX_MV = "mfmax.h.mv.i"
    MFMIN_MM = "mfmin.h.mm"
    MFMIN_MV = "mfmin.h.mv.i"
    # matrix multiplication
    MFMACC = "mfmacc.h"
    MFMACC_WIDEN = "mfmacc.s.h"   # FP16 -> FP32 widening form
    # load/store & misc (pointer-table resolved, paper §3.2.6)
    MLD = "mld"
    MST = "mst"
    MLD_T = "mld.t"               # transposed load
    MMOV = "mmov.mm"
    MBC = "mbc.v"                 # broadcast
    MPACK = "mpack"
    MSLIDE = "mslide"


#: Paper Table 1 — AME arithmetic/matrix ops -> native PIM opcode sequence.
#: ``None`` means "Not supported" on Aquabolt-XL.
AME_TO_PIM = {
    AMEOp.MFADD_MM: (PIMOpcode.ADD,),
    AMEOp.MFADD_MV: (PIMOpcode.ADD,),
    AMEOp.MFSUB_MM: (PIMOpcode.MUL, PIMOpcode.ADD),
    AMEOp.MFSUB_MV: (PIMOpcode.MUL, PIMOpcode.ADD),
    AMEOp.MFMUL_MM: (PIMOpcode.MUL,),
    AMEOp.MFMUL_MV: (PIMOpcode.MUL,),
    AMEOp.MFMAX_MM: None,
    AMEOp.MFMAX_MV: None,
    AMEOp.MFMIN_MM: None,
    AMEOp.MFMIN_MV: None,
    AMEOp.MFMACC: (PIMOpcode.MAC,),
    AMEOp.MFMACC_WIDEN: None,
}


class UnsupportedOnPIM(NotImplementedError):
    """AME operation with no Aquabolt-XL mapping (paper Table 1)."""


def pim_mapping(op: AMEOp) -> Tuple[PIMOpcode, ...]:
    """The PIM opcode sequence implementing ``op``, or raise."""
    seq = AME_TO_PIM.get(op, ())
    if seq is None:
        raise UnsupportedOnPIM(
            f"{op.value}: no native PIM mapping (no comparison/widening "
            "support in the Aquabolt-XL datapath — paper Table 1)")
    return seq


@dataclasses.dataclass
class AMECSRState:
    """AME configuration CSRs (paper §2.4.1).

    mtilem/k/n bound the *active* tile shape of subsequent instructions;
    msettile* clamps against the implementation constants (Table 2) the way
    a real implementation reports back the granted dimension.
    """

    mtilem: int = ROWNUM
    mtilek: int = TILE_MAX_COLS
    mtilen: int = ROWNUM

    def msettilem(self, m: int) -> int:
        self.mtilem = max(1, min(int(m), ROWNUM))
        return self.mtilem

    def msettilek(self, k: int) -> int:
        self.mtilek = max(1, min(int(k), TILE_MAX_COLS))
        return self.mtilek

    def msettilen(self, n: int) -> int:
        self.mtilen = max(1, min(int(n), TILE_MAX_COLS))
        return self.mtilen
