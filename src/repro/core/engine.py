"""AMEEngine — executes AME instructions on the PIM model (paper §3.2/3.3).

The engine holds the AME architectural state (tile registers tr0-tr3,
accumulation registers acc0-acc3, the mtilem/k/n CSRs) and the paper's
pointer table: registers are *memory-resident* handles, and data-movement
instructions (load/store/move/transpose/pack/slide) resolve to pointer/layout
updates, not copies (paper §3.2.6).

Numeric execution uses the fast JAX path below — vectorized but *order-exact*
with the hardware: FP16 rounding after the multiplier and adder stages, k
walked in ascending order per output column, exactly like the MAC-PEP.  It is
cross-validated bit-exactly against the strict interpreter
(:mod:`repro.core.pim`) in the test suite.

Cost accounting uses :mod:`repro.core.cost`; every instruction returns and
accumulates a :class:`PEPCostReport`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost as cost_mod
from repro.core.isa import (
    AMECSRState,
    AMEOp,
    ROWNUM,
    TILE_MAX_COLS,
    UnsupportedOnPIM,
    pim_mapping,
)

F16 = jnp.float16


# ---------------------------------------------------------------------------
# Fast, order-exact numeric semantics (jitted)
# ---------------------------------------------------------------------------


@jax.jit
def _ew_add(a, b):
    return (a.astype(F16) + b.astype(F16)).astype(F16)


@jax.jit
def _ew_mul(a, b):
    return (a.astype(F16) * b.astype(F16)).astype(F16)


@jax.jit
def _ew_sub(a, b):
    # emulated: a + (-1)*b, with FP16 rounding after the MUL stage (SUB-PEP)
    nb = (b.astype(F16) * F16(-1.0)).astype(F16)
    return (a.astype(F16) + nb).astype(F16)


@jax.jit
def _mac_outer(acc, a, b):
    """acc(m,n) += A(m,k) @ B(k,n), FP16, ascending-k outer products.

    One scan step == one MAC instruction's effect across all columns: the
    MAC is a fused multiply-accumulate (paper §2.3.1), so the product+add
    round *once* at register writeback — modeled as exact f32 arithmetic
    rounded to FP16 per k-step.  Bit-exact with the strict interpreter.
    """
    a = a.astype(F16).astype(jnp.float32)
    b = b.astype(F16).astype(jnp.float32)

    def step(carry, ab):
        col, row = ab                       # col: (m,), row: (n,)
        out = (carry.astype(jnp.float32)
               + col[:, None] * row[None, :]).astype(F16)
        return out, None

    out, _ = jax.lax.scan(step, acc.astype(F16), (a.T, b), unroll=4)
    return out


# ---------------------------------------------------------------------------
# Memory-resident register handles + pointer table
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TileHandle:
    """A tile/accumulator register: pointer-table entry + layout metadata.

    ``data`` is the logical (rows, cols) array; ``transposed`` marks a
    pending zero-copy transpose (mld.t / mmov.t) that downstream consumers
    fold into their access pattern; ``row_off``/``col_off`` implement slide
    and pack as view updates.
    """

    data: jnp.ndarray
    transposed: bool = False
    row_off: int = 0
    col_off: int = 0

    def resolve(self) -> jnp.ndarray:
        d = self.data
        if self.transposed:
            d = d.T
        if self.row_off or self.col_off:
            d = d[self.row_off:, self.col_off:]
        return d

    @property
    def shape(self) -> Tuple[int, int]:
        r, c = self.data.shape
        if self.transposed:
            r, c = c, r
        return (r - self.row_off, c - self.col_off)


@dataclasses.dataclass(frozen=True)
class InstrRecord:
    """One executed AME arithmetic instruction, with its active tile shape.

    Enough to regenerate the exact PEP launch decomposition (and hence the
    command trace) after the fact: ``kind`` in {add, mul, sub, mac}; for
    element-wise ops ``n`` is 1 and ``k`` is the column count.
    """

    kind: str
    m: int
    k: int
    n: int = 1


@dataclasses.dataclass(frozen=True)
class ShardSpan:
    """Aggregated record of one whole-shard batched/analytic execution.

    The fast paths charge a shard's cost in one step instead of walking
    tiles, so the instruction stream holds one span per shard; the trace
    emitter expands it back into the identical per-tile
    :class:`InstrRecord` sequence via :meth:`records` — command traces are
    byte-for-byte the same as the per-tile walk's.

    ``kind`` is ``"mac"`` (``cols`` = K extent, ``ns`` = N extent) or an
    element-wise kind (``cols`` = column extent, ``ns`` unused).
    """

    kind: str
    rows: int
    cols: int
    ns: int = 1

    def records(self):
        """The per-tile instruction records of the blocked walk, in engine
        dispatch order."""
        if self.kind == "mac":
            for i0, i1, j0, j1, c0, c1 in gemm_tiles(self.rows, self.cols,
                                                     self.ns):
                yield InstrRecord("mac", i1 - i0, c1 - c0, j1 - j0)
        else:
            for i0, i1, c0, c1 in ew_tiles(self.rows, self.cols):
                yield InstrRecord(self.kind, i1 - i0, c1 - c0)


class AMEEngine:
    """Executes the AME instruction subset of paper Table 1 on HBM-PIM.

    The engine models exactly ONE pseudo-channel — the leaf executor.
    Multi-pseudo-channel execution lives one layer up in
    :mod:`repro.runtime`, which partitions operands across per-channel
    engines and reports makespan, rather than scaling FLOPs in place.
    """

    def __init__(self):
        self.csr = AMECSRState()
        self.tr: Dict[int, Optional[TileHandle]] = {i: None for i in range(4)}
        self.acc: Dict[int, Optional[TileHandle]] = {i: None for i in range(4)}
        self.total_cycles = 0.0
        self.total_flops = 0
        self.total_commands = 0
        self.log: List[cost_mod.PEPCostReport] = []
        # per-instruction records (InstrRecord) or whole-shard spans
        # (ShardSpan) from the batched executors, in dispatch order
        self.instrs: List[object] = []

    # -- configuration (msettile*) ------------------------------------------

    def msettilem(self, m: int) -> int:
        return self.csr.msettilem(m)

    def msettilek(self, k: int) -> int:
        return self.csr.msettilek(k)

    def msettilen(self, n: int) -> int:
        return self.csr.msettilen(n)

    def mrelease(self) -> None:
        for i in range(4):
            self.tr[i] = None
            self.acc[i] = None

    # -- load/store & misc: pointer-table ops, zero cycle charge ------------

    def mld(self, reg: int, a: jnp.ndarray) -> None:
        assert a.ndim == 2 and a.shape[0] <= ROWNUM and a.shape[1] <= TILE_MAX_COLS, \
            f"tile {a.shape} exceeds {ROWNUM}x{TILE_MAX_COLS}"
        self.tr[reg] = TileHandle(jnp.asarray(a, F16))

    def mld_t(self, reg: int, a: jnp.ndarray) -> None:
        """Transposed load — resolved by pointer/layout update (§3.2.6)."""
        self.tr[reg] = TileHandle(jnp.asarray(a, F16), transposed=True)

    def mld_acc(self, reg: int, a: jnp.ndarray) -> None:
        self.acc[reg] = TileHandle(jnp.asarray(a, F16))

    def mst(self, reg: int) -> jnp.ndarray:
        return self.acc[reg].resolve()

    def mmov(self, dst: int, src: int) -> None:
        self.tr[dst] = dataclasses.replace(self.tr[src])

    def mslide(self, reg: int, rows: int = 0, cols: int = 0) -> None:
        h = self.tr[reg]
        self.tr[reg] = dataclasses.replace(h, row_off=h.row_off + rows,
                                           col_off=h.col_off + cols)

    def mbc_v(self, reg: int, v: jnp.ndarray, rows: int) -> None:
        """Broadcast a row vector to all tile rows (mbc.v)."""
        self.tr[reg] = TileHandle(jnp.broadcast_to(
            jnp.asarray(v, F16)[None, :], (rows, v.shape[-1])))

    # -- arithmetic ----------------------------------------------------------

    def _active_mk(self, h: TileHandle) -> Tuple[int, int]:
        r, c = h.shape
        return min(r, self.csr.mtilem), min(c, self.csr.mtilek)

    def _charge(self, rep: cost_mod.PEPCostReport,
                rec: InstrRecord) -> cost_mod.PEPCostReport:
        self.total_cycles += rep.cycles
        self.total_flops += rep.flops
        self.total_commands += rep.commands
        self.log.append(rep)
        self.instrs.append(rec)
        return rep

    def _ew(self, op: AMEOp, kind: str, fn, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        pim_mapping(op)  # raises UnsupportedOnPIM for max/min/widening
        ha = self.tr[a]
        m, k = self._active_mk(ha)
        av = ha.resolve()[:m, :k]
        if isinstance(b, int):                       # .mm form
            bv = self.tr[b].resolve()[:m, :k]
        else:                                        # .mv.i form: row vector
            bv = jnp.broadcast_to(jnp.asarray(b, F16)[None, :k], (m, k))
        self.acc[dst] = TileHandle(fn(av, bv))
        return self._charge(cost_mod.elementwise_cost(kind, m, k),
                            InstrRecord(kind, m, k))

    def mfadd(self, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        op = AMEOp.MFADD_MM if isinstance(b, int) else AMEOp.MFADD_MV
        return self._ew(op, "add", _ew_add, dst, a, b)

    def mfsub(self, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        op = AMEOp.MFSUB_MM if isinstance(b, int) else AMEOp.MFSUB_MV
        return self._ew(op, "sub", _ew_sub, dst, a, b)

    def mfmul(self, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        op = AMEOp.MFMUL_MM if isinstance(b, int) else AMEOp.MFMUL_MV
        return self._ew(op, "mul", _ew_mul, dst, a, b)

    def mfmax(self, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        pim_mapping(AMEOp.MFMAX_MM if isinstance(b, int) else AMEOp.MFMAX_MV)
        raise AssertionError("unreachable")

    def mfmin(self, dst: int, a: int, b) -> cost_mod.PEPCostReport:
        pim_mapping(AMEOp.MFMIN_MM if isinstance(b, int) else AMEOp.MFMIN_MV)
        raise AssertionError("unreachable")

    def mfmacc(self, dst: int, a: int, b: int,
               widen: bool = False) -> cost_mod.PEPCostReport:
        """acc(dst) += tr(a) @ tr(b) — the reduction-free outer-product path."""
        if widen:
            pim_mapping(AMEOp.MFMACC_WIDEN)
        pim_mapping(AMEOp.MFMACC)
        ha, hb = self.tr[a], self.tr[b]
        m = min(ha.shape[0], self.csr.mtilem)
        k = min(ha.shape[1], hb.shape[0], self.csr.mtilek)
        n = min(hb.shape[1], self.csr.mtilen)
        av = ha.resolve()[:m, :k]
        bv = hb.resolve()[:k, :n]
        acc = self.acc[dst]
        if acc is None or acc.shape != (m, n):
            acc = TileHandle(jnp.zeros((m, n), F16))
        self.acc[dst] = TileHandle(_mac_outer(acc.resolve()[:m, :n], av, bv))
        return self._charge(cost_mod.mfmacc_cost(m, k, n),
                            InstrRecord("mac", m, k, n))


# ---------------------------------------------------------------------------
# Single-channel blocked execution (the runtime's leaf executors)
#
# Multi-channel GEMM/GEMV lives in repro.runtime: the scheduler partitions
# operands across per-channel engines and calls these walkers per shard.
# ---------------------------------------------------------------------------


def gemm_tiles(m: int, k: int, n: int):
    """The blocked-GEMM tile walk: (i0, i1, j0, j1, c0, c1) in engine order.

    Shared between the numeric executor (:func:`gemm_on_engine`) and the
    runtime's analytic cost path so both charge identical ledgers.
    """
    bm, bk, bn = ROWNUM, TILE_MAX_COLS, ROWNUM
    for i0 in range(0, m, bm):
        i1 = min(i0 + bm, m)
        for j0 in range(0, n, bn):
            j1 = min(j0 + bn, n)
            for c0 in range(0, k, bk):
                c1 = min(c0 + bk, k)
                yield i0, i1, j0, j1, c0, c1


def ew_tiles(m: int, c: int):
    """Blocked element-wise tile walk: (i0, i1, c0, c1) in engine order."""
    for i0 in range(0, m, ROWNUM):
        i1 = min(i0 + ROWNUM, m)
        for c0 in range(0, c, TILE_MAX_COLS):
            c1 = min(c0 + TILE_MAX_COLS, c)
            yield i0, i1, c0, c1


def gemm_on_engine(eng: AMEEngine, a: jnp.ndarray,
                   b: jnp.ndarray) -> np.ndarray:
    """C = A @ B as AME mfmacc tiles on ONE pseudo-channel engine.

    Blocks A (M,K) and B (K,N) into <=128x4096 tiles and walks them
    sequentially, charging the engine's cycle/FLOP ledger.  Every output
    element's accumulation order is ascending-k regardless of the M/N
    blocking, so any output-space partition of a larger problem is
    bit-exact with a single-engine run.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out = np.zeros((m, n), np.float16)
    last_ij = None
    for i0, i1, j0, j1, c0, c1 in gemm_tiles(m, k, n):
        if (i0, j0) != last_ij:
            if last_ij is not None:
                li, lj = last_ij
                out[li:li + ROWNUM, lj:lj + ROWNUM] = np.asarray(eng.mst(0))
            eng.acc[0] = None
            eng.msettilem(i1 - i0)
            eng.msettilen(j1 - j0)
            last_ij = (i0, j0)
        eng.msettilek(c1 - c0)
        eng.mld(0, a[i0:i1, c0:c1])
        # B block enters as an (n x k) tile register consumed through
        # the pointer table's transposed view (mld.t, paper §3.2.6) —
        # this is what produces the K-major dense scalar layout the
        # MAC-PEP broadcasts from.
        eng.mld_t(1, jnp.asarray(b[c0:c1, j0:j1]).T)
        eng.mfmacc(0, 0, 1)
    if last_ij is not None:
        li, lj = last_ij
        out[li:li + ROWNUM, lj:lj + ROWNUM] = np.asarray(eng.mst(0))
    return out


def ew_on_engine(eng: AMEEngine, kind: str, a: jnp.ndarray,
                 b: jnp.ndarray) -> np.ndarray:
    """Element-wise ``a <op> b`` blocked over ONE pseudo-channel engine."""
    assert a.shape == b.shape and kind in ("add", "sub", "mul")
    m, c = a.shape
    out = np.zeros((m, c), np.float16)
    for i0, i1, c0, c1 in ew_tiles(m, c):
        eng.msettilem(i1 - i0)
        eng.msettilek(c1 - c0)
        eng.mld(0, a[i0:i1, c0:c1])
        eng.mld(1, b[i0:i1, c0:c1])
        getattr(eng, f"mf{kind}")(0, 0, 1)
        out[i0:i1, c0:c1] = np.asarray(eng.mst(0))
    return out


# ---------------------------------------------------------------------------
# Batched whole-shard executors (the numeric fast path)
#
# One jitted call per shard instead of one engine instruction per <=128x4096
# tile.  Bit-exactness with the per-tile walk (property-tested):
#
# * GEMM — every output element's value is a left fold over ascending k of
#   ``RN16(RN32(acc + a_ik * b_kj))`` (the MAC-PEP's per-column-command FP16
#   writeback; the f16*f16 product is exact in f32).  The blocked walk only
#   *partitions* those per-element chains across tiles — the chain itself
#   never observes M/N blocking, and K chunk boundaries add no rounding
#   because the accumulator register is already FP16 at every step.  A
#   single scan over the full ascending-k axis therefore reproduces each
#   chain bit-for-bit while vectorizing over the whole (m, n) output.
# * Element-wise — no accumulation at all; a whole-shard fused op is
#   trivially the tiled result.
#
# Cost is charged via the closed-form shard aggregate (repro.core.cost),
# which equals the per-instruction sum exactly; the instruction stream gets
# one ShardSpan that the trace emitter re-expands per tile.
# ---------------------------------------------------------------------------


def gemm_on_engine_batched(eng: AMEEngine, a: jnp.ndarray,
                           b: jnp.ndarray) -> np.ndarray:
    """C = A @ B on ONE pseudo-channel engine, whole shard in one jit call.

    Charges the same ledger totals as :func:`gemm_on_engine` (closed-form
    aggregate; one log entry, one :class:`ShardSpan` instruction record)
    and returns a bit-identical result.

    Strategy is shape-adaptive: N == 1 (skinny GEMV) shards delegate to
    the per-tile walk — its 128-row scan steps stay in XLA's inline
    single-thread regime and measure faster than a whole-column scan,
    whose (m,)-wide steps pay thread-pool dispatch 2048 times per
    k-sweep.  Both strategies are bit-exact, so this is purely a
    wall-clock choice.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if n == 1:
        return gemm_on_engine(eng, a, b)
    # the whole shard is one mfmacc-semantics fold: _mac_outer with a zero
    # accumulator, so the load-bearing rounding recipe lives in ONE place
    out = np.asarray(_mac_outer(jnp.zeros((m, n), F16),
                                jnp.asarray(a, F16), jnp.asarray(b, F16)))
    agg = cost_mod.gemm_shard_cost(m, k, n)
    eng._charge(agg, ShardSpan("mac", m, k, n))
    return out


def ew_on_engine_batched(eng: AMEEngine, kind: str, a: jnp.ndarray,
                         b: jnp.ndarray) -> np.ndarray:
    """Element-wise ``a <kind> b`` on ONE engine, whole shard in one call."""
    assert a.shape == b.shape and kind in ("add", "sub", "mul")
    m, c = a.shape
    fn = {"add": _ew_add, "sub": _ew_sub, "mul": _ew_mul}[kind]
    out = np.asarray(fn(jnp.asarray(a, F16), jnp.asarray(b, F16)))
    agg = cost_mod.ew_shard_cost(kind, m, c)
    eng._charge(agg, ShardSpan(kind, m, c))
    return out
