"""Core AME-PIM layer: the paper's contribution, faithful in JAX.

Layers:
  isa      — AME + Aquabolt-XL PIM instruction sets, Table-1 mapping
  pim      — strict lock-step interpreter of one pseudo-channel
  pep      — the four PEP microkernels + tile memory layout (§3.2)
  cost     — calibrated cycle model (59.4 FLOP/cycle mfmacc headline, §4)
  engine   — AMEEngine: AME architectural state, pointer table, fast
             order-exact execution for ONE pseudo-channel (the leaf
             executor; multi-channel execution lives in repro.runtime)
"""
from repro.core.isa import (
    AMECSRState,
    AMEOp,
    AME_TO_PIM,
    PIMInstr,
    PIMOpcode,
    ROWNUM,
    TILE_MAX_COLS,
    THEORETICAL_PEAK_FLOP_PER_CYCLE,
    UnsupportedOnPIM,
)
from repro.core.engine import (
    AMEEngine,
    InstrRecord,
    ShardSpan,
    TileHandle,
    ew_on_engine,
    ew_on_engine_batched,
    ew_tiles,
    gemm_on_engine,
    gemm_on_engine_batched,
    gemm_tiles,
)
from repro.core.cost import (
    PEPCostReport,
    elementwise_cost,
    ew_shard_cost,
    gemm_shard_cost,
    max_tile_mfmacc,
    mfmacc_cost,
    saturated_flop_per_cycle,
)

__all__ = [
    "AMECSRState", "AMEOp", "AME_TO_PIM", "PIMInstr", "PIMOpcode",
    "ROWNUM", "TILE_MAX_COLS", "THEORETICAL_PEAK_FLOP_PER_CYCLE",
    "UnsupportedOnPIM", "AMEEngine", "InstrRecord", "ShardSpan",
    "TileHandle", "ew_on_engine", "ew_on_engine_batched", "ew_tiles",
    "gemm_on_engine", "gemm_on_engine_batched", "gemm_tiles",
    "PEPCostReport", "elementwise_cost", "ew_shard_cost", "gemm_shard_cost",
    "max_tile_mfmacc", "mfmacc_cost", "saturated_flop_per_cycle",
]
