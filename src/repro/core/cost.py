"""Cycle-cost model for PEP execution on Aquabolt-XL (paper §4).

Two models, both analytic:

* **ISA model** — cycles = DRAM column commands issued (each command retires
  one PIM instruction step; JUMPs are zero-cycle, paper §2.3.3).  This is the
  upper bound implied purely by the instruction mix of Listing 1.

* **Bus-calibrated model** — the paper measures from the bus side of the
  FPGA PIM_kernel and reports 59.4 FLOP/cycle for mfmacc at 128x4096 tiles
  (14.9 GFLOP/s at 250 MHz).  The MAC-PEP pass is 26 commands for 2048
  useful FLOP per pseudo-channel => the ISA model would give 78.8
  FLOP/cycle; the measurement implies ~34.5 effective cycles per pass.  We
  model the gap as a per-pass overhead ``eta`` (even<->odd bank turnaround +
  command-stream gaps observed from the bus), calibrated once:

      2048 / (26 + eta) = 59.4  =>  eta ~= 8.5

  and applied uniformly to all PEPs ("largely uniform execution latency
  across PEP types", paper §4.2).

Setup costs: CRF programming + mode transitions per AME instruction
(SETUP_CRF) and per-PEP-launch re-trigger/row-activate (SETUP_INVOKE);
chosen such that setup is <1% of runtime at max tile size (paper §4.2) and
dominates at small tiles (paper Fig 9).

These costs are the single source of per-op busy time for *both*
execution models of the runtime scheduler: the serialized barrier-per-op
mode and the async dependency-aware timeline
(:mod:`repro.runtime.timeline`) consume identical per-channel cycle
charges — the timeline only decides *when* each busy interval starts
(``max(dep retire, channel free, link free)``), never what it costs, so
start/retire times inherit the calibration unchanged.  The setup-
dominated small-tile regime (Fig 9) is also why the async decode DAG
wins: decode-shaped matmuls pay launch floors per channel, so running
independent ops on disjoint channel groups removes serialized floors
without inflating per-op work.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.isa import (
    AAM_BLOCKS,
    JUMP_MAX_ITERS,
    PIM_FREQ_HZ,
    ROWNUM,
    THEORETICAL_PEAK_FLOP_PER_CYCLE,
    TILE_MAX_COLS,
)
from repro.core.pep import (
    COMMANDS_PER_PASS,
    FLOPS_PER_PASS,
    SUB_PROLOGUE_COMMANDS,
    ew_invocations,
    mac_invocations,
)

#: calibrated per-pass bus overhead (cycles) — see module docstring
ETA_BUS = 8.5
#: one-time cost per AME instruction: CRF broadcast-program + mode transitions
SETUP_CRF = 128
#: per PEP launch: AB-PIM re-trigger + row activation
SETUP_INVOKE = 64


@dataclasses.dataclass(frozen=True)
class PEPCostReport:
    """Cost of one AME instruction executed via PEP launches."""

    kind: str
    launches: int
    passes: int
    commands: int          # ISA-model cycles (column commands)
    cycles: float          # bus-calibrated cycles incl. setup
    flops: int             # useful FLOPs (paper counts MAC as 2)

    @property
    def flop_per_cycle(self) -> float:
        return self.flops / self.cycles

    @property
    def flop_per_cycle_isa(self) -> float:
        return self.flops / self.commands

    @property
    def gflops(self) -> float:
        return self.flop_per_cycle * PIM_FREQ_HZ / 1e9

    @property
    def seconds(self) -> float:
        return self.cycles / PIM_FREQ_HZ


def _report(kind: str, launches: int, passes: int, flops: int,
            extra_cmds: int = 0, eta: float = ETA_BUS) -> PEPCostReport:
    cmds = passes * COMMANDS_PER_PASS[kind] + extra_cmds
    cycles = (cmds + passes * eta
              + launches * SETUP_INVOKE + SETUP_CRF)
    return PEPCostReport(kind=kind, launches=launches, passes=passes,
                         commands=cmds, cycles=cycles, flops=flops)


def elementwise_cost(kind: str, m: int, c: int, eta: float = ETA_BUS) -> PEPCostReport:
    """mfadd/mfmul/mfsub on an (m x c) tile pair.

    Rows < 128 waste SIMD lanes (parallel width is fixed, paper §3.2.3):
    commands do not shrink with m, only useful FLOPs do.
    """
    assert kind in ("add", "mul", "sub")
    launches = ew_invocations(c)
    passes = sum(p for _, p in launches)
    flops = m * c  # one FLOP per element
    extra = SUB_PROLOGUE_COMMANDS * len(launches) if kind == "sub" else 0
    return _report(kind, len(launches), passes, flops, extra_cmds=extra,
                   eta=eta)


def mfmacc_cost(m: int, k: int, n: int, eta: float = ETA_BUS) -> PEPCostReport:
    """mfmacc: acc(m x n) += A(m x k) @ B(k x n); m <= 128 rows in lock-step."""
    invs = mac_invocations(k, n)
    passes = sum(i.passes for i in invs)
    flops = 2 * m * k * n
    return _report("mac", len(invs), passes, flops, eta=eta)


# ---------------------------------------------------------------------------
# Closed-form whole-shard costs (the analytic fast path)
#
# The runtime's blocked walk tiles a shard (rows, ks, ns) into <=128 x 4096
# (x <=128) engine tiles; each axis takes at most TWO distinct tile sizes
# (the full block and one ragged edge), so the whole walk collapses to <=8
# tile classes.  Per class the per-tile cost is computed once and scaled by
# the class count — O(1) per shard instead of O(#tiles).
#
# Exactness: every per-tile ``cycles`` is a multiple of 0.5 (commands and
# setup are integers, passes * ETA_BUS a half-integer), so count * cycles
# and the class sums are exact in binary floating point — the closed form
# equals the generator walk's running sum bit-for-bit, which the test
# suite asserts with ``==`` across ragged shapes.
# ---------------------------------------------------------------------------


def _axis_classes(size: int, block: int):
    """Tile sizes along one blocked axis as [(tile_size, count)] — the full
    block plus at most one ragged edge."""
    full, rem = divmod(size, block)
    out = []
    if full:
        out.append((block, full))
    if rem:
        out.append((rem, 1))
    return out


def _scale(rep: PEPCostReport, count: int) -> PEPCostReport:
    return PEPCostReport(kind=rep.kind, launches=rep.launches * count,
                         passes=rep.passes * count,
                         commands=rep.commands * count,
                         cycles=rep.cycles * count, flops=rep.flops * count)


def _merge(kind: str, parts) -> PEPCostReport:
    return PEPCostReport(kind=kind,
                         launches=sum(p.launches for p in parts),
                         passes=sum(p.passes for p in parts),
                         commands=sum(p.commands for p in parts),
                         cycles=sum(p.cycles for p in parts),
                         flops=sum(p.flops for p in parts))


def gemm_shard_cost(rows: int, ks: int, ns: int,
                    eta: float = ETA_BUS) -> PEPCostReport:
    """Total cost of the blocked-GEMM walk over a (rows, ks, ns) shard.

    Equals ``sum(mfmacc_cost(tile) for tile in gemm_tiles(rows, ks, ns))``
    exactly, without walking the tiles.
    """
    parts = []
    for msz, mc in _axis_classes(rows, ROWNUM):
        for ksz, kc in _axis_classes(ks, TILE_MAX_COLS):
            for nsz, nc in _axis_classes(ns, ROWNUM):
                parts.append(_scale(mfmacc_cost(msz, ksz, nsz, eta=eta),
                                    mc * kc * nc))
    return _merge("mac", parts)


def ew_shard_cost(kind: str, rows: int, cols: int,
                  eta: float = ETA_BUS) -> PEPCostReport:
    """Total cost of the blocked element-wise walk over a (rows, cols)
    shard; equals the per-tile sum over ``ew_tiles(rows, cols)`` exactly."""
    parts = []
    for msz, mc in _axis_classes(rows, ROWNUM):
        for csz, cc in _axis_classes(cols, TILE_MAX_COLS):
            parts.append(_scale(elementwise_cost(kind, msz, csz, eta=eta),
                                mc * cc))
    return _merge(kind, parts)


def max_tile_mfmacc() -> PEPCostReport:
    """The paper's headline point: 128x4096 tiles => C(128x128) += A @ B."""
    return mfmacc_cost(128, 4096, 128)


def saturated_flop_per_cycle(kind: str) -> float:
    """Asymptotic FLOP/cycle of a PEP (ignoring setup) — Fig 9's plateau."""
    per_pass = COMMANDS_PER_PASS[kind] + ETA_BUS
    return FLOPS_PER_PASS[kind] / per_pass


def summary() -> Dict[str, float]:
    """Key calibration numbers, checked against the paper in benchmarks."""
    head = max_tile_mfmacc()
    return {
        "mfmacc_flop_per_cycle_saturated": saturated_flop_per_cycle("mac"),
        "mfmacc_flop_per_cycle_maxtile": head.flop_per_cycle,
        "mfmacc_gflops_maxtile": head.gflops,
        "mfmacc_launches_maxtile": head.launches,
        "theoretical_peak": THEORETICAL_PEAK_FLOP_PER_CYCLE,
        "add_flop_per_cycle_saturated": saturated_flop_per_cycle("add"),
        "sub_flop_per_cycle_saturated": saturated_flop_per_cycle("sub"),
        "setup_share_maxtile": (head.launches * SETUP_INVOKE + SETUP_CRF)
        / head.cycles,
    }
