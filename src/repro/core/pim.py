"""Strict functional model of one Aquabolt-XL PIM pseudo-channel.

This is the *reference interpreter*: it executes CRF programs one DRAM column
command at a time, for all 8 PIM units in lock-step, with FP16 rounding after
every multiplier/adder stage — exactly the execution model of paper §2.1-2.3.
It is deliberately numpy (not traced): the fast, JAX-traceable path in
:mod:`repro.core.engine` is cross-validated against this interpreter on small
shapes, then used for real tile sizes.

Memory model
------------
Each bank is an array of 256-bit *blocks* (16 FP16 lanes).  Bank operands are
addressed as ``bases[op.base] + op.index (+ b*op.step in AAM step b)`` where
``bases`` is the per-loop-iteration base-address table supplied by the host
command stream — this mirrors address-aligned mode (AAM), where operand
addresses are derived from the column command itself rather than from the
instruction encoding.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.isa import (
    AAM_BLOCKS,
    EVEN_BANKS,
    GRF_REGS,
    ODD_BANKS,
    PIM_UNITS,
    PIMInstr,
    PIMOpcode,
    Operand,
    OperandSpace,
    SIMD_LANES,
    SRF_REGS,
)

F16 = np.float16


def f16(x: np.ndarray) -> np.ndarray:
    """Round to FP16 — models one datapath pipeline stage's output latch."""
    return np.asarray(x, dtype=F16)


@dataclasses.dataclass
class ChannelState:
    """Architectural state of one pseudo-channel."""

    even_banks: np.ndarray  # (PIM_UNITS, nblocks, 16) f16
    odd_banks: np.ndarray   # (PIM_UNITS, nblocks, 16) f16
    grf_a: np.ndarray       # (PIM_UNITS, GRF_REGS, 16) f16
    grf_b: np.ndarray       # (PIM_UNITS, GRF_REGS, 16) f16
    srf_a: np.ndarray       # (PIM_UNITS, SRF_REGS) f16
    srf_m: np.ndarray       # (PIM_UNITS, SRF_REGS) f16

    @classmethod
    def zeros(cls, nblocks: int) -> "ChannelState":
        return cls(
            even_banks=np.zeros((PIM_UNITS, nblocks, SIMD_LANES), F16),
            odd_banks=np.zeros((PIM_UNITS, nblocks, SIMD_LANES), F16),
            grf_a=np.zeros((PIM_UNITS, GRF_REGS, SIMD_LANES), F16),
            grf_b=np.zeros((PIM_UNITS, GRF_REGS, SIMD_LANES), F16),
            srf_a=np.zeros((PIM_UNITS, SRF_REGS), F16),
            srf_m=np.zeros((PIM_UNITS, SRF_REGS), F16),
        )


class PIMChannel:
    """Lock-step interpreter for CRF microkernel programs (AB-PIM mode)."""

    def __init__(self, nblocks: int = 4096):
        self.state = ChannelState.zeros(nblocks)
        self.commands_issued = 0  # column commands == bus-side cycles (ISA model)

    # -- operand access ----------------------------------------------------

    def _bank(self, space: OperandSpace) -> np.ndarray:
        if space is OperandSpace.EVEN_BANK:
            return self.state.even_banks
        if space is OperandSpace.ODD_BANK:
            return self.state.odd_banks
        raise ValueError(space)

    def _resolve(self, op: Operand, bases: Dict[str, int], b: int) -> int:
        base = bases.get(getattr(op, "base", ""), 0) if hasattr(op, "base") else 0
        return base + op.index + b * getattr(op, "step", 0)

    def _read_vec(self, op: Operand, bases: Dict[str, int], b: int) -> np.ndarray:
        """Read a 16-lane vector operand for every unit: (PIM_UNITS, 16)."""
        s = self.state
        if op.space is OperandSpace.ZERO:
            return np.zeros((PIM_UNITS, SIMD_LANES), F16)
        if op.space is OperandSpace.GRF_A:
            return s.grf_a[:, op.index + b * op.step]
        if op.space is OperandSpace.GRF_B:
            return s.grf_b[:, op.index + b * op.step]
        if op.space is OperandSpace.SRF_A:
            return np.repeat(s.srf_a[:, op.index + b * op.step, None],
                             SIMD_LANES, axis=1)
        if op.space is OperandSpace.SRF_M:
            return np.repeat(s.srf_m[:, op.index + b * op.step, None],
                             SIMD_LANES, axis=1)
        if op.space in (OperandSpace.EVEN_BANK, OperandSpace.ODD_BANK):
            blk = self._resolve(op, bases, b)
            banks = self._bank(op.space)
            if op.broadcast:  # single source bank routed to every unit
                return np.repeat(banks[0, blk][None], PIM_UNITS, axis=0)
            return banks[:, blk]
        raise ValueError(op.space)

    def _write_vec(self, op: Operand, bases: Dict[str, int], b: int,
                   val: np.ndarray) -> None:
        s = self.state
        if op.space is OperandSpace.GRF_A:
            s.grf_a[:, op.index + b * op.step] = val
        elif op.space is OperandSpace.GRF_B:
            s.grf_b[:, op.index + b * op.step] = val
        elif op.space in (OperandSpace.EVEN_BANK, OperandSpace.ODD_BANK):
            self._bank(op.space)[:, self._resolve(op, bases, b)] = val
        else:
            raise ValueError(f"cannot write vector to {op.space}")

    # -- execution ---------------------------------------------------------

    def _exec_once(self, ins: PIMInstr, bases: Dict[str, int], b: int) -> None:
        s = self.state
        if ins.op is PIMOpcode.FILL:
            dst = ins.dst
            if dst.space in (OperandSpace.SRF_A, OperandSpace.SRF_M):
                # scalar fill: one FP16 lane of a bank block, broadcast-routable.
                # The listings' 2-byte stride = one lane per AAM sub-command.
                src = ins.src0
                blk = self._resolve(src, bases, 0)
                lane = ((src.lane or 0) + bases.get(src.base + "_lane", 0)
                        + b * src.step)
                blk += lane // SIMD_LANES
                lane = lane % SIMD_LANES
                banks = self._bank(src.space)
                tgt = s.srf_a if dst.space is OperandSpace.SRF_A else s.srf_m
                idx = dst.index + b * dst.step
                if src.broadcast:
                    tgt[:, idx] = banks[0, blk, lane]  # one bank -> all units
                else:
                    tgt[:, idx] = banks[:, blk, lane]
            else:
                self._write_vec(dst, bases, b, self._read_vec(ins.src0, bases, b))
        elif ins.op is PIMOpcode.MOV:
            self._write_vec(ins.dst, bases, b, self._read_vec(ins.src0, bases, b))
        elif ins.op is PIMOpcode.ADD:
            a = self._read_vec(ins.src0, bases, b)
            c = self._read_vec(ins.src1, bases, b)
            self._write_vec(ins.dst, bases, b, f16(a.astype(F16) + c.astype(F16)))
        elif ins.op is PIMOpcode.MUL:
            a = self._read_vec(ins.src0, bases, b)
            c = self._read_vec(ins.src1, bases, b)
            self._write_vec(ins.dst, bases, b, f16(a * c))
        elif ins.op is PIMOpcode.MAD:
            # fused multiply-add (paper §2.3.1): single rounding at writeback
            a = self._read_vec(ins.src0, bases, b).astype(np.float32)
            c = self._read_vec(ins.src1, bases, b).astype(np.float32)
            d = self._read_vec(ins.dst, bases, b).astype(np.float32)
            self._write_vec(ins.dst, bases, b, f16(a * c + d))
        elif ins.op is PIMOpcode.MAC:
            # fused multiply-accumulate: exact product + add, one rounding
            a = self._read_vec(ins.src0, bases, b).astype(np.float32)
            c = self._read_vec(ins.src1, bases, b).astype(np.float32)
            acc = self._read_vec(ins.dst, bases, b).astype(np.float32)
            self._write_vec(ins.dst, bases, b, f16(acc + a * c))
        elif ins.op is PIMOpcode.NOP:
            pass
        else:
            raise ValueError(ins.op)

    def run(self, crf: List[PIMInstr],
            iter_bases: Callable[[int], Dict[str, int]],
            setup_bases: Optional[Dict[str, int]] = None) -> int:
        """Execute a CRF program to EXIT; returns column commands issued.

        ``iter_bases(t)`` supplies the host-driven base-address table for
        loop pass ``t`` (AAM semantics).  Instructions before the JUMP
        target index use ``setup_bases`` (one-time prologue, e.g. SUB-PEP's
        SRF_M initialization).
        """
        setup_bases = setup_bases or {}
        pc = 0
        t = 0                      # loop pass index
        jump_remaining: Optional[int] = None
        commands = 0
        loop_start = next((i.jump_target for i in crf
                           if i.op is PIMOpcode.JUMP), 0)
        while pc < len(crf):
            ins = crf[pc]
            if ins.op is PIMOpcode.EXIT:
                break
            if ins.op is PIMOpcode.JUMP:
                if jump_remaining is None:
                    jump_remaining = ins.jump_iters
                if jump_remaining > 0:
                    jump_remaining -= 1
                    t += 1
                    pc = ins.jump_target
                else:
                    pc += 1
                continue  # zero-cycle jump
            bases = setup_bases if pc < loop_start else iter_bases(t)
            reps = AAM_BLOCKS if ins.aam else 1
            for b in range(reps):
                self._exec_once(ins, bases, b)
                commands += 1
            pc += 1
        self.commands_issued += commands
        return commands
