"""PIM Execution Primitives (paper §3.2, Listing 1) and tile memory layout.

A PEP is a CRF-resident microkernel of native PIM instructions, executed in
AB-PIM mode by all 8 PIM units of a pseudo-channel in lock-step.  This module
builds the four PEPs of the paper —

* ``ADD-PEP`` / ``MUL-PEP``  (Listing 1a): element-wise ops on 128x2048 tiles,
  256 loop passes over 128x8 windows (24 column commands per pass).
* ``SUB-PEP``  (Listing 1b): subtraction emulated as ``a + (-1)*b`` via SRF_M
  (no native SUB), 32 commands per pass plus an 8-command prologue.
* ``MAC-PEP``  (Listing 1c): the reduction-free outer-product GEMM step —
  per pass, 8 scalars of B are double-broadcast (bank -> SRF_A -> GRF_A, 16
  commands) and MAC'd against 8 columns of A into the accumulator column
  held in GRF_B[0] (26 commands per pass).

Tile layout (paper §3.2.1): a tile has up to ROWNUM=128 rows; row ``r`` lives
in even bank ``r // 16``, SIMD lane ``r % 16``; within a bank the tile is
column-major, so block ``base + c`` of bank ``u`` holds rows ``16u..16u+15``
of column ``c``.  Accumulators use the same layout in the odd banks.

The second MAC operand is stored K-major dense (one column of B = K
consecutive FP16 scalars), matching the listings' 2-byte AAM stride; the
paper leaves tr1's placement implicit — its transposed-load (``mld.t``) +
pointer-table machinery (§3.2.6) produces exactly this layout.  We place the
dense region in even bank 0 and use the broadcast fill routing of §2.3.2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.isa import (
    AAM_BLOCKS,
    JUMP_MAX_ITERS,
    PIM_UNITS,
    PIMInstr,
    PIMOpcode,
    Operand,
    OperandSpace,
    ROWNUM,
    SIMD_LANES,
)
from repro.core.pim import PIMChannel

# symbolic base-address names (resolved per loop pass from the command stream)
BT0, BT1, BA0 = "bt0", "bt1", "ba0"
ZERO_BLOCK = "zero"          # reserved all-zeros block (even banks)
MINUS_ONE_BLOCK = "m1"       # reserved -1.0 vector block (even bank 0)

EB = OperandSpace.EVEN_BANK
OB = OperandSpace.ODD_BANK
GA = OperandSpace.GRF_A
GB = OperandSpace.GRF_B
SA = OperandSpace.SRF_A
SM = OperandSpace.SRF_M


def _op(space, index=0, **kw) -> Operand:
    return Operand(space=space, index=index, **kw)


# ---------------------------------------------------------------------------
# PEP program builders (CRF contents)
# ---------------------------------------------------------------------------


def build_ew_pep(op: PIMOpcode, iters: int) -> List[PIMInstr]:
    """ADD-PEP / MUL-PEP (Listing 1a): dst = A <op> B on 128 x 8*iters."""
    assert op in (PIMOpcode.ADD, PIMOpcode.MUL)
    return [
        PIMInstr(PIMOpcode.FILL, dst=_op(GA, step=1),
                 src0=_op(EB, base=BT0, step=1), aam=True),
        PIMInstr(op, dst=_op(GB, step=1),
                 src0=_op(EB, base=BT1, step=1), src1=_op(GA, step=1),
                 aam=True),
        PIMInstr(PIMOpcode.MOV, dst=_op(OB, base=BA0, step=1),
                 src0=_op(GB, step=1), aam=True),
        PIMInstr(PIMOpcode.JUMP, jump_iters=iters - 1, jump_target=0),
        PIMInstr(PIMOpcode.EXIT),
    ]


def build_sub_pep(iters: int) -> List[PIMInstr]:
    """SUB-PEP (Listing 1b): dst = A - B as A + (-1)*B via SRF_M."""
    return [
        # prologue: fill all eight SRF_M entries with -1.0 (broadcast routing)
        PIMInstr(PIMOpcode.FILL, dst=_op(SM, step=1),
                 src0=_op(EB, base=MINUS_ONE_BLOCK, step=1, broadcast=True),
                 aam=True),
        # loop body (jump_target = 1)
        PIMInstr(PIMOpcode.FILL, dst=_op(GA, step=1),
                 src0=_op(EB, base=BT0, step=1), aam=True),
        PIMInstr(PIMOpcode.MUL, dst=_op(GB, step=1),
                 src0=_op(EB, base=BT1, step=1), src1=_op(SM, step=1),
                 aam=True),
        PIMInstr(PIMOpcode.ADD, dst=_op(GB, step=1),
                 src0=_op(GA, step=1), src1=_op(GB, step=1), aam=True),
        PIMInstr(PIMOpcode.MOV, dst=_op(OB, base=BA0, step=1),
                 src0=_op(GB, step=1), aam=True),
        PIMInstr(PIMOpcode.JUMP, jump_iters=iters - 1, jump_target=1),
        PIMInstr(PIMOpcode.EXIT),
    ]


def build_mac_pep(iters: int) -> List[PIMInstr]:
    """MAC-PEP (Listing 1c): one outer-product accumulation step per pass.

    Pass ``t`` computes  acc[:, j] += sum_{i<8} A[:, k0+i] * B[k0+i, j]
    with the 8 B-scalars double-broadcast (bank -> SRF_A -> GRF_A) because
    SRF_M cannot source the MAC in AAM (paper §3.2.5).
    """
    return [
        PIMInstr(PIMOpcode.FILL, dst=_op(GB, 0), src0=_op(OB, base=BA0)),
        PIMInstr(PIMOpcode.FILL, dst=_op(SA, step=1),
                 src0=_op(EB, base=BT1, step=1, broadcast=True), aam=True),
        PIMInstr(PIMOpcode.ADD, dst=_op(GA, step=1),
                 src0=_op(EB, base=ZERO_BLOCK), src1=_op(SA, step=1),
                 aam=True),
        PIMInstr(PIMOpcode.MAC, dst=_op(GB, 0),
                 src0=_op(EB, base=BT0, step=1), src1=_op(GA, step=1),
                 aam=True),
        PIMInstr(PIMOpcode.MOV, dst=_op(OB, base=BA0), src0=_op(GB, 0)),
        PIMInstr(PIMOpcode.JUMP, jump_iters=iters - 1, jump_target=0),
        PIMInstr(PIMOpcode.EXIT),
    ]


#: column commands per loop pass (Listing 1 instruction mix)
COMMANDS_PER_PASS = {
    "add": 3 * AAM_BLOCKS,        # fill + add + mov           = 24
    "mul": 3 * AAM_BLOCKS,        # fill + mul + mov           = 24
    "sub": 4 * AAM_BLOCKS,        # fill + mul + add + mov     = 32
    "mac": 2 + 3 * AAM_BLOCKS,    # fill/mov + srf+bcast+mac   = 26
}
#: useful FLOPs per loop pass per pseudo-channel
FLOPS_PER_PASS = {
    "add": AAM_BLOCKS * SIMD_LANES * PIM_UNITS,        # 1024
    "mul": AAM_BLOCKS * SIMD_LANES * PIM_UNITS,        # 1024
    "sub": AAM_BLOCKS * SIMD_LANES * PIM_UNITS,        # 1024 (the -1 mul is overhead)
    "mac": 2 * AAM_BLOCKS * SIMD_LANES * PIM_UNITS,    # 2048 (MAC = 2 FLOP/lane)
}
SUB_PROLOGUE_COMMANDS = AAM_BLOCKS  # SRF_M init


# ---------------------------------------------------------------------------
# Tile layout <-> dense matrices
# ---------------------------------------------------------------------------


def tile_to_banks(banks: np.ndarray, base: int, tile: np.ndarray) -> None:
    """Write dense ``tile`` (M<=128, C) into bank storage at block ``base``."""
    m, c = tile.shape
    assert m <= ROWNUM, f"tile rows {m} exceed ROWNUM {ROWNUM}"
    full = np.zeros((ROWNUM, c), np.float16)
    full[:m] = tile.astype(np.float16)
    # (128, C) -> (8 banks, 16 lanes, C) -> per bank column-major blocks
    per_bank = full.reshape(PIM_UNITS, SIMD_LANES, c)
    banks[:, base:base + c, :] = np.swapaxes(per_bank, 1, 2)


def banks_to_tile(banks: np.ndarray, base: int, m: int, c: int) -> np.ndarray:
    """Read a dense (m, c) tile back from bank storage at block ``base``."""
    blk = banks[:, base:base + c, :]                    # (8, c, 16)
    return np.swapaxes(blk, 1, 2).reshape(ROWNUM, c)[:m]


def scalars_to_bank0(banks: np.ndarray, base: int, flat: np.ndarray) -> None:
    """Write a dense FP16 scalar run into even bank 0 starting at ``base``."""
    n = flat.size
    nblk = math.ceil(n / SIMD_LANES)
    buf = np.zeros(nblk * SIMD_LANES, np.float16)
    buf[:n] = flat.astype(np.float16).ravel()
    banks[0, base:base + nblk, :] = buf.reshape(nblk, SIMD_LANES)


# ---------------------------------------------------------------------------
# Invocation decomposition (paper §3.2.5 / §4.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MacInvocation:
    """One MAC-PEP launch: ``passes`` loop passes starting at global pass
    ``start`` of the (j-outer, k-inner) schedule."""

    start: int
    passes: int


def mac_pass_coords(t: int, k: int) -> Tuple[int, int]:
    """Global pass ``t`` -> (output column j, k-base k0)."""
    kc = math.ceil(k / AAM_BLOCKS)
    return t // kc, (t % kc) * AAM_BLOCKS


def mac_invocations(k: int, n: int) -> List[MacInvocation]:
    """Decompose a (128 x k x n) mfmacc into MAC-PEP launches.

    Passes walk columns j outer / k inner (FP16 accumulation order is
    exactly the hardware's); a launch is a run of <= JUMP_MAX_ITERS=256
    consecutive passes, so a single launch covers 128x2048x1 GEMV *or*
    128x8x256 GEMM (paper §3.2.5), and the paper's max tiles (K=4096,
    N=128) need the quoted 256 launches.
    """
    kc = math.ceil(k / AAM_BLOCKS)          # k-chunks of 8 per column
    total = kc * n
    out: List[MacInvocation] = []
    t = 0
    while t < total:
        passes = min(JUMP_MAX_ITERS, total - t)
        out.append(MacInvocation(start=t, passes=passes))
        t += passes
    return out


def ew_invocations(c: int) -> List[Tuple[int, int]]:
    """Element-wise launches: (col0, passes) with 8 columns per pass."""
    cc = math.ceil(c / AAM_BLOCKS)
    out = []
    i = 0
    while i < cc:
        passes = min(JUMP_MAX_ITERS, cc - i)
        out.append((i * AAM_BLOCKS, passes))
        i += passes
    return out


# ---------------------------------------------------------------------------
# Strict execution drivers (run Listing 1 on the reference interpreter)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChannelMemoryMap:
    """Block bases of the reserved regions and tile/acc registers."""

    zero: int = 0                  # all-zeros block
    minus_one: int = 1             # -1.0 vector block
    b_scalars: int = 2             # dense K-major region for the MAC B operand
    tiles: Tuple[int, ...] = ()    # tr0..tr3 bases (even banks)
    accs: Tuple[int, ...] = ()     # acc0..acc3 bases (odd banks)


def init_channel(nblocks: int, b_region_blocks: int = 2048,
                 n_tiles: int = 2, tile_cols: int = 2048) -> Tuple[PIMChannel, ChannelMemoryMap]:
    ch = PIMChannel(nblocks=nblocks)
    mm = ChannelMemoryMap()
    mm = dataclasses.replace(
        mm,
        tiles=tuple(mm.b_scalars + b_region_blocks + i * tile_cols
                    for i in range(n_tiles)),
        accs=tuple(i * tile_cols for i in range(n_tiles)),
    )
    ch.state.even_banks[:, mm.zero, :] = 0.0
    ch.state.even_banks[0, mm.minus_one, :] = np.float16(-1.0)
    return ch, mm


def run_ew_strict(ch: PIMChannel, mm: ChannelMemoryMap, kind: str,
                  a_base: int, b_base: int, acc_base: int, cols: int) -> int:
    """Run ADD/MUL/SUB-PEP launches covering ``cols`` columns; ret commands."""
    total = 0
    for col0, passes in ew_invocations(cols):
        if kind == "sub":
            crf = build_sub_pep(passes)
        else:
            crf = build_ew_pep(PIMOpcode.ADD if kind == "add" else PIMOpcode.MUL,
                               passes)

        def bases(t: int, _c0=col0) -> Dict[str, int]:
            c = _c0 + t * AAM_BLOCKS
            return {BT0: a_base + c, BT1: b_base + c, BA0: acc_base + c,
                    MINUS_ONE_BLOCK: mm.minus_one, ZERO_BLOCK: mm.zero}

        total += ch.run(crf, bases, setup_bases={MINUS_ONE_BLOCK: mm.minus_one})
    return total


def run_mac_strict(ch: PIMChannel, mm: ChannelMemoryMap,
                   a_base: int, acc_base: int, k: int, n: int) -> int:
    """Run MAC-PEP launches for acc(128 x n) += A(128 x k) @ B(k x n).

    B must already be resident K-major dense at ``mm.b_scalars`` (the
    pointer-table/mld.t layout).  Returns column commands issued.
    """
    total = 0
    for inv in mac_invocations(k, n):
        crf = build_mac_pep(inv.passes)

        def bases(t: int, _inv=inv) -> Dict[str, int]:
            j, k0 = mac_pass_coords(_inv.start + t, k)
            saddr = j * k + k0               # scalar index of B[k0, j]
            return {
                BA0: acc_base + j,
                BT0: a_base + k0,
                BT1: mm.b_scalars + saddr // SIMD_LANES,
                BT1 + "_lane": saddr % SIMD_LANES,
                ZERO_BLOCK: mm.zero,
            }

        total += ch.run(crf, bases)
    return total
