"""Deterministic, sharded, checkpointable data pipeline.

Synthetic but *learnable* streams (a fixed seeded bigram chain for text, a
fixed frame->cluster mapping for audio), so end-to-end training examples
show real loss decrease.  Determinism contract: ``batch(step)`` depends only
on (seed, step, shard), so restart-from-checkpoint resumes the exact
stream — the pipeline state IS the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


class SyntheticLM:
    """Seeded bigram-chain token stream (model can learn the chain)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                 shard: int = 0, num_shards: int = 1,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None,
                 active_vocab: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        self.batch_size = batch_override or shape.global_batch
        self.seq = seq_override or shape.seq_len
        v = min(cfg.vocab_size, active_vocab or 4096)
        self.active_vocab = v
        rng = np.random.default_rng(seed)  # FIXED chain, shared by all shards
        logits = rng.standard_normal((v, v)) * 2.0
        self.probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self.cum = np.cumsum(self.probs, axis=-1)

    def _sample_chain(self, rng: np.random.Generator, b: int, t: int):
        toks = np.empty((b, t + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.active_vocab, b)
        u = rng.random((b, t))
        for i in range(t):
            toks[:, i + 1] = (self.cum[toks[:, i]] > u[:, i:i + 1]).argmax(-1)
        return toks

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.shard)
        b = self.batch_size // self.num_shards
        cfg = self.cfg
        if cfg.modality == "audio_frames":
            targets = rng.integers(0, cfg.vocab_size, (b, self.seq),
                                   dtype=np.int32)
            proj = np.random.default_rng(self.seed).standard_normal(
                (cfg.vocab_size, cfg.d_model)).astype(np.float32)
            frames = proj[targets] * 0.1 \
                + rng.standard_normal((b, self.seq, cfg.d_model)) * 0.01
            mask = rng.random((b, self.seq)) < 0.25
            return {"frames": frames.astype(np.float32), "mask": mask,
                    "targets": targets}
        toks = self._sample_chain(rng, b, self.seq)
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "targets": toks[:, 1:].astype(np.int32),
               "loss_mask": np.ones((b, self.seq), np.float32)}
        if cfg.modality == "vision_text":
            npatch = max(self.seq // 4, 16)
            tt = self.seq - npatch
            out = {"tokens": toks[:, :tt].astype(np.int32),
                   "targets": toks[:, 1:tt + 1].astype(np.int32),
                   "loss_mask": np.ones((b, tt), np.float32),
                   "vision_embeds": rng.standard_normal(
                       (b, npatch, cfg.d_model)).astype(np.float32) * 0.1}
        return out

    def iterate(self, state: PipelineState) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(state.step)
            state.step += 1
