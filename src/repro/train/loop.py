"""Fault-tolerant training loop.

Production posture (scaled down to run anywhere, incl. this CPU host):

* checkpoint/restart: periodic async checkpoints + resume autodiscovery;
  the data-pipeline state (a step counter) rides in checkpoint meta, so a
  restart resumes the exact batch stream.
* preemption: SIGTERM/SIGINT trigger a final blocking checkpoint before
  exit (the standard TPU-maintenance handshake).
* straggler watchdog: per-step wall time EWMA; steps slower than
  ``straggler_factor`` x EWMA are counted and logged — at fleet scale this
  feeds the scheduler's hot-spare replacement policy (here: observability).
* metrics: JSONL per step.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import PipelineState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    out_dir: str = "runs/default"
    keep_ckpts: int = 3
    straggler_factor: float = 3.0


class TrainLoop:
    """Drives (params, opt_state) through ``step_fn`` with fault tolerance.

    ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
    is any compiled step (launch/steps.make_train_step or a plain jit for
    CPU-scale runs).
    """

    def __init__(self, cfg: LoopConfig, step_fn: Callable, params, opt_state,
                 pipeline, shardings=None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.pstate = PipelineState()
        self.out = Path(cfg.out_dir)
        self.out.mkdir(parents=True, exist_ok=True)
        self.ckpt = CheckpointManager(self.out / "ckpt", keep=cfg.keep_ckpts)
        self.metrics_file = self.out / "metrics.jsonl"
        self.step = 0
        self.straggler_steps = 0
        self._ewma: Optional[float] = None
        self._preempted = False
        self._shardings = shardings

    # -- fault-tolerance hooks -------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, handler)

    def try_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        (self.params, self.opt_state), meta = self.ckpt.restore(
            (self.params, self.opt_state))
        if self._shardings is not None:
            self.params, self.opt_state = jax.device_put(
                (self.params, self.opt_state), self._shardings)
        self.step = meta["step"]
        self.pstate = PipelineState.from_dict(meta["pipeline"])
        return True

    def _save(self, blocking=False):
        self.ckpt.save(self.step, (self.params, self.opt_state),
                       meta={"pipeline": self.pstate.to_dict()},
                       blocking=blocking)

    # -- main ------------------------------------------------------------------

    def run(self) -> Dict:
        self._install_signal_handlers()
        resumed = self.try_resume()
        log = self.metrics_file.open("a")
        last_metrics: Dict = {}
        while self.step < self.cfg.total_steps:
            if self._preempted:
                self._save(blocking=True)
                log.close()
                return {"status": "preempted", "step": self.step,
                        **last_metrics}
            batch = self.pipeline.batch(self.pstate.step)
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self.pstate.step += 1
            self.step += 1

            # straggler watchdog
            if self._ewma is None:
                self._ewma = dt
            else:
                if dt > self.cfg.straggler_factor * self._ewma:
                    self.straggler_steps += 1
                self._ewma = 0.9 * self._ewma + 0.1 * dt

            last_metrics = {k: float(np.asarray(v)) for k, v in
                            metrics.items()}
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                rec = dict(step=self.step, sec_per_step=round(dt, 4),
                           stragglers=self.straggler_steps,
                           resumed=resumed, **last_metrics)
                log.write(json.dumps(rec) + "\n")
                log.flush()
            if self.step % self.cfg.ckpt_every == 0:
                self._save()
        self._save(blocking=True)
        log.close()
        return {"status": "done", "step": self.step,
                "stragglers": self.straggler_steps, **last_metrics}
