"""Batched serving loop: slot-based continuous batching.

A fixed decode batch of ``slots``; finished sequences free their slot and
the next queued request is prefilled into it.  Greedy sampling (argmax);
the decode step is a single compiled function over the whole slot batch,
caches donated in place — the production shape of vLLM-style serving,
scaled to run on this host with reduced configs.

Pass ``pim_offload=DecodeOffload(cfg, ...)`` to mirror every decode
step's matmuls onto a resident-weight PIM runtime (balanced placement,
weights uploaded once): the sidecar accumulates a per-step PIM-vs-host
roofline without touching the serving numerics — see
:mod:`repro.serve.offload`.  With ``DecodeOffload(cfg, numeric=True)``
(small configs) the sidecar additionally executes each step's matmul set
on the per-channel engines and cross-checks every output — lm_head
logits included — against an XLA reference within FP16 accumulation
tolerance, while charging the same ledgers as the analytic sidecar.

Graceful degradation (:mod:`repro.faults`): ``Server(faults=...)``
accepts a :class:`~repro.faults.plan.FaultPlan` (or DSL string) and
consumes its :class:`~repro.faults.plan.ServeFault` entries — the
request decoding in the named slot at the named iteration is knocked
out and requeued with per-request exponential backoff
(``retry_backoff_steps`` doubling per retry, capped), failing
permanently after ``max_retries``.  ``step_deadline_s`` counts
over-deadline serving iterations; ``max_queue`` turns :meth:`submit`
into admission control that sheds load (:class:`AdmissionError`) when
the queue exceeds the cap *scaled by surviving PIM capacity* — a
half-dead offload cluster halves what the server accepts.

Virtual time (:mod:`repro.serve.traffic`): request timestamps
(``submitted_at`` / ``admitted_at`` / ``first_token_at`` /
``finished_at``) are stamped from a :class:`~repro.serve.traffic.
SimClock` by default — admission advances it by the host-prefill
roofline, each decode iteration by the offload's ``StepRecord.pim_s``
(or the host decode roofline without a sidecar) — so
:meth:`Server.latency_summary` percentiles are deterministic and
machine-independent.  ``Server(wall=True)`` restores wall-clock
stamping for live measurement.

:class:`TrafficServer` is the load-study twin: it drives a
:class:`~repro.serve.offload.DecodeOffload` under a stochastic arrival
:class:`~repro.serve.traffic.Trace` entirely in virtual time, with
prefill/decode **disaggregation** — prefill batches priced on the host
XLA roofline while decode steps stay PIM-resident, the prefilled KV
handed off across the shared :class:`~repro.runtime.cluster.
HostLinkLedger` as clocked ``"prefill"`` busy windows (decode's
activations as ``"acts"``), chunked-prefill interleaving, admission
control, slot autoscaling policies, and TTFT/TPOT/goodput SLO
accounting.  ``disaggregate=False`` is the colocated baseline: the
same chunks serialize on the decode lane.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.isa import PIM_FREQ_HZ
from repro.models import model as lm
from repro.obs.metrics import Histogram
from repro.runtime.cluster import HostLinkLedger
from repro.serve.offload import DecodeOffload
from repro.serve.traffic import (SLO, HostCostModel, SimClock, Trace,
                                 TraceRequest, WallClock)


class AdmissionError(RuntimeError):
    """Admission control shed this request (queue over the surviving-
    capacity-scaled cap).  Callers should back off and resubmit."""


# eq=False: the generated __eq__ would compare the ndarray prompt field
# and raise "truth value is ambiguous" on membership tests (req in
# queue); identity is the right request equality anyway — uid is the
# stable name across retries
@dataclasses.dataclass(eq=False)
class Request:
    uid: int
    prompt: np.ndarray              # (Tp,) int32
    max_new: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    admitted_at: float = 0.0        # left the queue (prefill started)
    first_token_at: float = 0.0     # prefill produced the first token
    finished_at: float = 0.0
    retries: int = 0                # fault knock-outs survived so far
    not_before: int = 0             # earliest serving iteration to re-admit


class Server:
    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 cache_len: int = 128, eos_id: Optional[int] = None,
                 pim_offload: Optional[DecodeOffload] = None,
                 metrics=None, faults=None,
                 step_deadline_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 retry_backoff_steps: int = 2,
                 retry_backoff_cap: int = 16,
                 max_retries: int = 2,
                 wall: bool = False, clock=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.pim_offload = pim_offload
        # virtual-time stamping (deterministic latency percentiles) by
        # default; wall=True keeps the old time.time() stamps for live
        # measurement, and an explicit clock= shares one SimClock
        # across several servers
        self.clock = clock if clock is not None \
            else (WallClock() if wall else SimClock())
        self.cost = HostCostModel(cfg)
        # repro.obs registry for serve.* latency metrics (TTFT/TPOT per
        # request, step wall time); pass the same registry to the
        # offload sidecar to merge runtime streams into one snapshot
        self.metrics = metrics
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)
        self.caches = lm.make_caches(cfg, slots, cache_len)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # -- graceful degradation state (all zero / empty without faults)
        self.step_deadline_s = step_deadline_s
        self.max_queue = max_queue
        self.retry_backoff_steps = retry_backoff_steps
        self.retry_backoff_cap = retry_backoff_cap
        self.max_retries = max_retries
        self.failed_requests: List[Request] = []
        self.shed = 0                   # submissions refused at admission
        self.deadline_misses = 0        # serving iterations over deadline
        self.retries_total = 0          # fault knock-outs requeued
        self.undrained = 0              # left pending by run_until_drained
        self._iter = 0                  # serving-iteration counter (1-based)
        self._serve_faults: List = []
        if faults is not None:
            from repro.faults.plan import as_plan
            self._serve_faults = sorted(
                as_plan(faults).serve_faults,
                key=lambda f: (f.at_iter, f.slot))

        self._decode = jax.jit(
            lambda p, t, ps, c: lm.decode_step(p, t, ps, c, cfg),
            donate_argnums=(3,))
        self._prefill_one = jax.jit(
            lambda p, toks: lm.prefill(p, {"tokens": toks}, cfg,
                                       cache_len=cache_len))

    def _check_prompt(self, req: Request) -> None:
        """A prompt must leave at least one cache position for decode —
        longer ones would silently corrupt the slot cache at prefill."""
        if len(req.prompt) >= self.cache_len:
            raise ValueError(
                f"prompt of request uid={req.uid} has {len(req.prompt)} "
                f"tokens but cache_len={self.cache_len} leaves no room "
                f"to decode — truncate the prompt or grow cache_len")

    @property
    def _kv(self):
        """The offload sidecar's KV manager when KV-resident attention
        is on (``DecodeOffload(kv_offload=True)``), else None — every
        hook below is a no-op without it."""
        off = self.pim_offload
        return off.kv if off is not None else None

    @property
    def surviving_fraction(self) -> float:
        """Fraction of PIM decode capacity still alive (1.0 without an
        offload sidecar or without faults) — scales the admission cap."""
        off = self.pim_offload
        return off.surviving_fraction if off is not None else 1.0

    def submit(self, req: Request):
        self._check_prompt(req)
        if self.max_queue is not None:
            cap = max(1, int(self.max_queue * self.surviving_fraction))
            if len(self.queue) >= cap:
                self.shed += 1
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.shed", unit="requests",
                        help="submissions shed by admission control").inc()
                raise AdmissionError(
                    f"queue at {len(self.queue)} >= cap {cap} "
                    f"(max_queue={self.max_queue}, surviving="
                    f"{self.surviving_fraction:.2f}); shedding "
                    f"request uid={req.uid}")
        req.submitted_at = self.clock.now
        self.queue.append(req)

    def _apply_serve_faults(self):
        """Fire ServeFaults due this iteration: knock out the slot's
        request and requeue it with exponential backoff (or fail it
        permanently past max_retries)."""
        due = [f for f in self._serve_faults if f.at_iter == self._iter]
        if not due:
            return
        self._serve_faults = [f for f in self._serve_faults
                              if f.at_iter != self._iter]
        for f in due:
            if f.slot >= self.slots or self.active[f.slot] is None:
                continue
            req = self.active[f.slot]
            self.active[f.slot] = None
            # the slot's cache is considered poisoned: restart the
            # request from its prompt (prefill re-runs on re-admission);
            # its PIM-resident KV drops with it
            if self._kv is not None:
                self.pim_offload.kv_release(req.uid)
            req.out_tokens = []
            req.first_token_at = 0.0
            req.retries += 1
            if req.retries > self.max_retries:
                req.done = True
                req.finished_at = self.clock.now
                self.failed_requests.append(req)
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.failed", unit="requests",
                        help="requests failed past max_retries").inc()
                continue
            backoff = min(
                self.retry_backoff_steps * 2 ** (req.retries - 1),
                self.retry_backoff_cap)
            req.not_before = self._iter + backoff
            self.queue.append(req)
            self.retries_total += 1
            if self.metrics is not None:
                self.metrics.counter(
                    "serve.retries", unit="requests",
                    help="fault knock-outs requeued with backoff").inc()

    def _admit(self):
        """Prefill queued requests into free slots (FIFO among requests
        whose retry backoff has elapsed)."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                idx = next((j for j, r in enumerate(self.queue)
                            if r.not_before <= self._iter), None)
                if idx is None:
                    return           # everything queued is backing off
                req = self.queue.pop(idx)
                self._check_prompt(req)
                req.admitted_at = self.clock.now
                if self.metrics is not None:
                    self.metrics.histogram(
                        "serve.queue_delay_s", unit="s",
                        help="queue wait (submit -> prefill start)"
                    ).record(req.admitted_at - req.submitted_at)
                logits, fresh = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None, :]))
                # splice slot i's cache from the single-seq prefill cache
                self.caches = jax.tree.map(
                    lambda full, one, _i=i: _splice(full, one, _i, self.cfg),
                    self.caches, fresh)
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                # the prefill's argmax IS the request's first token:
                # TTFT closes here, before any decode step runs.  The
                # virtual clock charges the host-prefill roofline (a
                # WallClock ignores the advance and reads real time)
                self.clock.advance(self.cost.prefill_s(len(req.prompt)))
                req.first_token_at = self.clock.now
                if self.metrics is not None:
                    self.metrics.histogram(
                        "serve.ttft_s", unit="s",
                        help="time to first token (submit -> prefill "
                             "argmax)").record(
                        req.first_token_at - req.submitted_at)
                self.active[i] = req
                self.pos[i] = len(req.prompt)
                # host prefill produced the prompt's KV: ship it onto
                # the sidecar's PIM pages once, decode grows it in place
                if self._kv is not None:
                    self.pim_offload.kv_prefill(req.uid, len(req.prompt))

    def _retire(self, i: int):
        req = self.active[i]
        req.done = True
        req.finished_at = self.clock.now
        self.completed.append(req)
        self.active[i] = None
        if self._kv is not None:
            self.pim_offload.kv_release(req.uid)
        if self.metrics is not None:
            m = self.metrics
            m.counter("serve.requests", unit="requests",
                      help="requests completed").inc()
            m.counter("serve.tokens", unit="tokens",
                      help="tokens generated (first token included)").inc(
                len(req.out_tokens))
            if len(req.out_tokens) >= 2:      # TPOT needs a decode tail
                m.histogram(
                    "serve.tpot_s", unit="s",
                    help="time per output token after the first").record(
                    (req.finished_at - req.first_token_at)
                    / (len(req.out_tokens) - 1))

    def step(self):
        """One serving iteration: fire serve faults, admit, batched
        decode, retire; count the iteration against the step deadline."""
        track_wall = self.metrics is not None \
            or self.step_deadline_s is not None
        t0 = time.time() if track_wall else 0.0
        self._iter += 1
        self._apply_serve_faults()
        self._admit()
        live = [i for i in range(self.slots) if self.active[i] is not None]
        if not live:
            # backing-off requests still count as pending work: report
            # True so run_until_drained keeps iterating toward their
            # re-admission instead of spinning the caller's loop exit
            return bool(self.queue)
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            toks[i, 0] = self.active[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks),
            jnp.asarray(self.pos), self.caches)
        rec = None
        if self.pim_offload is not None:
            rec = self.pim_offload.step(
                len(live),
                request_ids=[self.active[i].uid for i in live])
        # the decode iteration's virtual duration: the PIM step's clocked
        # makespan when a sidecar ran it, else the host decode roofline
        self.clock.advance(rec.pim_s if rec is not None
                           else self.cost.decode_step_s(len(live)))
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            hit_eos = self.eos_id is not None and int(nxt[i]) == self.eos_id
            if (len(req.out_tokens) >= req.max_new or hit_eos
                    or int(self.pos[i]) >= self.cache_len - 1):
                self._retire(i)
        if track_wall:
            wall = time.time() - t0
            if self.step_deadline_s is not None \
                    and wall > self.step_deadline_s:
                self.deadline_misses += 1
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.deadline_misses", unit="steps",
                        help="serving iterations over step_deadline_s"
                    ).inc()
            if self.metrics is not None:
                self.metrics.histogram(
                    "serve.step_s", unit="s",
                    help="serving-iteration wall time").record(wall)
                self.metrics.gauge(
                    "serve.live_slots", unit="slots",
                    help="slots decoding in the last iteration").set(
                    len(live))
        return True

    def run_until_drained(self, max_iters: int = 10_000,
                          on_undrained: str = "raise"):
        """Step until every request completes (or fails permanently).

        If ``max_iters`` exhausts with requests still queued or active,
        the default ``on_undrained="raise"`` raises ``RuntimeError`` —
        a hung serving loop must not masquerade as success.
        ``on_undrained="warn"`` downgrades to a ``RuntimeWarning`` and
        returns the partial results; either way the pending count is
        recorded in :attr:`undrained` / :meth:`latency_summary`.
        """
        if on_undrained not in ("raise", "warn"):
            raise ValueError(
                f"on_undrained must be 'raise' or 'warn', "
                f"got {on_undrained!r}")
        it = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and it < max_iters:
            self.step()
            it += 1
        self.undrained = len(self.queue) \
            + sum(a is not None for a in self.active)
        if self.undrained:
            msg = (f"run_until_drained exhausted max_iters={max_iters} "
                   f"with {self.undrained} request(s) still "
                   f"queued/active ({len(self.completed)} completed)")
            if on_undrained == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return self.completed

    def latency_summary(self) -> Dict:
        """TTFT/TPOT/queue-delay percentile summary over completed
        requests (p50/p90/p99/p99.9 — virtual seconds by default, so
        identical across runs and machines; wall seconds with
        ``wall=True``).

        Computed from the request timestamps directly, so it works with
        or without an attached metrics registry.  TTFT is submit ->
        prefill argmax; TPOT divides the decode tail by the tokens after
        the first (requests with a single token report no TPOT sample);
        queue delay is submit -> prefill start.
        """
        ttft = Histogram("serve.ttft_s", unit="s")
        tpot = Histogram("serve.tpot_s", unit="s")
        qdel = Histogram("serve.queue_delay_s", unit="s")
        for req in self.completed:
            if req.first_token_at:
                ttft.record(req.first_token_at - req.submitted_at)
                qdel.record(req.admitted_at - req.submitted_at)
                if req.finished_at and len(req.out_tokens) >= 2:
                    tpot.record((req.finished_at - req.first_token_at)
                                / (len(req.out_tokens) - 1))
        return {
            "requests": len(self.completed),
            "tokens": sum(len(r.out_tokens) for r in self.completed),
            "ttft_s": _pct_summary(ttft),
            "tpot_s": _pct_summary(tpot),
            "queue_delay_s": _pct_summary(qdel),
            # degradation accounting (all zero on a fault-free run)
            "undrained": self.undrained,
            "failed": len(self.failed_requests),
            "shed": self.shed,
            "deadline_misses": self.deadline_misses,
            "retries": self.retries_total,
        }


def _pct_summary(h: Histogram) -> Dict:
    """``Histogram.summary()`` plus the serving tail the SLO studies
    read (p99.9)."""
    s = h.summary()
    s["p99.9"] = h.percentile(99.9)
    return s


# ---------------------------------------------------------------------------
# Traffic-driven virtual-time serving: prefill/decode disaggregation
# ---------------------------------------------------------------------------


class _BusyLane:
    """One contended resource as a set of reserved busy intervals.

    A scalar "free after the last reservation" clock is wrong for the
    host link: prefill KV handoffs are reserved *into the future* (each
    chunk ships only after its compute lands), and a decode step's tiny
    activation window arriving *now* must be allowed to use the idle
    gap in front of them instead of queueing behind the whole prefill
    pipeline.  ``reserve`` places a duration at the earliest gap at or
    after ``ready`` — first-fit, which is exactly link arbitration with
    no preemption.
    """

    def __init__(self):
        self._busy: List[Tuple[float, float]] = []   # sorted, disjoint

    def prune(self, now: float) -> None:
        """Drop intervals that ended before ``now`` — reservations are
        never placed in the past, so they can no longer collide."""
        self._busy = [iv for iv in self._busy if iv[1] > now]

    def reserve(self, ready: float, dur: float) -> Tuple[float, float]:
        """Occupy the lane for ``dur`` seconds starting at the earliest
        instant >= ``ready`` with no overlap; returns ``(start, end)``."""
        if dur <= 0:
            return ready, ready
        t = ready
        at = 0
        for i, (s, e) in enumerate(self._busy):
            if s - t >= dur:        # fits in the gap before interval i
                at = i
                break
            t = max(t, e)
            at = i + 1
        self._busy.insert(at, (t, t + dur))
        return t, t + dur


class TrafficServer:
    """Virtual-time load simulator: a decode-resident PIM server under a
    stochastic arrival :class:`~repro.serve.traffic.Trace`.

    Where :class:`Server` runs the actual XLA model on reduced configs,
    ``TrafficServer`` *clocks* serving at paper scale: every duration
    comes from the analytic cost substrate (the offload's per-step PIM
    makespan, the :class:`~repro.serve.traffic.HostCostModel` prefill
    roofline, and :func:`~repro.runtime.cluster.host_link_cycles` for
    everything crossing the host link), so hundreds-to-thousands of
    requests simulate in milliseconds and every latency percentile is
    deterministic and machine-independent.

    Three resources contend, each a monotonic "free at" lane in virtual
    seconds:

    * the **host XLA device** (prefill chunks — compute the prompt's KV
      and first token);
    * the **shared host link** (prefilled KV handed off to PIM pages as
      ``"prefill"`` windows, per-decode-step activations as ``"acts"``
      windows — charged on the offload cluster's own
      :class:`~repro.runtime.cluster.HostLinkLedger` when it has one,
      so they land in its trace);
    * the **PIM decode pipeline** (batched decode steps, priced by the
      offload's :class:`~repro.serve.offload.StepRecord`).

    ``disaggregate=True`` (default) lets the host lane prefill ahead
    while PIM decodes — the two phases contend only on the link.
    ``disaggregate=False`` is the **colocated** baseline: prefill
    chunks serialize on the decode lane (one chunk per live prefilling
    request per serving iteration — classic chunked-prefill continuous
    batching), stalling decode exactly as a single-pipeline server
    does.  ``chunk_tokens`` bounds that stall in both modes.

    Admission control (``max_queue``, arrivals shed beyond it), slot
    autoscaling (``autoscale=`` one of the :mod:`repro.serve.traffic`
    policies), and an :class:`~repro.serve.traffic.SLO` for
    goodput/attainment accounting complete the load study.  With
    ``kv_offload`` sidecars the KV lifecycle (``kv_prefill`` at
    handoff, ``kv_release`` at retire) runs for real; analytic decode
    step costs are probed once per distinct batch size
    (``cache_steps``; exact per-iteration stepping is forced when the
    step cost is stateful, i.e. the KV cache grows).

    Strictly additive: constructing one and running an empty trace
    leaves the offload's ledgers ``==``-equal and its trace
    byte-identical — the traffic layer charges nothing until traffic
    exists.
    """

    def __init__(self, offload: DecodeOffload, *, slots: int = 4,
                 disaggregate: bool = True, chunk_tokens: int = 256,
                 max_queue: Optional[int] = None, autoscale=None,
                 slo: Optional[SLO] = None, metrics=None, clock=None,
                 cost: Optional[HostCostModel] = None,
                 cache_steps: Optional[bool] = None,
                 step_costs: Optional[Dict[int, Tuple[float, int]]] = None):
        if offload.async_mode:
            raise ValueError(
                "TrafficServer clocks its own virtual lanes; drive it "
                "with a serialized (async_mode=False) offload")
        self.off = offload
        self.cfg = offload.cfg
        self.cost = cost if cost is not None else HostCostModel(offload.cfg)
        self.slots = slots
        self.disaggregate = disaggregate
        self.chunk_tokens = max(1, chunk_tokens)
        self.max_queue = max_queue
        self.autoscale = autoscale
        self.slo = slo
        self.metrics = metrics
        self.clock = clock if clock is not None else SimClock()
        # analytic StepRecords are pure functions of the batch size, so
        # one probe step per distinct batch prices every iteration; a
        # growing KV cache makes the cost stateful -> step exactly
        self.cache_steps = (offload.kv is None) if cache_steps is None \
            else cache_steps
        self._step_costs: Dict[int, Tuple[float, int]] = \
            step_costs if step_costs is not None else {}
        # the shared host link: the offload cluster's ledger when it has
        # one (multi-stack — handoff windows then land in its trace),
        # else a sim-owned ledger with identical accounting
        stack = offload.rt.stack
        self.link: HostLinkLedger = getattr(stack, "link", None) \
            or HostLinkLedger()
        # -- virtual lanes: host and PIM are monotonic "free at" times
        # (their work is always scheduled at the current sim time); the
        # link takes future reservations, so it books busy intervals --
        self._host_free_s = 0.0         # host XLA prefill lane
        self._pim_free_s = 0.0          # PIM decode lane
        self._link_lane = _BusyLane()
        if self.link.tl_free > 0:       # respect prior async occupancy
            self._link_lane.reserve(0.0, self.link.tl_free / PIM_FREQ_HZ)
        # -- request state --
        self.queue: List[Request] = []
        self.active: List[Request] = []         # decode-resident
        self.prefilling: List[Request] = []     # colocated chunk progress
        self._tokens_left: Dict[int, int] = {}  # colocated prefill tokens
        self._ready_s: Dict[int, float] = {}    # uid -> KV handoff done
        self._last_tok_s: Dict[int, float] = {}
        self.completed: List[Request] = []
        self.shed_requests: List[TraceRequest] = []
        self.shed = 0
        self.iterations = 0
        self.slots_max_seen = slots
        self.max_decode_gap_s = 0.0     # worst inter-token decode stall
        self._recent_ttft: List[float] = []

    # -- resource lanes -------------------------------------------------------

    def _link_window(self, kind: str, nbytes: int,
                     ready_s: float) -> Tuple[float, float]:
        """Charge ``nbytes`` on the shared host link as one ``kind``
        event and occupy the link lane for its clocked duration starting
        no earlier than ``ready_s``; returns ``(start, end)`` seconds."""
        if nbytes <= 0:
            return ready_s, ready_s
        cyc = self.link.charge(kind, nbytes)
        self._link_lane.prune(self.clock.now)
        start, end = self._link_lane.reserve(ready_s, cyc / PIM_FREQ_HZ)
        self.link.tl_free = max(self.link.tl_free, end * PIM_FREQ_HZ)
        return start, end

    def _step_cost(self, batch: int,
                   rids: List[int]) -> Tuple[float, int]:
        """One decode iteration's ``(pim_s, h2d_bytes)`` over ``batch``
        slots — probed once per distinct batch when cacheable."""
        if not self.cache_steps:
            rec = self.off.step(batch, request_ids=rids)
            return rec.pim_s, rec.h2d_bytes
        if batch not in self._step_costs:
            rec = self.off.step(batch)
            self._step_costs[batch] = (rec.pim_s, rec.h2d_bytes)
        return self._step_costs[batch]

    @property
    def routing_observed(self):
        """The offload's observed per-layer expert-selection histogram
        (a :class:`~repro.serve.traffic.RoutingProfile`), or ``None``
        when the offload is not routed (``routing=None``)."""
        return self.off.observed

    # -- request lifecycle ----------------------------------------------------

    def _arrive(self, tr: TraceRequest) -> None:
        if self.max_queue is not None:
            cap = max(1, int(self.max_queue * self.off.surviving_fraction))
            if len(self.queue) >= cap:
                self.shed += 1
                self.shed_requests.append(tr)
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.shed", unit="requests",
                        help="arrivals shed by admission control").inc()
                return
        req = Request(uid=tr.uid,
                      prompt=np.zeros((tr.prompt_len,), np.int32),
                      max_new=tr.max_new, submitted_at=tr.at_s)
        self.queue.append(req)

    def _admit(self, req: Request) -> None:
        now = self.clock.now
        req.admitted_at = now
        if self.metrics is not None:
            self.metrics.histogram(
                "serve.queue_delay_s", unit="s",
                help="queue wait (arrival -> prefill start)").record(
                now - req.submitted_at)
        if not self.disaggregate:
            # colocated: chunks serialize on the decode lane, one per
            # serving iteration (see _prefill_chunk_colocated)
            self._tokens_left[req.uid] = len(req.prompt)
            self.prefilling.append(req)
            return
        # disaggregated: the whole chunked prefill schedules on the host
        # lane right now; each chunk's KV hands off over the link as
        # soon as its compute lands.  TTFT closes at the last chunk's
        # compute (the prefill argmax); decode may start once the last
        # handoff clears the link.
        tokens, t, ready = len(req.prompt), now, now
        while tokens > 0:
            ct = min(self.chunk_tokens, tokens)
            tokens -= ct
            cs = max(t, self._host_free_s)
            ce = cs + self.cost.prefill_s(ct)
            self._host_free_s = t = ce
            _, ready = self._link_window(
                "prefill", self.cost.kv_ship_bytes(ct), ce)
        req.first_token_at = t
        self._finish_prefill(req, ready)

    def _prefill_chunk_colocated(self, req: Request) -> None:
        """Advance one colocated request's prefill by one chunk *on the
        decode lane* — the serialization that makes colocated serving
        stall, and exactly what ``chunk_tokens`` bounds."""
        ct = min(self.chunk_tokens, self._tokens_left[req.uid])
        cs = max(self.clock.now, self._pim_free_s)
        ce = cs + self.cost.prefill_s(ct)
        self._pim_free_s = ce
        _, ready = self._link_window(
            "prefill", self.cost.kv_ship_bytes(ct), ce)
        self.clock.advance_to(ce)
        self._tokens_left[req.uid] -= ct
        if self._tokens_left[req.uid] <= 0:
            del self._tokens_left[req.uid]
            self.prefilling.remove(req)
            req.first_token_at = ce
            self._finish_prefill(req, ready)

    def _finish_prefill(self, req: Request, ready_s: float) -> None:
        req.out_tokens.append(0)        # the prefill argmax (token 1)
        ttft = req.first_token_at - req.submitted_at
        self._recent_ttft.append(ttft)
        if self.metrics is not None:
            self.metrics.histogram(
                "serve.ttft_s", unit="s",
                help="time to first token (arrival -> prefill argmax)"
            ).record(ttft)
        self._ready_s[req.uid] = ready_s
        self._last_tok_s[req.uid] = req.first_token_at
        self.active.append(req)
        if self.off.kv is not None:
            self.off.kv_prefill(req.uid, len(req.prompt))

    def _decode_step(self) -> bool:
        """One batched decode iteration over every handoff-complete
        active request; returns False when none is eligible yet."""
        now = self.clock.now
        eligible = [r for r in self.active if self._ready_s[r.uid] <= now]
        if not eligible:
            return False
        pim_s, h2d = self._step_cost(len(eligible),
                                     [r.uid for r in eligible])
        # the step's activations cross the link, then PIM computes
        _, le = self._link_window("acts", h2d, now)
        ds = max(le, self._pim_free_s)
        de = ds + pim_s
        self._pim_free_s = de
        self.clock.advance_to(de)
        for req in eligible:
            self.max_decode_gap_s = max(
                self.max_decode_gap_s, de - self._last_tok_s[req.uid])
            self._last_tok_s[req.uid] = de
            req.out_tokens.append(0)
            if len(req.out_tokens) >= req.max_new:
                self._retire(req, de)
        return True

    def _retire(self, req: Request, at_s: float) -> None:
        req.done = True
        req.finished_at = at_s
        self.active.remove(req)
        del self._ready_s[req.uid], self._last_tok_s[req.uid]
        self.completed.append(req)
        if self.off.kv is not None:
            self.off.kv_release(req.uid)
        if self.metrics is not None:
            m = self.metrics
            m.counter("serve.requests", unit="requests",
                      help="requests completed").inc()
            m.counter("serve.tokens", unit="tokens",
                      help="tokens generated (first token included)").inc(
                len(req.out_tokens))
            if len(req.out_tokens) >= 2:
                m.histogram(
                    "serve.tpot_s", unit="s",
                    help="time per output token after the first").record(
                    (req.finished_at - req.first_token_at)
                    / (len(req.out_tokens) - 1))

    # -- the serving loop -----------------------------------------------------

    def run(self, trace: Trace, max_iters: int = 2_000_000
            ) -> List[Request]:
        """Replay ``trace`` to completion; returns the completed
        requests (``latency_summary`` aggregates them)."""
        pending = list(trace)
        pi, n = 0, len(pending)
        while pi < n or self.queue or self.active or self.prefilling:
            self.iterations += 1
            if self.iterations > max_iters:
                raise RuntimeError(
                    f"traffic simulation exceeded max_iters={max_iters} "
                    f"({len(self.completed)} completed, "
                    f"{len(self.queue)} queued)")
            now = self.clock.now
            while pi < n and pending[pi].at_s <= now:
                self._arrive(pending[pi])
                pi += 1
            if self.autoscale is not None:
                live = len(self.active) + len(self.prefilling)
                self.slots = max(1, self.autoscale.target(
                    queue_len=len(self.queue), slots=self.slots,
                    live=live, recent_ttft=self._recent_ttft))
                self.slots_max_seen = max(self.slots_max_seen, self.slots)
            if self.metrics is not None:
                self.metrics.gauge(
                    "serve.queue_depth", unit="requests",
                    help="queued requests at iteration start").set(
                    len(self.queue))
                self.metrics.gauge(
                    "serve.slots", unit="slots",
                    help="decode slot capacity (autoscaled)").set(
                    self.slots)
            while self.queue and \
                    len(self.active) + len(self.prefilling) < self.slots:
                self._admit(self.queue.pop(0))
            for req in list(self.prefilling):
                self._prefill_chunk_colocated(req)
            stepped = self._decode_step() if self.active else False
            if stepped or self.prefilling or self.clock.now > now:
                continue
            # idle: jump to the next event (an arrival, or a pending
            # KV handoff completing)
            horizon = []
            if self.active:
                horizon.append(min(self._ready_s[r.uid]
                                   for r in self.active))
            if pi < n:
                horizon.append(pending[pi].at_s)
            if not horizon:
                raise RuntimeError(
                    "traffic simulation stalled with work pending — "
                    "this is a scheduler bug")
            self.clock.advance_to(min(horizon))
        return self.completed

    # -- reporting ------------------------------------------------------------

    def latency_summary(self) -> Dict:
        """Load-study summary: latency percentiles (virtual seconds),
        throughput, and — with an :class:`~repro.serve.traffic.SLO`
        attached — attainment and goodput.

        Attainment counts shed arrivals as SLO misses (shedding is a
        service failure from the client's side); goodput is SLO-met
        completions per second of simulated serving time.
        """
        ttft = Histogram("serve.ttft_s", unit="s")
        tpot = Histogram("serve.tpot_s", unit="s")
        qdel = Histogram("serve.queue_delay_s", unit="s")
        met = 0
        for req in self.completed:
            t = req.first_token_at - req.submitted_at
            ttft.record(t)
            qdel.record(req.admitted_at - req.submitted_at)
            p = None
            if len(req.out_tokens) >= 2:
                p = (req.finished_at - req.first_token_at) \
                    / (len(req.out_tokens) - 1)
                tpot.record(p)
            if self.slo is not None and self.slo.met(t, p):
                met += 1
        span = max((r.finished_at for r in self.completed),
                   default=self.clock.now) or 1e-12
        offered = len(self.completed) + self.shed
        out = {
            "requests": len(self.completed),
            "shed": self.shed,
            "tokens": sum(len(r.out_tokens) for r in self.completed),
            "duration_s": span,
            "throughput_rps": len(self.completed) / span,
            "ttft_s": _pct_summary(ttft),
            "tpot_s": _pct_summary(tpot),
            "queue_delay_s": _pct_summary(qdel),
            "max_decode_gap_s": self.max_decode_gap_s,
            "iterations": self.iterations,
            "slots_max": self.slots_max_seen,
            "link_prefill_bytes": sum(
                b for k, b in self.link.events if k == "prefill"),
            "link_acts_bytes": sum(
                b for k, b in self.link.events if k == "acts"),
        }
        if self.slo is not None:
            out["slo"] = {"ttft_s": self.slo.ttft_s,
                          "tpot_s": self.slo.tpot_s}
            out["slo_met"] = met
            out["slo_attainment"] = met / offered if offered else 0.0
            out["goodput_rps"] = met / span
        return out


def _splice(full, one, slot: int, cfg: ArchConfig):
    """Write the single-sequence prefill cache ``one`` into batch slot
    ``slot`` of the server cache ``full``.  Cache layouts put batch at
    axis 1 (layer-stacked) for every family."""
    # trim/pad the sequence axis if the prefill cache is longer/shorter
    if one.shape != full.shape:
        pads = []
        slc = []
        for a, (fo, oo) in enumerate(zip(full.shape, one.shape)):
            if a == 1:      # batch axis
                pads.append((0, 0))
                slc.append(slice(0, oo))
            else:
                pads.append((0, max(0, fo - oo)))
                slc.append(slice(0, min(fo, oo)))
        one = jnp.pad(one[tuple(slc)], pads)
    return jax.lax.dynamic_update_index_in_dim(full, one[:, :1], slot, 1)
