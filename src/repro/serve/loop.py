"""Batched serving loop: slot-based continuous batching.

A fixed decode batch of ``slots``; finished sequences free their slot and
the next queued request is prefilled into it.  Greedy sampling (argmax);
the decode step is a single compiled function over the whole slot batch,
caches donated in place — the production shape of vLLM-style serving,
scaled to run on this host with reduced configs.

Pass ``pim_offload=DecodeOffload(cfg, ...)`` to mirror every decode
step's matmuls onto a resident-weight PIM runtime (balanced placement,
weights uploaded once): the sidecar accumulates a per-step PIM-vs-host
roofline without touching the serving numerics — see
:mod:`repro.serve.offload`.  With ``DecodeOffload(cfg, numeric=True)``
(small configs) the sidecar additionally executes each step's matmul set
on the per-channel engines and cross-checks every output — lm_head
logits included — against an XLA reference within FP16 accumulation
tolerance, while charging the same ledgers as the analytic sidecar.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as lm
from repro.obs.metrics import Histogram
from repro.serve.offload import DecodeOffload


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (Tp,) int32
    max_new: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0     # prefill produced the first token
    finished_at: float = 0.0


class Server:
    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 cache_len: int = 128, eos_id: Optional[int] = None,
                 pim_offload: Optional[DecodeOffload] = None,
                 metrics=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.pim_offload = pim_offload
        # repro.obs registry for serve.* latency metrics (TTFT/TPOT per
        # request, step wall time); pass the same registry to the
        # offload sidecar to merge runtime streams into one snapshot
        self.metrics = metrics
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)
        self.caches = lm.make_caches(cfg, slots, cache_len)
        self.queue: List[Request] = []
        self.completed: List[Request] = []

        self._decode = jax.jit(
            lambda p, t, ps, c: lm.decode_step(p, t, ps, c, cfg),
            donate_argnums=(3,))
        self._prefill_one = jax.jit(
            lambda p, toks: lm.prefill(p, {"tokens": toks}, cfg,
                                       cache_len=cache_len))

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self):
        """Prefill queued requests into free slots."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                logits, fresh = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None, :]))
                # splice slot i's cache from the single-seq prefill cache
                self.caches = jax.tree.map(
                    lambda full, one, _i=i: _splice(full, one, _i, self.cfg),
                    self.caches, fresh)
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                # the prefill's argmax IS the request's first token:
                # TTFT closes here, before any decode step runs
                req.first_token_at = time.time()
                if self.metrics is not None:
                    self.metrics.histogram(
                        "serve.ttft_s", unit="s",
                        help="time to first token (submit -> prefill "
                             "argmax)").record(
                        req.first_token_at - req.submitted_at)
                self.active[i] = req
                self.pos[i] = len(req.prompt)

    def _retire(self, i: int):
        req = self.active[i]
        req.done = True
        req.finished_at = time.time()
        self.completed.append(req)
        self.active[i] = None
        if self.metrics is not None:
            m = self.metrics
            m.counter("serve.requests", unit="requests",
                      help="requests completed").inc()
            m.counter("serve.tokens", unit="tokens",
                      help="tokens generated (first token included)").inc(
                len(req.out_tokens))
            if len(req.out_tokens) >= 2:      # TPOT needs a decode tail
                m.histogram(
                    "serve.tpot_s", unit="s",
                    help="time per output token after the first").record(
                    (req.finished_at - req.first_token_at)
                    / (len(req.out_tokens) - 1))

    def step(self):
        """One serving iteration: admit, batched decode, retire."""
        t0 = time.time() if self.metrics is not None else 0.0
        self._admit()
        live = [i for i in range(self.slots) if self.active[i] is not None]
        if not live:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            toks[i, 0] = self.active[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks),
            jnp.asarray(self.pos), self.caches)
        if self.pim_offload is not None:
            self.pim_offload.step(len(live))
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            hit_eos = self.eos_id is not None and int(nxt[i]) == self.eos_id
            if (len(req.out_tokens) >= req.max_new or hit_eos
                    or int(self.pos[i]) >= self.cache_len - 1):
                self._retire(i)
        if self.metrics is not None:
            self.metrics.histogram(
                "serve.step_s", unit="s",
                help="serving-iteration wall time").record(
                time.time() - t0)
            self.metrics.gauge(
                "serve.live_slots", unit="slots",
                help="slots decoding in the last iteration").set(len(live))
        return True

    def run_until_drained(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and it < max_iters:
            self.step()
            it += 1
        return self.completed

    def latency_summary(self) -> Dict:
        """TTFT/TPOT percentile summary over completed requests.

        Computed from the request timestamps directly, so it works with
        or without an attached metrics registry.  TTFT is submit ->
        prefill argmax; TPOT divides the decode tail by the tokens after
        the first (requests with a single token report no TPOT sample).
        """
        ttft = Histogram("serve.ttft_s", unit="s")
        tpot = Histogram("serve.tpot_s", unit="s")
        for req in self.completed:
            if req.first_token_at:
                ttft.record(req.first_token_at - req.submitted_at)
                if req.finished_at and len(req.out_tokens) >= 2:
                    tpot.record((req.finished_at - req.first_token_at)
                                / (len(req.out_tokens) - 1))
        return {
            "requests": len(self.completed),
            "tokens": sum(len(r.out_tokens) for r in self.completed),
            "ttft_s": ttft.summary(),
            "tpot_s": tpot.summary(),
        }


def _splice(full, one, slot: int, cfg: ArchConfig):
    """Write the single-sequence prefill cache ``one`` into batch slot
    ``slot`` of the server cache ``full``.  Cache layouts put batch at
    axis 1 (layer-stacked) for every family."""
    # trim/pad the sequence axis if the prefill cache is longer/shorter
    if one.shape != full.shape:
        pads = []
        slc = []
        for a, (fo, oo) in enumerate(zip(full.shape, one.shape)):
            if a == 1:      # batch axis
                pads.append((0, 0))
                slc.append(slice(0, oo))
            else:
                pads.append((0, max(0, fo - oo)))
                slc.append(slice(0, min(fo, oo)))
        one = jnp.pad(one[tuple(slc)], pads)
    return jax.lax.dynamic_update_index_in_dim(full, one[:, :1], slot, 1)
