"""Batched serving loop: slot-based continuous batching.

A fixed decode batch of ``slots``; finished sequences free their slot and
the next queued request is prefilled into it.  Greedy sampling (argmax);
the decode step is a single compiled function over the whole slot batch,
caches donated in place — the production shape of vLLM-style serving,
scaled to run on this host with reduced configs.

Pass ``pim_offload=DecodeOffload(cfg, ...)`` to mirror every decode
step's matmuls onto a resident-weight PIM runtime (balanced placement,
weights uploaded once): the sidecar accumulates a per-step PIM-vs-host
roofline without touching the serving numerics — see
:mod:`repro.serve.offload`.  With ``DecodeOffload(cfg, numeric=True)``
(small configs) the sidecar additionally executes each step's matmul set
on the per-channel engines and cross-checks every output — lm_head
logits included — against an XLA reference within FP16 accumulation
tolerance, while charging the same ledgers as the analytic sidecar.

Graceful degradation (:mod:`repro.faults`): ``Server(faults=...)``
accepts a :class:`~repro.faults.plan.FaultPlan` (or DSL string) and
consumes its :class:`~repro.faults.plan.ServeFault` entries — the
request decoding in the named slot at the named iteration is knocked
out and requeued with per-request exponential backoff
(``retry_backoff_steps`` doubling per retry, capped), failing
permanently after ``max_retries``.  ``step_deadline_s`` counts
over-deadline serving iterations; ``max_queue`` turns :meth:`submit`
into admission control that sheds load (:class:`AdmissionError`) when
the queue exceeds the cap *scaled by surviving PIM capacity* — a
half-dead offload cluster halves what the server accepts.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as lm
from repro.obs.metrics import Histogram
from repro.serve.offload import DecodeOffload


class AdmissionError(RuntimeError):
    """Admission control shed this request (queue over the surviving-
    capacity-scaled cap).  Callers should back off and resubmit."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (Tp,) int32
    max_new: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0     # prefill produced the first token
    finished_at: float = 0.0
    retries: int = 0                # fault knock-outs survived so far
    not_before: int = 0             # earliest serving iteration to re-admit


class Server:
    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 cache_len: int = 128, eos_id: Optional[int] = None,
                 pim_offload: Optional[DecodeOffload] = None,
                 metrics=None, faults=None,
                 step_deadline_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 retry_backoff_steps: int = 2,
                 retry_backoff_cap: int = 16,
                 max_retries: int = 2):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.pim_offload = pim_offload
        # repro.obs registry for serve.* latency metrics (TTFT/TPOT per
        # request, step wall time); pass the same registry to the
        # offload sidecar to merge runtime streams into one snapshot
        self.metrics = metrics
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)
        self.caches = lm.make_caches(cfg, slots, cache_len)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # -- graceful degradation state (all zero / empty without faults)
        self.step_deadline_s = step_deadline_s
        self.max_queue = max_queue
        self.retry_backoff_steps = retry_backoff_steps
        self.retry_backoff_cap = retry_backoff_cap
        self.max_retries = max_retries
        self.failed_requests: List[Request] = []
        self.shed = 0                   # submissions refused at admission
        self.deadline_misses = 0        # serving iterations over deadline
        self.retries_total = 0          # fault knock-outs requeued
        self.undrained = 0              # left pending by run_until_drained
        self._iter = 0                  # serving-iteration counter (1-based)
        self._serve_faults: List = []
        if faults is not None:
            from repro.faults.plan import as_plan
            self._serve_faults = sorted(
                as_plan(faults).serve_faults,
                key=lambda f: (f.at_iter, f.slot))

        self._decode = jax.jit(
            lambda p, t, ps, c: lm.decode_step(p, t, ps, c, cfg),
            donate_argnums=(3,))
        self._prefill_one = jax.jit(
            lambda p, toks: lm.prefill(p, {"tokens": toks}, cfg,
                                       cache_len=cache_len))

    def _check_prompt(self, req: Request) -> None:
        """A prompt must leave at least one cache position for decode —
        longer ones would silently corrupt the slot cache at prefill."""
        if len(req.prompt) >= self.cache_len:
            raise ValueError(
                f"prompt of request uid={req.uid} has {len(req.prompt)} "
                f"tokens but cache_len={self.cache_len} leaves no room "
                f"to decode — truncate the prompt or grow cache_len")

    @property
    def _kv(self):
        """The offload sidecar's KV manager when KV-resident attention
        is on (``DecodeOffload(kv_offload=True)``), else None — every
        hook below is a no-op without it."""
        off = self.pim_offload
        return off.kv if off is not None else None

    @property
    def surviving_fraction(self) -> float:
        """Fraction of PIM decode capacity still alive (1.0 without an
        offload sidecar or without faults) — scales the admission cap."""
        off = self.pim_offload
        return off.surviving_fraction if off is not None else 1.0

    def submit(self, req: Request):
        self._check_prompt(req)
        if self.max_queue is not None:
            cap = max(1, int(self.max_queue * self.surviving_fraction))
            if len(self.queue) >= cap:
                self.shed += 1
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.shed", unit="requests",
                        help="submissions shed by admission control").inc()
                raise AdmissionError(
                    f"queue at {len(self.queue)} >= cap {cap} "
                    f"(max_queue={self.max_queue}, surviving="
                    f"{self.surviving_fraction:.2f}); shedding "
                    f"request uid={req.uid}")
        req.submitted_at = time.time()
        self.queue.append(req)

    def _apply_serve_faults(self):
        """Fire ServeFaults due this iteration: knock out the slot's
        request and requeue it with exponential backoff (or fail it
        permanently past max_retries)."""
        due = [f for f in self._serve_faults if f.at_iter == self._iter]
        if not due:
            return
        self._serve_faults = [f for f in self._serve_faults
                              if f.at_iter != self._iter]
        for f in due:
            if f.slot >= self.slots or self.active[f.slot] is None:
                continue
            req = self.active[f.slot]
            self.active[f.slot] = None
            # the slot's cache is considered poisoned: restart the
            # request from its prompt (prefill re-runs on re-admission);
            # its PIM-resident KV drops with it
            if self._kv is not None:
                self.pim_offload.kv_release(req.uid)
            req.out_tokens = []
            req.first_token_at = 0.0
            req.retries += 1
            if req.retries > self.max_retries:
                req.done = True
                req.finished_at = time.time()
                self.failed_requests.append(req)
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.failed", unit="requests",
                        help="requests failed past max_retries").inc()
                continue
            backoff = min(
                self.retry_backoff_steps * 2 ** (req.retries - 1),
                self.retry_backoff_cap)
            req.not_before = self._iter + backoff
            self.queue.append(req)
            self.retries_total += 1
            if self.metrics is not None:
                self.metrics.counter(
                    "serve.retries", unit="requests",
                    help="fault knock-outs requeued with backoff").inc()

    def _admit(self):
        """Prefill queued requests into free slots (FIFO among requests
        whose retry backoff has elapsed)."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                idx = next((j for j, r in enumerate(self.queue)
                            if r.not_before <= self._iter), None)
                if idx is None:
                    return           # everything queued is backing off
                req = self.queue.pop(idx)
                self._check_prompt(req)
                logits, fresh = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None, :]))
                # splice slot i's cache from the single-seq prefill cache
                self.caches = jax.tree.map(
                    lambda full, one, _i=i: _splice(full, one, _i, self.cfg),
                    self.caches, fresh)
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                # the prefill's argmax IS the request's first token:
                # TTFT closes here, before any decode step runs
                req.first_token_at = time.time()
                if self.metrics is not None:
                    self.metrics.histogram(
                        "serve.ttft_s", unit="s",
                        help="time to first token (submit -> prefill "
                             "argmax)").record(
                        req.first_token_at - req.submitted_at)
                self.active[i] = req
                self.pos[i] = len(req.prompt)
                # host prefill produced the prompt's KV: ship it onto
                # the sidecar's PIM pages once, decode grows it in place
                if self._kv is not None:
                    self.pim_offload.kv_prefill(req.uid, len(req.prompt))

    def _retire(self, i: int):
        req = self.active[i]
        req.done = True
        req.finished_at = time.time()
        self.completed.append(req)
        self.active[i] = None
        if self._kv is not None:
            self.pim_offload.kv_release(req.uid)
        if self.metrics is not None:
            m = self.metrics
            m.counter("serve.requests", unit="requests",
                      help="requests completed").inc()
            m.counter("serve.tokens", unit="tokens",
                      help="tokens generated (first token included)").inc(
                len(req.out_tokens))
            if len(req.out_tokens) >= 2:      # TPOT needs a decode tail
                m.histogram(
                    "serve.tpot_s", unit="s",
                    help="time per output token after the first").record(
                    (req.finished_at - req.first_token_at)
                    / (len(req.out_tokens) - 1))

    def step(self):
        """One serving iteration: fire serve faults, admit, batched
        decode, retire; count the iteration against the step deadline."""
        track_wall = self.metrics is not None \
            or self.step_deadline_s is not None
        t0 = time.time() if track_wall else 0.0
        self._iter += 1
        self._apply_serve_faults()
        self._admit()
        live = [i for i in range(self.slots) if self.active[i] is not None]
        if not live:
            # backing-off requests still count as pending work: report
            # True so run_until_drained keeps iterating toward their
            # re-admission instead of spinning the caller's loop exit
            return bool(self.queue)
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            toks[i, 0] = self.active[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks),
            jnp.asarray(self.pos), self.caches)
        if self.pim_offload is not None:
            self.pim_offload.step(
                len(live),
                request_ids=[self.active[i].uid for i in live])
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            hit_eos = self.eos_id is not None and int(nxt[i]) == self.eos_id
            if (len(req.out_tokens) >= req.max_new or hit_eos
                    or int(self.pos[i]) >= self.cache_len - 1):
                self._retire(i)
        if track_wall:
            wall = time.time() - t0
            if self.step_deadline_s is not None \
                    and wall > self.step_deadline_s:
                self.deadline_misses += 1
                if self.metrics is not None:
                    self.metrics.counter(
                        "serve.deadline_misses", unit="steps",
                        help="serving iterations over step_deadline_s"
                    ).inc()
            if self.metrics is not None:
                self.metrics.histogram(
                    "serve.step_s", unit="s",
                    help="serving-iteration wall time").record(wall)
                self.metrics.gauge(
                    "serve.live_slots", unit="slots",
                    help="slots decoding in the last iteration").set(
                    len(live))
        return True

    def run_until_drained(self, max_iters: int = 10_000,
                          on_undrained: str = "raise"):
        """Step until every request completes (or fails permanently).

        If ``max_iters`` exhausts with requests still queued or active,
        the default ``on_undrained="raise"`` raises ``RuntimeError`` —
        a hung serving loop must not masquerade as success.
        ``on_undrained="warn"`` downgrades to a ``RuntimeWarning`` and
        returns the partial results; either way the pending count is
        recorded in :attr:`undrained` / :meth:`latency_summary`.
        """
        if on_undrained not in ("raise", "warn"):
            raise ValueError(
                f"on_undrained must be 'raise' or 'warn', "
                f"got {on_undrained!r}")
        it = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and it < max_iters:
            self.step()
            it += 1
        self.undrained = len(self.queue) \
            + sum(a is not None for a in self.active)
        if self.undrained:
            msg = (f"run_until_drained exhausted max_iters={max_iters} "
                   f"with {self.undrained} request(s) still "
                   f"queued/active ({len(self.completed)} completed)")
            if on_undrained == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return self.completed

    def latency_summary(self) -> Dict:
        """TTFT/TPOT percentile summary over completed requests.

        Computed from the request timestamps directly, so it works with
        or without an attached metrics registry.  TTFT is submit ->
        prefill argmax; TPOT divides the decode tail by the tokens after
        the first (requests with a single token report no TPOT sample).
        """
        ttft = Histogram("serve.ttft_s", unit="s")
        tpot = Histogram("serve.tpot_s", unit="s")
        for req in self.completed:
            if req.first_token_at:
                ttft.record(req.first_token_at - req.submitted_at)
                if req.finished_at and len(req.out_tokens) >= 2:
                    tpot.record((req.finished_at - req.first_token_at)
                                / (len(req.out_tokens) - 1))
        return {
            "requests": len(self.completed),
            "tokens": sum(len(r.out_tokens) for r in self.completed),
            "ttft_s": ttft.summary(),
            "tpot_s": tpot.summary(),
            # degradation accounting (all zero on a fault-free run)
            "undrained": self.undrained,
            "failed": len(self.failed_requests),
            "shed": self.shed,
            "deadline_misses": self.deadline_misses,
            "retries": self.retries_total,
        }


def _splice(full, one, slot: int, cfg: ArchConfig):
    """Write the single-sequence prefill cache ``one`` into batch slot
    ``slot`` of the server cache ``full``.  Cache layouts put batch at
    axis 1 (layer-stacked) for every family."""
    # trim/pad the sequence axis if the prefill cache is longer/shorter
    if one.shape != full.shape:
        pads = []
        slc = []
        for a, (fo, oo) in enumerate(zip(full.shape, one.shape)):
            if a == 1:      # batch axis
                pads.append((0, 0))
                slc.append(slice(0, oo))
            else:
                pads.append((0, max(0, fo - oo)))
                slc.append(slice(0, min(fo, oo)))
        one = jnp.pad(one[tuple(slc)], pads)
    return jax.lax.dynamic_update_index_in_dim(full, one[:, :1], slot, 1)
