"""Decode-path PIM offload: resident-weight GEMV accounting for serving.

The serve loop's decode step is GEMV-heavy (batch is small, weights are
huge) — exactly the regime AMD's balanced-placement work targets and the
regime where PrIM says host<->PIM transfer decides everything.  This
module is the offload sidecar: it mirrors each decode step's matmuls onto
a :class:`~repro.runtime.scheduler.PIMRuntime` whose weights were placed
**once** as resident :class:`~repro.runtime.residency.DeviceTensor`
handles (balanced placement), so the steady-state per-step h2d traffic is
the activation vectors alone — weight re-transfer amortizes to zero after
step 1.

The default sidecar is *accounting-only*: the numeric decode keeps
running through XLA (weights are shape-only analytic handles, never
materialized — full-scale configs stay placeable), while every step
yields a :class:`StepRecord` combining the accumulated
:class:`RuntimeReport`s into a PIM-vs-host roofline:

    pim_s  = sum of per-op makespans / PIM_FREQ_HZ      (ops serialize)
    host_s = max(flops / PEAK_FLOPS, bytes / HBM_BW)    (TPU v5e roofline)

``numeric=True`` (small configs only) additionally *runs* every decode
matmul on the per-channel engines: weights are materialized (seeded
FP16) and placed resident, each step's activations flow through the
batched engines, and every output — the lm_head logits included — is
cross-checked against an XLA reference of the same matmul set within
FP16 accumulation tolerance.  The ledgers are identical to the analytic
sidecar's (execute/analytic parity is property-tested), so the roofline
trajectory is unchanged; the numerics close the ROADMAP
"numeric decode-on-PIM" item.

``dump`` writes the trajectory as ``results/dryrun/*.pim_offload.json``
so future changes to the cost model have a BENCH baseline to diff.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.isa import PIM_FREQ_HZ
from repro.launch import hw
from repro.runtime import BYTES_PER_ELEM, DeviceTensor, PIMRuntime
from repro.sharding.rules import ame_pim_stack_map

F16 = np.float16

#: numeric mode materializes every decode weight on the host — refuse
#: configs past this, the regime stays "small config, cross-check"
NUMERIC_MAX_WEIGHT_BYTES = 64 << 20

#: |y_pim - y_xla| ceiling for the numeric cross-check.  The PIM engines
#: round the accumulator to FP16 per ascending-k step while XLA
#: accumulates in FP32, so the gap is genuine FP16 accumulation error —
#: O(sqrt(k) * 2^-11 * |y|) for the decode shapes, far below this bound.
NUMERIC_ATOL = 0.05


# ---------------------------------------------------------------------------
# The decode step's matmul set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeMatmul:
    """One weight matmul of the decode step: y(out) = W(out, in) @ h(in).

    ``count`` is the per-step multiplicity (layers; active experts)."""

    name: str
    out_dim: int
    in_dim: int
    count: int = 1

    @property
    def weight_bytes(self) -> int:
        return self.out_dim * self.in_dim * BYTES_PER_ELEM * self.count


def decode_matmuls(cfg: ArchConfig) -> List[DecodeMatmul]:
    """The per-step weight matmuls of one decode token for ``cfg``.

    Covers the decoder families the serve loop decodes (dense / vlm text
    stack / moe); SSM and hybrid stacks mix scans with matmuls and are not
    modeled yet.
    """
    if cfg.family not in ("dense", "vlm", "moe") or cfg.encoder_only:
        raise ValueError(
            f"decode offload models dense/moe decoder stacks, not "
            f"{cfg.family!r}")
    d, hd = cfg.d_model, cfg.head_dim_
    L = cfg.n_layers
    mm = [
        DecodeMatmul("attn.wq", cfg.n_heads * hd, d, L),
        DecodeMatmul("attn.wk", cfg.n_kv_heads * hd, d, L),
        DecodeMatmul("attn.wv", cfg.n_kv_heads * hd, d, L),
        DecodeMatmul("attn.wo", d, cfg.n_heads * hd, L),
    ]
    gated = cfg.act in ("swiglu", "geglu")
    if cfg.moe is None:
        mm += [DecodeMatmul("mlp.wi", cfg.d_ff, d, L)]
        if gated:
            mm += [DecodeMatmul("mlp.wg", cfg.d_ff, d, L)]
        mm += [DecodeMatmul("mlp.wo", d, cfg.d_ff, L)]
    else:
        moe = cfg.moe
        n_moe = L - moe.first_dense_layers
        if moe.first_dense_layers:
            mm += [DecodeMatmul("mlp.wi", cfg.d_ff, d,
                                moe.first_dense_layers)]
            if gated:
                mm += [DecodeMatmul("mlp.wg", cfg.d_ff, d,
                                    moe.first_dense_layers)]
            mm += [DecodeMatmul("mlp.wo", d, cfg.d_ff,
                                moe.first_dense_layers)]
        # per token: router + top_k routed experts + shared experts
        active = moe.top_k + moe.n_shared
        mm += [DecodeMatmul("moe.router", moe.num_experts, d, n_moe)]
        mm += [DecodeMatmul("moe.expert.wi", moe.d_ff_expert, d,
                            n_moe * active)]
        if gated:
            mm += [DecodeMatmul("moe.expert.wg", moe.d_ff_expert, d,
                                n_moe * active)]
        mm += [DecodeMatmul("moe.expert.wo", d, moe.d_ff_expert,
                            n_moe * active)]
    mm += [DecodeMatmul("lm_head", cfg.vocab_padded, d, 1)]
    return mm


# ---------------------------------------------------------------------------
# Per-step records and the offload sidecar
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """PIM-vs-host roofline of one decode step."""

    step: int
    batch: int
    pim_cycles: float
    pim_s: float
    h2d_bytes: int              # host->PIM this step (activations at steady)
    d2h_bytes: int
    reuse_bytes: int            # weight traffic avoided by residency
    flops: int
    host_s: float               # TPU v5e roofline time for the same math
    host_bound: str             # 'memory' | 'compute'
    numeric: bool = False       # matmuls executed on the engines this step
    numeric_max_err: float = 0.0    # max |y_pim - y_xla| over the step
    logits_max_err: float = 0.0     # same, lm_head output only

    @property
    def pim_vs_host(self) -> float:
        """host_s / pim_s — >1 means PIM wins the roofline."""
        return self.host_s / self.pim_s if self.pim_s else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["pim_vs_host"] = self.pim_vs_host
        return d


class DecodeOffload:
    """Sidecar: one serve loop's decode path on resident PIM.

    Weights are placed once at construction with the given placement;
    :meth:`step` replays one decode step's matmuls through the runtime
    and records the roofline.  Attach to a :class:`repro.serve.loop.Server`
    via its ``pim_offload`` argument, or drive it directly (the residency
    benchmark sweep does).

    Default mode is accounting-only (analytic, shape-only handles).  With
    ``numeric=True`` the weights are materialized (seeded FP16) and every
    step's matmuls — activations included — execute on the per-channel
    engines, cross-checked element-wise against an XLA reference
    (:meth:`_xla_reference`); the lm_head output is the step's logits and
    its deviation is tracked separately (``logits_max_err``).  Small
    configs only (:data:`NUMERIC_MAX_WEIGHT_BYTES`).

    ``stacks > 1`` runs the sidecar on a multi-stack
    :class:`~repro.runtime.cluster.PIMCluster`: every weight instance is
    homed on its *layer's* stack per the ``ame_pim`` layers map
    (:func:`~repro.sharding.rules.ame_pim_stack_map` — contiguous layer
    blocks, one layer's attention/MLP/experts/router together, lm_head
    with the last layer), weights are placed on their home stack only, and
    every step's matmuls run stack-restricted — so per-stack capacity,
    upload distribution, and the host-link ledger all scale past one
    stack while numerics and per-op ledgers stay those of a
    ``channels``-wide decomposition.
    """

    def __init__(self, cfg: ArchConfig, *, channels: int = 16,
                 stacks: int = 1,
                 placement: str = "balanced", numeric: bool = False,
                 seed: int = 0, atol: float = NUMERIC_ATOL,
                 engine: str = "batched"):
        self.cfg = cfg
        self.placement = placement
        self.numeric = numeric
        self.atol = atol
        self.stacks = stacks
        self.rt = PIMRuntime(channels=channels, stacks=stacks,
                             engine=engine)
        self.matmuls = decode_matmuls(cfg)
        if numeric and self.weight_bytes > NUMERIC_MAX_WEIGHT_BYTES:
            raise ValueError(
                f"numeric decode offload materializes every weight; "
                f"{self.weight_bytes} bytes exceeds the small-config cap "
                f"{NUMERIC_MAX_WEIGHT_BYTES} — use a cfg.reduced()")
        rng = np.random.default_rng(seed)
        # (matmul, [(home stack or None, handle), ...]) — every instance
        # homed on its *layer's* stack (ame_pim layers map), so one
        # layer's attention, MLP/expert, and router weights share a stack
        # and the hidden-state hand-off between them never crosses it
        layer_stacks = ame_pim_stack_map(cfg, stacks)["layers"] \
            if stacks > 1 else None
        self.weights: List[Tuple[DecodeMatmul,
                                 List[Tuple[Optional[int],
                                            DeviceTensor]]]] = []
        for m in self.matmuls:
            homes = [layer_stacks[ell] for ell in self._family_layers(m)] \
                if stacks > 1 else [None] * m.count
            handles = []
            for home in homes:
                if numeric:
                    w = (rng.standard_normal((m.out_dim, m.in_dim))
                         * 0.05).astype(F16)
                    handles.append((home, self.rt.place(
                        w, placement=placement, stack=home)))
                else:
                    handles.append((home, self.rt.place(
                        (m.out_dim, m.in_dim), placement=placement,
                        stack=home)))
            self.weights.append((m, handles))
        self.upload_bytes = sum(d.xfer.h2d_bytes for d in self.rt.stack)
        self.upload_bytes_per_stack: Optional[List[int]] = None
        if stacks > 1:
            self.upload_bytes_per_stack = [
                sum(d.xfer.h2d_bytes for d in stk)
                for stk in self.rt.stack.stacks]
        self.steps: List[StepRecord] = []
        self.last_logits: Optional[np.ndarray] = None     # numeric mode
        self._rng = rng
        self._act_cache: Dict[Tuple[int, int], np.ndarray] = {}

    def _family_layers(self, m: DecodeMatmul) -> List[int]:
        """Decoder-layer index of each instance of one matmul family —
        the key the ame_pim layers map is consulted with, so instance
        counts that collapse layer x expert still land each weight on
        its layer's home stack.  lm_head follows the last layer (that is
        where its input activation lives)."""
        cfg = self.cfg
        if m.name == "lm_head":
            return [cfg.n_layers - 1]
        if m.name.startswith("moe."):
            fd = cfg.moe.first_dense_layers
            if m.name == "moe.router":
                return [fd + i for i in range(m.count)]
            active = cfg.moe.top_k + cfg.moe.n_shared
            return [fd + i // active for i in range(m.count)]
        # attn.* spans all layers; mlp.* spans all dense layers (= the
        # leading first_dense_layers block under MoE) — both from 0
        return list(range(m.count))

    @property
    def weight_bytes(self) -> int:
        """FP16 bytes of all decode weights (the host-side HBM read/step)."""
        return sum(m.weight_bytes for m in self.matmuls)

    def _activation(self, in_dim: int, batch: int) -> np.ndarray:
        """The step's (in_dim, batch) activation block.

        Analytic mode re-uses one zeros buffer per shape (shapes are all
        the gemm reads); numeric mode draws fresh seeded values so every
        step exercises a different accumulation pattern — matmuls sharing
        ``in_dim`` within a step share the block, like the decode hidden
        state feeding every projection.
        """
        key = (in_dim, batch)
        if not self.numeric:
            x = self._act_cache.get(key)
            if x is None:
                x = self._act_cache[key] = np.zeros(key, F16)
            return x
        x = self._act_cache.get(key)
        if x is None:
            x = self._act_cache[key] = \
                (self._rng.standard_normal(key) * 0.05).astype(F16)
        return x

    @staticmethod
    def _xla_reference(w: np.ndarray, x: np.ndarray) -> np.ndarray:
        """The decode step's XLA math for one matmul: FP32 matmul of the
        FP16 operands, like ``decode_step``'s compute-dtype path."""
        return np.asarray(jnp.matmul(jnp.asarray(w, jnp.float32),
                                     jnp.asarray(x, jnp.float32)))

    def step(self, batch: int) -> StepRecord:
        """Account (and in numeric mode, execute) one decode step over
        ``batch`` live slots."""
        before = {d.channel_id: d.snapshot() for d in self.rt.stack}
        pim_cycles = 0.0
        flops = 0
        act_bytes = 0
        max_err = logits_err = 0.0
        if self.numeric:
            self._act_cache.clear()     # fresh activations each step
        for m, handles in self.weights:
            x = self._activation(m.in_dim, batch)
            for home, h in handles:
                y, rep = self.rt.gemm(h, x, placement=self.placement,
                                      execute=self.numeric, stack=home)
                pim_cycles += rep.makespan_cycles    # ops serialize per step
                flops += rep.total_flops
                if self.numeric:
                    ref = self._xla_reference(h.values, x)
                    err = float(np.max(np.abs(
                        np.asarray(y, np.float32) - ref)))
                    assert err < self.atol, \
                        (m.name, err, "PIM numeric decode diverged from "
                         "the XLA path beyond FP16 accumulation tolerance")
                    max_err = max(max_err, err)
                    if m.name == "lm_head":
                        logits_err = max(logits_err, err)
                        self.last_logits = np.asarray(y)
            act_bytes += m.in_dim * batch * BYTES_PER_ELEM * m.count
        h2d = sum(d.xfer.h2d_bytes - before[d.channel_id].h2d_bytes
                  for d in self.rt.stack)
        d2h = sum(d.xfer.d2h_bytes - before[d.channel_id].d2h_bytes
                  for d in self.rt.stack)
        reuse = sum(d.reuse_bytes - before[d.channel_id].reuse_bytes
                    for d in self.rt.stack)
        host_bytes = self.weight_bytes + act_bytes
        host_compute_s = flops / hw.PEAK_FLOPS
        host_memory_s = host_bytes / hw.HBM_BW
        rec = StepRecord(
            step=len(self.steps) + 1, batch=batch,
            pim_cycles=pim_cycles, pim_s=pim_cycles / PIM_FREQ_HZ,
            h2d_bytes=h2d, d2h_bytes=d2h, reuse_bytes=reuse, flops=flops,
            host_s=max(host_compute_s, host_memory_s),
            host_bound=("compute" if host_compute_s > host_memory_s
                        else "memory"),
            numeric=self.numeric, numeric_max_err=max_err,
            logits_max_err=logits_err)
        self.steps.append(rec)
        return rec

    # -- reporting -----------------------------------------------------------

    def roofline(self) -> Dict:
        """Summary over accumulated steps: steady-state transfer breakdown
        and the PIM-vs-host comparison.

        "Steady state" is the latest *full-batch* step — the serve loop's
        drain tail decodes with shrinking live batches, which would
        under-report the steady activation traffic.
        """
        assert self.steps, "run at least one step first"
        peak = max(s.batch for s in self.steps)
        steady = [s for s in self.steps if s.batch == peak][-1]
        return {
            "arch": self.cfg.name,
            # the per-op decomposition width (channels per stack) — every
            # op is stack-restricted, so this, not stacks*channels, is
            # the width the per-channel ledgers reflect
            "channels": (len(self.rt.stack) if self.stacks == 1
                         else self.rt.stack.channels_per_stack),
            "stacks": self.stacks,
            "upload_bytes_per_stack": self.upload_bytes_per_stack,
            "host_link_bytes": (self.rt.stack.link.bytes
                                if self.stacks > 1 else 0),
            "placement": self.placement,
            "matmuls_per_step": sum(m.count for m in self.matmuls),
            "weight_bytes": self.weight_bytes,
            "upload_bytes": self.upload_bytes,
            "steady_h2d_bytes": steady.h2d_bytes,
            "steady_d2h_bytes": steady.d2h_bytes,
            "steady_reuse_bytes": steady.reuse_bytes,
            "steady_pim_s": steady.pim_s,
            "steady_host_s": steady.host_s,
            "steady_host_bound": steady.host_bound,
            "steady_pim_vs_host": steady.pim_vs_host,
            "steps": [s.to_json() for s in self.steps],
        }

    def dump(self, path: str) -> Dict:
        """Write the roofline trajectory as JSON (the BENCH artifact)."""
        rec = self.roofline()
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")
        return rec
