"""Decode-path PIM offload: resident-weight GEMV accounting for serving.

The serve loop's decode step is GEMV-heavy (batch is small, weights are
huge) — exactly the regime AMD's balanced-placement work targets and the
regime where PrIM says host<->PIM transfer decides everything.  This
module is the offload sidecar: it mirrors each decode step's matmuls onto
a :class:`~repro.runtime.scheduler.PIMRuntime` whose weights were placed
**once** as resident :class:`~repro.runtime.residency.DeviceTensor`
handles (balanced placement), so the steady-state per-step h2d traffic is
the activation vectors alone — weight re-transfer amortizes to zero after
step 1.

The default sidecar is *accounting-only*: the numeric decode keeps
running through XLA (weights are shape-only analytic handles, never
materialized — full-scale configs stay placeable), while every step
yields a :class:`StepRecord` combining the accumulated
:class:`RuntimeReport`s into a PIM-vs-host roofline:

    pim_s  = sum of per-op makespans / PIM_FREQ_HZ      (ops serialize)
    host_s = max(flops / PEAK_FLOPS, bytes / HBM_BW)    (TPU v5e roofline)

``numeric=True`` (small configs only) additionally *runs* every decode
matmul on the per-channel engines: weights are materialized (seeded
FP16) and placed resident, each step's activations flow through the
batched engines, and every output — the lm_head logits included — is
cross-checked against an XLA reference of the same matmul set within
FP16 accumulation tolerance.  The ledgers are identical to the analytic
sidecar's (execute/analytic parity is property-tested), so the roofline
trajectory is unchanged; the numerics close the ROADMAP
"numeric decode-on-PIM" item.

``async_mode=True`` replaces the barrier-per-op accounting with the
runtime's dependency-aware timeline (:mod:`repro.runtime.timeline`):
each decode step is submitted as an op DAG — q/k/v concurrent, attention
output as the join, gate/up concurrent, router before its experts — with
every concurrency group placed on *disjoint channel groups* of the home
stack (per-op launch floors dominate decode-shaped matmuls, so giving
independent ops their own channels beats re-serializing them over the
full width), and :meth:`DecodeOffload.pipeline` wave-pipelines a batch
of independent decode requests: layer blocks on different home stacks
process different requests concurrently.  Serialized mode is the
default and is byte-identical in ledgers and traces to the previous
behavior.

``kv_offload=True`` extends the sidecar past the weight matmuls to the
*whole* attention step: each request's KV cache lives resident in
:data:`~repro.runtime.residency.KV_BLOCK_TOKENS`-token pages
(:class:`~repro.runtime.kvcache.KVCacheManager`), the per-step K/V
append is an in-place resident write (new-token bytes only), and the
score GEMV (``K @ q``), in-place softmax epilogue, and context GEMV
(``V^T @ probs``) run on the layer's home-stack channels under the
``paged`` placement — so steady-state per-step h2d stays independent of
context length.  ``kv_capacity_bytes`` bounds resident KV with paged
LRU eviction (oldest pages of the coldest request; re-ship charged as
``reupload`` link traffic).  Numeric mode cross-checks every head's
attention output against the XLA FP32 reference, evictions and
injected faults included.

``dump`` writes the trajectory as ``results/dryrun/*.pim_offload.json``
so future changes to the cost model have a BENCH baseline to diff.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import json
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.isa import PIM_FREQ_HZ
from repro.launch import hw
from repro.runtime import (
    BYTES_PER_ELEM,
    DeviceTensor,
    KVCacheManager,
    OpHandle,
    PIMRuntime,
)
from repro.serve.traffic import RoutingProfile
from repro.sharding.rules import (
    ExpertPlacement,
    ame_pim_expert_placement,
    ame_pim_stack_map,
)

F16 = np.float16

#: numeric mode materializes every decode weight on the host — refuse
#: configs past this, the regime stays "small config, cross-check"
NUMERIC_MAX_WEIGHT_BYTES = 64 << 20

#: XLA FP32 references, content-addressed: (sha1(weight bytes), batch)
#: -> reference output.  Module-level so offload instances over the
#: same seeded weights (the engine bench's tiled/batched pair) share
#: entries; weights are immutable after placement and activations are
#: deterministic per (in_dim, batch), so entries never go stale.
_REF_CACHE: Dict[Tuple[bytes, int], np.ndarray] = {}

#: |y_pim - y_xla| ceiling for the numeric cross-check.  The PIM engines
#: round the accumulator to FP16 per ascending-k step while XLA
#: accumulates in FP32, so the gap is genuine FP16 accumulation error —
#: O(sqrt(k) * 2^-11 * |y|) for the decode shapes, far below this bound.
NUMERIC_ATOL = 0.05


# ---------------------------------------------------------------------------
# The decode step's matmul set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeMatmul:
    """One weight matmul of the decode step: y(out) = W(out, in) @ h(in).

    ``count`` is the per-step multiplicity (layers; active experts)."""

    name: str
    out_dim: int
    in_dim: int
    count: int = 1

    @property
    def weight_bytes(self) -> int:
        return self.out_dim * self.in_dim * BYTES_PER_ELEM * self.count


def decode_matmuls(cfg: ArchConfig) -> List[DecodeMatmul]:
    """The per-step weight matmuls of one decode token for ``cfg``.

    Covers the decoder families the serve loop decodes (dense / vlm text
    stack / moe); SSM and hybrid stacks mix scans with matmuls and are not
    modeled yet.
    """
    if cfg.family not in ("dense", "vlm", "moe") or cfg.encoder_only:
        raise ValueError(
            f"decode offload models dense/moe decoder stacks, not "
            f"{cfg.family!r}")
    d, hd = cfg.d_model, cfg.head_dim_
    L = cfg.n_layers
    mm = [
        DecodeMatmul("attn.wq", cfg.n_heads * hd, d, L),
        DecodeMatmul("attn.wk", cfg.n_kv_heads * hd, d, L),
        DecodeMatmul("attn.wv", cfg.n_kv_heads * hd, d, L),
        DecodeMatmul("attn.wo", d, cfg.n_heads * hd, L),
    ]
    gated = cfg.act in ("swiglu", "geglu")
    if cfg.moe is None:
        mm += [DecodeMatmul("mlp.wi", cfg.d_ff, d, L)]
        if gated:
            mm += [DecodeMatmul("mlp.wg", cfg.d_ff, d, L)]
        mm += [DecodeMatmul("mlp.wo", d, cfg.d_ff, L)]
    else:
        moe = cfg.moe
        n_moe = L - moe.first_dense_layers
        if moe.first_dense_layers:
            mm += [DecodeMatmul("mlp.wi", cfg.d_ff, d,
                                moe.first_dense_layers)]
            if gated:
                mm += [DecodeMatmul("mlp.wg", cfg.d_ff, d,
                                    moe.first_dense_layers)]
            mm += [DecodeMatmul("mlp.wo", d, cfg.d_ff,
                                moe.first_dense_layers)]
        # per token: router + top_k routed experts + shared experts
        active = moe.top_k + moe.n_shared
        mm += [DecodeMatmul("moe.router", moe.num_experts, d, n_moe)]
        mm += [DecodeMatmul("moe.expert.wi", moe.d_ff_expert, d,
                            n_moe * active)]
        if gated:
            mm += [DecodeMatmul("moe.expert.wg", moe.d_ff_expert, d,
                                n_moe * active)]
        mm += [DecodeMatmul("moe.expert.wo", d, moe.d_ff_expert,
                            n_moe * active)]
    mm += [DecodeMatmul("lm_head", cfg.vocab_padded, d, 1)]
    return mm


# ---------------------------------------------------------------------------
# Async step DAG: stages, channel-group splits
# ---------------------------------------------------------------------------

#: dependency level of each matmul family inside one decoder layer —
#: same level = no data dependency (submitted concurrently on disjoint
#: channel groups), levels serialize.  Dense and MoE layers never mix
#: families within one layer, so the shared level numbers are per-layer
#: stage indices, not a global ordering.
_STAGE_OF = {
    "attn.wq": 0, "attn.wk": 0, "attn.wv": 0,     # independent projections
    "attn.wo": 1,                                 # joins q/k/v (attention)
    "mlp.wi": 2, "mlp.wg": 2,                     # gate/up concurrent
    "moe.router": 2,                              # routing decision first
    "mlp.wo": 3,
    "moe.expert.wi": 3, "moe.expert.wg": 3,       # all active experts
    "moe.expert.wo": 4,
}


@dataclasses.dataclass(frozen=True)
class _AsyncOp:
    """One weight matmul instance inside the async step DAG."""

    name: str
    out_dim: int
    in_dim: int
    handle: DeviceTensor
    channels: Tuple[int, ...]     # flat channel ids the op (and its
    #                               weight placement) is pinned to


@functools.lru_cache(maxsize=None)
def _probe_cycles(m: int, k: int, channels: int, placement: str,
                  batch: int = 1) -> float:
    """Makespan of one resident-weight (m, k) @ (k, batch) decode matmul
    on ``channels`` channels — the split-search cost oracle.  A subset
    op's shard geometry equals a ``len(subset)``-channel stack's, so a
    throwaway analytic runtime measures exactly what the subset costs.
    """
    rt = PIMRuntime(channels=channels)
    h = rt.place((m, k), placement=placement, other_dim=batch)
    _, rep = rt.gemm(h, np.zeros((k, batch), F16), placement=placement,
                     execute=False)
    return rep.makespan_cycles


@functools.lru_cache(maxsize=None)
def _group_split(shapes: Tuple[Tuple[int, int], ...], n_channels: int,
                 placement: str, batch: int = 1) -> Tuple[int, ...]:
    """Channel counts for one concurrency group's ops (sum =
    ``n_channels``, each >= 1).

    Starts proportional to each op's weight volume (largest remainder),
    then greedily moves single channels toward the bottleneck op while
    the group's makespan — max over ops of the probed subset makespan —
    improves.  The probe is exact, so AAM-aligned K-split quantization
    (a 5-channel split may cost the same as 4) is accounted, not
    approximated.  ``batch`` is the decode batch the split is tuned for
    (splits are fixed at weight-placement time; ``DecodeOffload``'s
    ``split_batch=`` chooses the regime, default single-slot decode).
    """
    g = len(shapes)
    assert 1 <= g <= n_channels, (g, n_channels)
    if g == 1:
        return (n_channels,)
    works = [m * k for m, k in shapes]
    tot = sum(works)
    raw = [n_channels * w / tot for w in works]
    alloc = [max(1, int(r)) for r in raw]
    while sum(alloc) > n_channels:      # min-1 clamping may overshoot
        # only donors above the floor: a clamped tiny op (raw < 1) is
        # exactly the entry the overshoot metric favors, and must keep
        # its channel — one exists since sum > n_channels >= g
        i = max((i for i in range(g) if alloc[i] > 1),
                key=lambda i: (alloc[i] - raw[i], alloc[i]))
        alloc[i] -= 1
    order = sorted(range(g), key=lambda i: raw[i] - alloc[i], reverse=True)
    for i in order:                     # largest remainder first
        if sum(alloc) == n_channels:
            break
        alloc[i] += 1
    while sum(alloc) < n_channels:      # g > remainders: round-robin
        alloc[min(range(g), key=lambda i: alloc[i])] += 1

    def times(a):
        return [_probe_cycles(shapes[i][0], shapes[i][1], a[i], placement,
                              batch)
                for i in range(g)]

    cur = times(alloc)
    for _ in range(4 * n_channels):
        best = None
        for i in range(g):              # grow the bottleneck...
            for j in range(g):          # ...at any donor's expense
                if i == j or alloc[j] <= 1:
                    continue
                trial = list(alloc)
                trial[i] += 1
                trial[j] -= 1
                tt = times(trial)
                if max(tt) < max(cur) and \
                        (best is None or max(tt) < max(best[1])):
                    best = (trial, tt)
        if best is None:
            break
        alloc, cur = best[0], best[1]
    return tuple(alloc)


# ---------------------------------------------------------------------------
# Per-step records and the offload sidecar
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """PIM-vs-host roofline of one decode step."""

    step: int
    batch: int
    pim_cycles: float
    pim_s: float
    h2d_bytes: int              # host->PIM this step (activations at steady)
    d2h_bytes: int
    reuse_bytes: int            # weight traffic avoided by residency
    flops: int
    host_s: float               # TPU v5e roofline time for the same math
    host_bound: str             # 'memory' | 'compute'
    numeric: bool = False       # matmuls executed on the engines this step
    numeric_max_err: float = 0.0    # max |y_pim - y_xla| over the step
    logits_max_err: float = 0.0     # same, lm_head output only
    overlapped: bool = False    # async DAG step: pim_cycles is the
    #                             timeline makespan, not a sum of ops
    # -- KV-resident attention (kv_offload=True; all zero otherwise) --
    kv_tokens: int = 0          # total context tokens across requests
    kv_host_bytes: int = 0      # host HBM KV read bytes folded into host_s
    attn_cycles: float = 0.0    # PIM cycles in attention ops (append +
    #                             score + softmax + context; serialized
    #                             sum — async overlaps them in pim_cycles)
    attn_max_err: float = 0.0   # max |attn_pim - attn_xla| this step

    @property
    def pim_vs_host(self) -> float:
        """host_s / pim_s — >1 means PIM wins the roofline."""
        return self.host_s / self.pim_s if self.pim_s else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["pim_vs_host"] = self.pim_vs_host
        return d


def _rid_key(rid: Hashable) -> int:
    """Stable 32-bit key of a request id for seeded KV draws (``hash``
    is process-randomized for strings)."""
    return int.from_bytes(
        hashlib.sha1(str(rid).encode()).digest()[:4], "big")


class DecodeOffload:
    """Sidecar: one serve loop's decode path on resident PIM.

    Weights are placed once at construction with the given placement;
    :meth:`step` replays one decode step's matmuls through the runtime
    and records the roofline.  Attach to a :class:`repro.serve.loop.Server`
    via its ``pim_offload`` argument, or drive it directly (the residency
    benchmark sweep does).

    Default mode is accounting-only (analytic, shape-only handles).  With
    ``numeric=True`` the weights are materialized (seeded FP16) and every
    step's matmuls — activations included — execute on the per-channel
    engines, cross-checked element-wise against an XLA reference
    (:meth:`_xla_reference`); the lm_head output is the step's logits and
    its deviation is tracked separately (``logits_max_err``).  Small
    configs only (:data:`NUMERIC_MAX_WEIGHT_BYTES`).

    ``stacks > 1`` runs the sidecar on a multi-stack
    :class:`~repro.runtime.cluster.PIMCluster`: every weight instance is
    homed on its *layer's* stack per the ``ame_pim`` layers map
    (:func:`~repro.sharding.rules.ame_pim_stack_map` — contiguous layer
    blocks, one layer's attention/MLP/experts/router together, lm_head
    with the last layer), weights are placed on their home stack only, and
    every step's matmuls run stack-restricted — so per-stack capacity,
    upload distribution, and the host-link ledger all scale past one
    stack while numerics and per-op ledgers stay those of a
    ``channels``-wide decomposition.

    ``async_mode=True`` switches the runtime to the dependency-aware
    timeline and each step to an op DAG: independent matmuls of one
    layer (q/k/v; gate/up; a routing level's experts) are placed on
    disjoint channel groups of their home stack (:func:`_group_split`)
    and submitted concurrently; dependent levels chain with ``after=``
    edges.  ``pim_cycles`` then reports the step's *timeline makespan*
    (``StepRecord.overlapped``), and :meth:`pipeline` wave-pipelines a
    batch of independent single-slot decode requests across the layer
    blocks' home stacks.

    ``kv_offload=True`` adds the attention step itself: per request
    (ids via ``step(batch, request_ids=...)``; :meth:`kv_prefill` /
    :meth:`kv_release` bracket the serve-loop lifecycle), each layer's
    K/V append lands as an in-place resident page write and every kv
    head runs score GEMV -> softmax -> context GEMV on the layer's
    home-stack channels under the ``paged`` placement.  Only the new
    token's KV bytes and the q vectors cross the bus per step — the
    resident prefix re-ships **zero** bytes, so per-step h2d is flat in
    context length (the context GEMV's K-split partials still drain
    d2h for the host reduction; that is the one context-proportional
    stream, and it is output-sized, not cache-sized).
    ``kv_capacity_bytes`` bounds resident KV via
    :class:`~repro.runtime.kvcache.KVCacheManager` paged eviction.

    Reproducibility: weights *and* per-step activations derive
    deterministically from the constructor's ``seed=`` (activations from
    per-``(in_dim, batch)`` child generators, so their values do not
    depend on draw order or weight count) — repeated offload runs in one
    process see identical data, and the XLA FP32 reference of each
    numeric matmul is cached per ``(weight, batch)`` key instead of
    recomputed every step.  The deliberate trade: numeric steps of one
    run now repeat the same accumulation pattern per (shape, batch)
    instead of drawing fresh values per step — vary ``seed=`` (or
    ``batch``) across runs to exercise different patterns.
    """

    def __init__(self, cfg: ArchConfig, *, channels: int = 16,
                 stacks: int = 1,
                 placement: str = "balanced", numeric: bool = False,
                 seed: int = 0, atol: float = NUMERIC_ATOL,
                 engine: str = "batched", async_mode: bool = False,
                 split_batch: int = 1, metrics=None, faults=None,
                 kv_offload: bool = False,
                 kv_capacity_bytes: Optional[int] = None,
                 routing: Optional[RoutingProfile] = None,
                 replicate_experts: int = 0,
                 expert_placement: str = "greedy",
                 migrate_threshold: Optional[float] = None,
                 migrate_min_tokens: int = 256,
                 link_topology: str = "shared"):
        self.cfg = cfg
        self.placement = placement
        self.numeric = numeric
        self.atol = atol
        self.stacks = stacks
        self.seed = seed
        self.async_mode = async_mode
        # -- routed-MoE expert parallelism (strictly additive when off:
        # routing=None leaves every code path below byte-identical) --
        self.routing = routing
        self.replicate_experts = replicate_experts
        self.expert_policy = expert_placement
        self.migrate_threshold = migrate_threshold
        self.migrate_min_tokens = migrate_min_tokens
        if routing is not None:
            if cfg.moe is None:
                raise ValueError(
                    "routing= models per-expert dispatch and requires an "
                    f"MoE config, not {cfg.name!r}")
            if async_mode or numeric:
                raise ValueError(
                    "routed-MoE dispatch is serialized accounting-only; "
                    "async_mode=/numeric= are unsupported with routing=")
            n_moe = cfg.n_layers - cfg.moe.first_dense_layers
            if (routing.n_layers, routing.n_experts) != \
                    (n_moe, cfg.moe.num_experts):
                raise ValueError(
                    f"routing profile is {routing.n_layers}x"
                    f"{routing.n_experts}; {cfg.name} has {n_moe} MoE "
                    f"layers x {cfg.moe.num_experts} experts")
        # repro.obs registry shared down into the runtime (per-op and
        # host-link streams land in the same registry as the per-step
        # offload.* metrics below); None = zero observability overhead
        self.metrics = metrics
        # the decode batch the async channel-group splits are tuned for
        # (splits are fixed at weight-placement time — weights live on
        # their groups — so pick the serving regime here, not per step)
        self._split_batch = split_batch
        self.rt = PIMRuntime(channels=channels, stacks=stacks,
                             engine=engine, async_mode=async_mode,
                             link_topology=link_topology,
                             metrics=metrics, faults=faults)
        self.matmuls = decode_matmuls(cfg)
        if numeric and self.weight_bytes > NUMERIC_MAX_WEIGHT_BYTES:
            raise ValueError(
                f"numeric decode offload materializes every weight; "
                f"{self.weight_bytes} bytes exceeds the small-config cap "
                f"{NUMERIC_MAX_WEIGHT_BYTES} — use a cfg.reduced()")
        rng = np.random.default_rng(seed)
        # (matmul, [(home stack or None, handle), ...]) — every instance
        # homed on its *layer's* stack (ame_pim layers map), so one
        # layer's attention, MLP/expert, and router weights share a stack
        # and the hidden-state hand-off between them never crosses it
        layer_stacks = ame_pim_stack_map(cfg, stacks)["layers"] \
            if stacks > 1 else None
        # live per-layer home map (failover remaps dead stacks' entries)
        self.stack_map: Optional[List[int]] = \
            list(layer_stacks) if layer_stacks is not None else None
        self.weights: List[Tuple[DecodeMatmul,
                                 List[Tuple[Optional[int],
                                            DeviceTensor]]]] = []
        #: async step DAG: consecutive stages chain, ops within a stage
        #: run concurrently on their disjoint channel groups
        self._stages: List[List[_AsyncOp]] = []
        self._step_tail: Optional[List[OpHandle]] = None
        if async_mode:
            self._build_async_plan(rng, layer_stacks)
        else:
            for m in self.matmuls:
                if routing is not None and \
                        m.name.startswith("moe.expert."):
                    # routed mode homes expert weights per the skew-
                    # driven placement (the bank below), not per-layer
                    self.weights.append((m, []))
                    continue
                homes = [layer_stacks[ell]
                         for ell in self._family_layers(m)] \
                    if stacks > 1 else [None] * m.count
                handles = []
                for home in homes:
                    handles.append((home, self.rt.place(
                        self._draw_weight(rng, m), placement=placement,
                        stack=home)))
                self.weights.append((m, handles))
        # -- routed-MoE expert bank / dispatch state ----------------------
        #: [moe_layer][expert] -> [(home stack, (wi, wg?, wo) handles)],
        #: primary home first (the ExpertPlacement homes order)
        self.expert_bank: List[List[List[
            Tuple[Optional[int], Tuple[DeviceTensor, ...]]]]] = []
        #: [moe_layer] -> shared-expert handles on the layer's home stack
        self.shared_bank: List[List[
            Tuple[Optional[int], Tuple[DeviceTensor, ...]]]] = []
        self._placement: Optional[ExpertPlacement] = None
        self._placement_profile: Optional[RoutingProfile] = None
        #: per-layer expert-selection histogram observed since the last
        #: placement (what drift-triggered migration compares against)
        self.observed: Optional[RoutingProfile] = None
        self._route_rng = None
        self.tokens_per_stack: List[int] = [0] * stacks
        self.moe_counters: Dict[str, int] = {
            "routed_tokens": 0, "replica_hits": 0, "migrations": 0}
        if routing is not None:
            self._placement = ame_pim_expert_placement(
                routing, stacks, replicate=replicate_experts,
                policy=expert_placement)
            self._placement_profile = routing.copy()
            self.observed = RoutingProfile.empty(
                routing.n_layers, routing.n_experts)
            self._route_rng = np.random.default_rng((seed, 32452867))
            self._build_expert_bank()
        self.upload_bytes = sum(d.xfer.h2d_bytes for d in self.rt.stack)
        self.upload_bytes_per_stack: Optional[List[int]] = None
        if stacks > 1:
            self.upload_bytes_per_stack = [
                sum(d.xfer.h2d_bytes for d in stk)
                for stk in self.rt.stack.stacks]
        self.steps: List[StepRecord] = []
        self.last_logits: Optional[np.ndarray] = None     # numeric mode
        self._act_cache: Dict[Tuple[int, int], np.ndarray] = {}
        self._ref_keys: Dict[int, bytes] = {}    # weight uid -> content key
        # -- KV-resident attention (strictly additive when off) --
        self.kv: Optional[KVCacheManager] = None
        self._kv_group = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
        if kv_offload:
            self.kv = KVCacheManager(
                self.rt, n_layers=cfg.n_layers,
                n_kv_heads=max(1, cfg.n_kv_heads),
                head_dim=cfg.head_dim_,
                channels_for_layer=self._kv_channels,
                capacity_bytes=kv_capacity_bytes,
                numeric=numeric, metrics=metrics)

    def _draw_weight(self, rng, m: DecodeMatmul):
        """Weight payload for one instance of family ``m``: seeded FP16
        values in numeric mode, a shape-only analytic handle spec
        otherwise."""
        if self.numeric:
            return (rng.standard_normal((m.out_dim, m.in_dim))
                    * 0.05).astype(F16)
        return (m.out_dim, m.in_dim)

    def _stack_channels(self, home: Optional[int]) -> Tuple[int, ...]:
        """Flat channel ids of one home stack (all channels on 1 stack)."""
        if home is None:
            return tuple(range(len(self.rt.stack)))
        cps = self.rt.stack.channels_per_stack
        return tuple(range(home * cps, (home + 1) * cps))

    # -- routed-MoE expert parallelism (routing=) ----------------------------

    def _expert_specs(self) -> List[Tuple[str, int, int]]:
        """(name, out_dim, in_dim) of one routed expert's matmuls."""
        moe, d = self.cfg.moe, self.cfg.d_model
        specs = [("moe.expert.wi", moe.d_ff_expert, d)]
        if self.cfg.act in ("swiglu", "geglu"):
            specs.append(("moe.expert.wg", moe.d_ff_expert, d))
        specs.append(("moe.expert.wo", d, moe.d_ff_expert))
        return specs

    @property
    def expert_bytes(self) -> int:
        """FP16 bytes of one expert's weights (a migration's payload)."""
        return sum(o * i for _, o, i in self._expert_specs()) \
            * BYTES_PER_ELEM

    def _home_arg(self, home: Optional[int]) -> Optional[int]:
        """The ``stack=`` argument for a placement home (single-stack
        runtimes take None — there is no stack axis to restrict to)."""
        return home if self.stacks > 1 else None

    def _place_expert(self, home: Optional[int],
                      specs: Sequence[Tuple[str, int, int]]
                      ) -> Tuple[DeviceTensor, ...]:
        """Place one expert's weight set resident on ``home``."""
        return tuple(self.rt.place((o, i), placement=self.placement,
                                   stack=self._home_arg(home))
                     for _, o, i in specs)

    def _build_expert_bank(self) -> None:
        """Place every routed expert (replicas included) on its
        :class:`~repro.sharding.rules.ExpertPlacement` homes, and the
        shared experts on their layer's home stack."""
        moe = self.cfg.moe
        fd = moe.first_dense_layers
        specs = self._expert_specs()
        for li, homes_row in enumerate(self._placement.homes):
            self.expert_bank.append(
                [[(h, self._place_expert(h, specs)) for h in homes]
                 for homes in homes_row])
            layer_home = self.stack_map[fd + li] \
                if self.stack_map is not None else None
            self.shared_bank.append(
                [(layer_home, self._place_expert(layer_home, specs))
                 for _ in range(moe.n_shared)])

    def set_routing(self, profile: RoutingProfile) -> None:
        """Swap the live routing distribution (traffic drift) without
        re-placing: subsequent steps sample from ``profile``, the
        observed histogram drifts away from the placement's, and —
        with ``migrate_threshold=`` set — :meth:`_maybe_migrate`
        eventually re-places from the observed counts."""
        if self.routing is None:
            raise ValueError("set_routing requires a routed offload "
                             "(construct with routing=)")
        if (profile.n_layers, profile.n_experts) != \
                (self.routing.n_layers, self.routing.n_experts):
            raise ValueError(
                f"profile shape {profile.n_layers}x{profile.n_experts} "
                f"!= {self.routing.n_layers}x{self.routing.n_experts}")
        self.routing = profile

    def _sample_routes(self, li: int, batch: int
                       ) -> List[Tuple[int, ...]]:
        """Per-token expert selections for MoE layer ``li``: ``top_k``
        distinct experts drawn from the live routing distribution.
        Seeded at construction, so the route stream is a pure function
        of (seed, step sequence)."""
        probs = np.asarray(self.routing.probs(li), dtype=np.float64)
        k = self.cfg.moe.top_k
        if np.count_nonzero(probs) < k:
            # degenerate histogram (fewer active experts than top_k):
            # Laplace-smooth so replace=False stays drawable
            probs = probs + 1.0 / probs.size
        probs = probs / probs.sum()
        return [tuple(int(e) for e in self._route_rng.choice(
                    probs.size, size=k, replace=False, p=probs))
                for _ in range(batch)]

    def _routed_moe_step(self, batch: int) -> Tuple[float, int, int]:
        """One decode step's routed expert sub-step.

        Per MoE layer: sample each token's ``top_k`` experts, group the
        tokens by expert, send each group to its expert's home stack —
        a replicated expert's tokens split one-by-one to the
        least-loaded home (by tokens assigned this layer) — and run the
        expert GEMVs stack-restricted.  Stacks work *in parallel* within
        a layer (expert parallelism), so the layer's cycle cost is the
        max over stacks of their summed op makespans; layers serialize.
        Cross-stack activation movement (tokens whose expert lives off
        the layer's home stack) is charged on the host link as
        ``xstack`` traffic — under ``link_topology="switched"`` the
        hidden-state block leaves the source stack's link *once* and the
        switch multicasts it, instead of once per destination.

        Returns ``(cycles, flops, act_bytes)`` for the step record.
        """
        cfg, moe = self.cfg, self.cfg.moe
        fd = moe.first_dense_layers
        d_model = cfg.d_model
        specs = self._expert_specs()
        total_cycles = 0.0
        flops = 0
        act_bytes = 0
        routed = hits = 0
        for li in range(self.routing.n_layers):
            layer_home = self.stack_map[fd + li] \
                if self.stack_map is not None else None
            groups: Dict[int, List[int]] = {}
            for t, experts in enumerate(self._sample_routes(li, batch)):
                for e in experts:
                    groups.setdefault(e, []).append(t)
            counts = {e: len(ts) for e, ts in groups.items()}
            # two-pass dispatch: single-home experts are fixed load, so
            # land them first; replicated experts' tokens then valley-
            # fill, one by one, onto the least-loaded replica home
            # (largest group first — the hottest expert has the most
            # freedom to level the stacks)
            load: collections.Counter = collections.Counter()
            assign: Dict[Tuple[int, Optional[int]],
                         Tuple[Tuple[DeviceTensor, ...], List[int]]] = {}

            def _put(e: int, home: Optional[int], t: int) -> None:
                load[home] += 1
                entry = assign.get((e, home))
                if entry is None:
                    entry = assign[(e, home)] = (
                        next(hs for h, hs in self.expert_bank[li][e]
                             if h == home), [])
                entry[1].append(t)

            flex: List[Tuple[int, List[int]]] = []
            for e in sorted(groups):
                bank = self.expert_bank[li][e]
                if len(bank) == 1:
                    for t in groups[e]:
                        _put(e, bank[0][0], t)
                else:
                    flex.append((e, groups[e]))
            # fewest-homes first: the widest-replicated (hottest) group
            # dispatches last, when it has full sight of the valleys
            for e, toks in sorted(
                    flex, key=lambda et: (len(self.expert_bank[li][et[0]]),
                                          -len(et[1]), et[0])):
                bank = self.expert_bank[li][e]
                for t in toks:
                    home = min((h for h, _ in bank),
                               key=lambda h: (load[h], h))
                    if home != bank[0][0]:
                        hits += 1
                    _put(e, home, t)
            self.observed.record_counts(li, counts)
            routed += sum(counts.values())
            stack_cycles: collections.Counter = collections.Counter()
            for (e, home), (handles, toks) in sorted(assign.items()):
                nt = len(toks)
                for (_, _, in_dim), h in zip(specs, handles):
                    x = self._activation(in_dim, nt)
                    _, rep = self.rt.gemm(h, x, placement=self.placement,
                                          execute=False,
                                          stack=self._home_arg(home))
                    stack_cycles[home] += rep.makespan_cycles
                    flops += rep.total_flops
                    act_bytes += in_dim * nt * BYTES_PER_ELEM
                self.tokens_per_stack[home or 0] += nt
            # shared experts run every token on the layer's home stack
            for home, handles in self.shared_bank[li]:
                for (_, _, in_dim), h in zip(specs, handles):
                    x = self._activation(in_dim, batch)
                    _, rep = self.rt.gemm(h, x, placement=self.placement,
                                          execute=False,
                                          stack=self._home_arg(home))
                    stack_cycles[home] += rep.makespan_cycles
                    flops += rep.total_flops
                    act_bytes += in_dim * batch * BYTES_PER_ELEM
            if self.stacks > 1:
                dest_tokens: Dict[int, Set[int]] = {}
                for (e, home), (_, toks) in assign.items():
                    if home != layer_home:
                        dest_tokens.setdefault(home, set()).update(toks)
                if dest_tokens:
                    cluster = self.rt.stack
                    if cluster.links is not None:
                        # multicast: the hidden-state block is read out
                        # of the source stack's link once; the switch
                        # fans it out to every destination
                        union: Set[int] = set()
                        for s in dest_tokens.values():
                            union |= s
                        cluster.link_for(layer_home).charge(
                            "xstack",
                            d_model * len(union) * BYTES_PER_ELEM)
                    else:
                        for dst in sorted(dest_tokens):
                            cluster.link.charge(
                                "xstack", d_model * len(dest_tokens[dst])
                                * BYTES_PER_ELEM)
            total_cycles += max(stack_cycles.values(), default=0.0)
        self.moe_counters["routed_tokens"] += routed
        self.moe_counters["replica_hits"] += hits
        if self.metrics is not None:
            m = self.metrics
            m.counter("moe.routed_tokens", unit="tokens",
                      help="expert-token assignments dispatched by the "
                           "routed-MoE layer").inc(routed)
            m.counter("moe.replica_hits", unit="tokens",
                      help="routed tokens served by a non-primary "
                           "expert replica").inc(hits)
            for s, v in enumerate(self.tokens_per_stack):
                m.gauge(f"moe.tokens_stack{s}", unit="tokens",
                        help="cumulative routed expert-tokens "
                             "dispatched to this stack").set(v)
        return total_cycles, flops, act_bytes

    def _maybe_migrate(self) -> None:
        """Step-boundary expert migration: when the observed routing
        histogram has drifted past ``migrate_threshold`` (total-
        variation distance, max over layers) from the profile the
        current placement was computed from, re-place from the observed
        counts.  Experts whose home set changed get their weights placed
        on the added homes (charged as ``reupload`` on the destination
        stack's link, marked ``# MIGRATE`` in the trace) and evicted
        from the removed ones; unchanged homes keep their resident
        handles — no traffic."""
        if self.routing is None or self.migrate_threshold is None:
            return
        if self.observed.total_tokens < self.migrate_min_tokens:
            return
        if self.observed.drift(self._placement_profile) \
                <= self.migrate_threshold:
            return
        new = ame_pim_expert_placement(
            self.observed, self.stacks, replicate=self.replicate_experts,
            policy=self.expert_policy)
        specs = self._expert_specs()
        ebytes = self.expert_bytes
        fd = self.cfg.moe.first_dense_layers
        cluster = self.rt._cluster
        moved = 0
        for li, row in enumerate(new.homes):
            for e, homes in enumerate(row):
                old = self.expert_bank[li][e]
                if list(homes) == [h for h, _ in old]:
                    continue
                src = old[0][0]
                keep = dict(old)
                bank = []
                for h in homes:
                    if h in keep:
                        bank.append((h, keep.pop(h)))
                        continue
                    bank.append((h, self._place_expert(h, specs)))
                    moved += 1
                    if cluster is not None:
                        cluster.link_for(h).charge("reupload", ebytes)
                        dev = cluster.device(h, 0)
                    else:
                        dev = self.rt.stack.devices[0]
                    dev.events.append(
                        ("migrate", (fd + li, e, src or 0, h or 0,
                                     ebytes)))
                for handles in keep.values():
                    for h2 in handles:
                        h2.evict()
                self.expert_bank[li][e] = bank
        self._placement = new
        self._placement_profile = self.observed.copy()
        self.observed = RoutingProfile.empty(
            self.observed.n_layers, self.observed.n_experts)
        if moved:
            self.moe_counters["migrations"] += moved
            if self.metrics is not None:
                self.metrics.counter(
                    "moe.migrations", unit="experts",
                    help="expert replica homes moved by drift-triggered "
                         "re-placement").inc(moved)

    @property
    def replica_hit_rate(self) -> float:
        """Fraction of routed tokens a non-primary replica absorbed."""
        tot = self.moe_counters["routed_tokens"]
        return self.moe_counters["replica_hits"] / tot if tot else 0.0

    def moe_summary(self) -> Dict:
        """Routed-MoE dispatch summary (the bench-facing view)."""
        toks = self.tokens_per_stack
        mean = sum(toks) / len(toks) if toks else 0.0
        return {
            "policy": self.expert_policy,
            "replicate": self.replicate_experts,
            "stacks": self.stacks,
            "routed_tokens": self.moe_counters["routed_tokens"],
            "replica_hits": self.moe_counters["replica_hits"],
            "replica_hit_rate": self.replica_hit_rate,
            "migrations": self.moe_counters["migrations"],
            "tokens_per_stack": list(toks),
            "observed_max_over_mean":
                (max(toks) / mean) if mean else 1.0,
            "placement_max_over_mean": self._placement.max_over_mean,
            "placement_worst_layer_max_over_mean":
                self._placement.worst_layer_max_over_mean,
        }

    # -- KV-resident attention (kv_offload=True) -----------------------------

    def _kv_channels(self, layer: int) -> Tuple[int, ...]:
        """Channels one layer's KV pages cycle over — its home stack,
        minus fail-stopped channels (so page owners keep coinciding
        with the healthy subset the attention GEMVs decompose on)."""
        home = self.stack_map[layer] if self.stack_map is not None \
            else None
        chans = self._stack_channels(home)
        inj = self.rt.faults
        if inj is not None and inj.failed:
            alive = tuple(c for c in chans if c not in inj.failed)
            if alive:
                return alive
        return chans

    def _kv_draw(self, tag: int, rid: Hashable, layer: int, head: int,
                 t0: int, shape: Tuple[int, int]) -> np.ndarray:
        """Seeded FP16 payload for one request's K/V/q draw, keyed by
        the token offset it lands at — deterministic per request and
        position regardless of admission or step order."""
        rng = np.random.default_rng(
            (self.seed, tag, _rid_key(rid), layer, head, t0))
        return (rng.standard_normal(shape) * 0.05).astype(F16)

    def _check_attention(self, K: DeviceTensor, VT: DeviceTensor,
                         q: np.ndarray, y) -> float:
        """Cross-check one head's attention-on-PIM output against the
        XLA FP32 reference ``V^T @ softmax(K @ q)`` over the request's
        full context (evicted-and-restored pages included — the host
        mirrors are exact)."""
        K32 = jnp.asarray(np.asarray(K.values), jnp.float32)
        V32 = jnp.asarray(np.asarray(VT.values), jnp.float32)
        probs = jax.nn.softmax(
            jnp.matmul(K32, jnp.asarray(q, jnp.float32)), axis=0)
        ref = np.asarray(jnp.matmul(V32, probs))
        err = float(np.max(np.abs(np.asarray(y, np.float32) - ref)))
        assert err < self.atol, \
            ("attention", err, "attention-on-PIM diverged from the XLA "
             "FP32 reference beyond FP16 accumulation tolerance")
        return err

    def kv_prefill(self, rid: Hashable, tokens: int,
                   after: Optional[Sequence[OpHandle]] = None):
        """Admit request ``rid`` with ``tokens`` prompt tokens: the host
        prefill produced their KV, so every layer's pages ship in once
        (h2d, ``# KVAPPEND``-marked) and decode steps grow from there.
        Returns the last append's timeline handle on async runtimes."""
        if self.kv is None:
            raise ValueError("kv_prefill requires kv_offload=True")
        if tokens <= 0:
            raise ValueError(f"prefill needs >= 1 token, got {tokens}")
        hd, heads = self.cfg.head_dim_, self.kv.n_kv_heads
        self.kv.request(rid)
        t0 = self.kv.tokens(rid)
        handle = after
        for ell in range(self.cfg.n_layers):
            k_vals = v_vals = None
            if self.numeric:
                k_vals = [self._kv_draw(11, rid, ell, j, t0, (tokens, hd))
                          for j in range(heads)]
                v_vals = [self._kv_draw(13, rid, ell, j, t0, (hd, tokens))
                          for j in range(heads)]
            handle = self.kv.append_tokens(rid, ell, tokens,
                                           k_vals, v_vals, after=handle)
        return handle

    def kv_release(self, rid: Hashable) -> int:
        """Drop a retired (or knocked-out) request's KV; returns the
        resident bytes freed.  No-op without ``kv_offload``."""
        return self.kv.release(rid) if self.kv is not None else 0

    def _attention_serialized(self, rid: Hashable
                              ) -> Tuple[float, int, float]:
        """One request's full attention step, barrier-per-op: per layer,
        append the new token's K/V in place, then per kv head run the
        score GEMV (kept resident), the in-place softmax epilogue, and
        the context GEMV on the layer's home channels.  Returns
        ``(cycles, flops, max_err)``."""
        cfg, kv = self.cfg, self.kv
        hd, heads, group = cfg.head_dim_, kv.n_kv_heads, self._kv_group
        kv.begin_decode(rid)        # restores evicted pages first
        t0 = kv.tokens(rid)
        cycles, flops, max_err = 0.0, 0, 0.0
        for ell in range(cfg.n_layers):
            chans = self._kv_channels(ell)
            k_vals = v_vals = None
            if self.numeric:
                k_vals = [self._kv_draw(11, rid, ell, j, t0, (1, hd))
                          for j in range(heads)]
                v_vals = [self._kv_draw(13, rid, ell, j, t0, (hd, 1))
                          for j in range(heads)]
            kv.append_tokens(rid, ell, 1, k_vals, v_vals)
            for j in range(heads):
                K, VT = kv.tensors(rid, ell, j)
                q = self._kv_draw(17, rid, ell, j, t0, (hd, group)) \
                    if self.numeric else np.zeros((hd, group), F16)
                scores, rep = self.rt.gemm(
                    K, q, placement="paged", keep_output=True,
                    execute=self.numeric, channels=chans)
                cycles += rep.makespan_cycles
                flops += rep.total_flops
                _, rep = self.rt.softmax(scores, placement="paged",
                                         execute=self.numeric,
                                         channels=chans)
                cycles += rep.makespan_cycles
                flops += rep.total_flops
                y, rep = self.rt.gemm(
                    VT, scores, placement="paged",
                    execute=self.numeric, channels=chans)
                cycles += rep.makespan_cycles
                flops += rep.total_flops
                if self.numeric:
                    max_err = max(max_err,
                                  self._check_attention(K, VT, q, y))
                scores.evict()
        return cycles, flops, max_err

    def _attention_async(self, rid: Hashable, ell: int, t0: int,
                         after: Optional[Sequence[OpHandle]]
                         ) -> Tuple[List[OpHandle], float, int, float]:
        """One request's attention DAG for layer ``ell``: the K/V append
        waits on the layer's q/k/v projections (``after``), each head
        chains score -> softmax -> context through residency deps, and
        the returned context handles gate the layer's ``attn.wo``.
        Returns ``(handles, cycles, flops, max_err)`` (cycles = summed
        op makespans; the timeline overlaps them across heads)."""
        cfg, kv = self.cfg, self.kv
        hd, heads, group = cfg.head_dim_, kv.n_kv_heads, self._kv_group
        chans = self._kv_channels(ell)
        k_vals = v_vals = None
        if self.numeric:
            k_vals = [self._kv_draw(11, rid, ell, j, t0, (1, hd))
                      for j in range(heads)]
            v_vals = [self._kv_draw(13, rid, ell, j, t0, (hd, 1))
                      for j in range(heads)]
        kv.append_tokens(rid, ell, 1, k_vals, v_vals, after=after)
        out: List[OpHandle] = []
        cycles, flops, max_err = 0.0, 0, 0.0
        for j in range(heads):
            K, VT = kv.tensors(rid, ell, j)
            q = self._kv_draw(17, rid, ell, j, t0, (hd, group)) \
                if self.numeric else np.zeros((hd, group), F16)
            f_score = self.rt.gemm(
                K, q, placement="paged", keep_output=True,
                execute=self.numeric, channels=chans, after=after)
            scores = f_score.result
            f_sm = self.rt.softmax(scores, placement="paged",
                                   execute=self.numeric, channels=chans)
            f_ctx = self.rt.gemm(
                VT, scores, placement="paged",
                execute=self.numeric, channels=chans)
            for f in (f_score, f_sm, f_ctx):
                cycles += f.report.makespan_cycles
                flops += f.report.total_flops
            if self.numeric:
                max_err = max(max_err,
                              self._check_attention(K, VT, q,
                                                    f_ctx.result))
            scores.evict()
            f_score.result = f_sm.result = f_ctx.result = None
            out.append(f_ctx)
        return out, cycles, flops, max_err

    def _build_async_plan(self, rng, layer_stacks: Optional[List[int]]
                          ) -> None:
        """Construct the per-layer stage DAG and place every weight on
        its op's channel group.

        Weight draw order is per layer (stage construction order), not
        per family — values still derive only from ``seed``.  Groups
        wider than the home stack's channel count split into serial
        waves so every op keeps >= 1 channel.
        """
        # group each family's instances by decoder layer
        per_layer: List[List[Tuple[int, DecodeMatmul]]] = \
            [[] for _ in range(self.cfg.n_layers)]
        lm_head: Optional[DecodeMatmul] = None
        fam_handles: Dict[str, List[Tuple[Optional[int], DeviceTensor]]] \
            = {m.name: [] for m in self.matmuls}
        for m in self.matmuls:
            if m.name == "lm_head":
                lm_head = m
                continue
            for ell in self._family_layers(m):
                per_layer[ell].append((_STAGE_OF[m.name], m))
        for ell, ops in enumerate(per_layer):
            home = layer_stacks[ell] if layer_stacks is not None else None
            chans = self._stack_channels(home)
            by_stage: Dict[int, List[DecodeMatmul]] = {}
            for lvl, m in ops:
                by_stage.setdefault(lvl, []).append(m)
            for lvl in sorted(by_stage):
                group = by_stage[lvl]
                # serial waves when a level is wider than the stack
                for w0 in range(0, len(group), len(chans)):
                    wave = group[w0:w0 + len(chans)]
                    split = _group_split(
                        tuple((m.out_dim, m.in_dim) for m in wave),
                        len(chans), self.placement, self._split_batch)
                    stage, c0 = [], 0
                    for m, nch in zip(wave, split):
                        sub = chans[c0:c0 + nch]
                        c0 += nch
                        h = self.rt.place(self._draw_weight(rng, m),
                                          placement=self.placement,
                                          channels=sub)
                        fam_handles[m.name].append((home, h))
                        stage.append(_AsyncOp(m.name, m.out_dim, m.in_dim,
                                              h, sub))
                    self._stages.append(stage)
        assert lm_head is not None
        home = layer_stacks[-1] if layer_stacks is not None else None
        chans = self._stack_channels(home)
        h = self.rt.place(self._draw_weight(rng, lm_head),
                          placement=self.placement, channels=chans)
        fam_handles[lm_head.name].append((home, h))
        self._stages.append([_AsyncOp(lm_head.name, lm_head.out_dim,
                                      lm_head.in_dim, h, chans)])
        self.weights = [(m, fam_handles[m.name]) for m in self.matmuls]

    def _family_layers(self, m: DecodeMatmul) -> List[int]:
        """Decoder-layer index of each instance of one matmul family —
        the key the ame_pim layers map is consulted with, so instance
        counts that collapse layer x expert still land each weight on
        its layer's home stack.  lm_head follows the last layer (that is
        where its input activation lives)."""
        cfg = self.cfg
        if m.name == "lm_head":
            return [cfg.n_layers - 1]
        if m.name.startswith("moe."):
            fd = cfg.moe.first_dense_layers
            if m.name == "moe.router":
                return [fd + i for i in range(m.count)]
            active = cfg.moe.top_k + cfg.moe.n_shared
            return [fd + i // active for i in range(m.count)]
        # attn.* spans all layers; mlp.* spans all dense layers (= the
        # leading first_dense_layers block under MoE) — both from 0
        return list(range(m.count))

    @property
    def weight_bytes(self) -> int:
        """FP16 bytes of all decode weights (the host-side HBM read/step)."""
        return sum(m.weight_bytes for m in self.matmuls)

    def _activation(self, in_dim: int, batch: int) -> np.ndarray:
        """The (in_dim, batch) activation block for this shape.

        Analytic mode re-uses one zeros buffer per shape (shapes are all
        the gemm reads); numeric mode draws seeded values from a child
        generator keyed by ``(seed, in_dim, batch)`` — deterministic
        regardless of draw order, weight count, or step index, so
        repeated offload runs in one process are reproducible and the
        XLA reference per ``(weight, batch)`` can be cached.  Matmuls
        sharing ``in_dim`` within a step share the block, like the
        decode hidden state feeding every projection.
        """
        key = (in_dim, batch)
        x = self._act_cache.get(key)
        if x is None:
            if self.numeric:
                rng = np.random.default_rng((self.seed, 7, in_dim, batch))
                x = (rng.standard_normal(key) * 0.05).astype(F16)
            else:
                x = np.zeros(key, F16)
            self._act_cache[key] = x
        return x

    @staticmethod
    def _xla_reference(w: np.ndarray, x: np.ndarray) -> np.ndarray:
        """The decode step's XLA math for one matmul: FP32 matmul of the
        FP16 operands, like ``decode_step``'s compute-dtype path."""
        return np.asarray(jnp.matmul(jnp.asarray(w, jnp.float32),
                                     jnp.asarray(x, jnp.float32)))

    def _reference(self, h: DeviceTensor, x: np.ndarray,
                   batch: int) -> np.ndarray:
        """Cached XLA FP32 reference of ``h.values @ x``.

        Activations are deterministic per ``(in_dim, batch)`` and
        weights never change after placement, so one reference per
        ``(weight, batch)`` key serves every step — the per-step
        recompute used to burn the numeric steps' wall clock for no
        information.  The key is content-addressed (weight bytes), so
        offload instances over the same seeded weights — e.g. the
        engine bench's tiled-vs-batched pair — share references too.
        """
        ck = self._ref_keys.get(h.uid)
        if ck is None:
            # shape is part of the content: offload modes chop the same
            # seeded stream into different shapes, so byte-equal buffers
            # of different geometry must not share references
            ck = self._ref_keys[h.uid] = hashlib.sha1(
                repr(h.shape).encode() + h.values.tobytes()).digest()
        key = (ck, batch)
        ref = _REF_CACHE.get(key)
        if ref is None:
            ref = _REF_CACHE[key] = self._xla_reference(h.values, x)
        return ref

    def _check_numeric(self, name: str, h: DeviceTensor, x: np.ndarray,
                       y, batch: int) -> Tuple[float, float]:
        """Cross-check one executed matmul against the XLA reference;
        returns ``(err, logits_err)`` for the step maxima."""
        ref = self._reference(h, x, batch)
        err = float(np.max(np.abs(np.asarray(y, np.float32) - ref)))
        assert err < self.atol, \
            (name, err, "PIM numeric decode diverged from the XLA path "
             "beyond FP16 accumulation tolerance")
        logits_err = 0.0
        if name == "lm_head":
            logits_err = err
            self.last_logits = np.asarray(y)
        return err, logits_err

    # -- fault failover (repro.faults) ---------------------------------------

    @property
    def surviving_fraction(self) -> float:
        """Fraction of the runtime's channels still healthy (1.0 without
        an attached fault plan) — the server's admission-control input."""
        inj = self.rt.faults
        if inj is None:
            return 1.0
        total = len(self.rt.stack)
        return (total - len(inj.failed)) / total

    def _maybe_failover(self) -> None:
        """Step-boundary failover: if a whole home stack has fail-stopped
        since the last step, migrate its weights to a survivor.

        Failover is step-granular by design — a step already dispatched
        completes on the pre-fault decomposition; the *next* step sees
        the remap (the retry unit real serving systems use).  Partial
        stack failures need no action here: the scheduler's healthy-
        subset remap already decomposes over the surviving channels.
        """
        inj = self.rt.faults
        if inj is None or self.stacks == 1:
            return
        inj.poll()
        if not inj.failed:
            return
        cps = self.rt.stack.channels_per_stack
        dead = {s for s in range(self.stacks)
                if all(s * cps + c in inj.failed for c in range(cps))}
        homes = set(self.stack_map or ())
        for s in sorted(dead & homes):
            self._failover_stack(s, inj)

    def _failover_stack(self, dead: int, inj) -> None:
        """Migrate every weight homed on ``dead`` to the surviving stack
        carrying the least homed weight bytes, charging the migration on
        the host link as ``reupload`` traffic (the host re-carries the
        weights from its mirror — weights are immutable after placement,
        so the host copy is exact)."""
        cps = self.rt.stack.channels_per_stack
        alive = [s for s in range(self.stacks)
                 if any(s * cps + c not in inj.failed for c in range(cps))]
        if not alive:
            from repro.faults.injector import NoHealthyChannelsError
            raise NoHealthyChannelsError(
                "every stack has failed; nowhere to fail weights over to")
        homed = {}
        for m, handles in self.weights:
            for home, _h in handles:
                if home is not None:
                    homed[home] = homed.get(home, 0) \
                        + m.out_dim * m.in_dim * BYTES_PER_ELEM
        survivor = min(alive, key=lambda s: (homed.get(s, 0), s))
        migrated = 0
        replaced: Dict[int, DeviceTensor] = {}
        if self.async_mode:
            healthy = tuple(c for c in self._stack_channels(survivor)
                            if c not in inj.failed)
            new_stages = []
            for stage in self._stages:
                if stage[0].channels[0] // cps != dead:
                    new_stages.append(stage)
                    continue
                if len(stage) <= len(healthy):
                    split = _group_split(
                        tuple((op.out_dim, op.in_dim) for op in stage),
                        len(healthy), self.placement, self._split_batch)
                    subs, c0 = [], 0
                    for nch in split:
                        subs.append(healthy[c0:c0 + nch])
                        c0 += nch
                else:
                    # fewer healthy channels than ops: share the full
                    # subset — the timeline serializes contenders
                    subs = [healthy] * len(stage)
                new_stage = []
                for op, sub in zip(stage, subs):
                    op.handle.evict()
                    payload = op.handle.values if self.numeric \
                        else (op.out_dim, op.in_dim)
                    nh = self.rt.place(payload, placement=self.placement,
                                       channels=sub)
                    replaced[op.handle.uid] = nh
                    migrated += op.out_dim * op.in_dim * BYTES_PER_ELEM
                    new_stage.append(_AsyncOp(op.name, op.out_dim,
                                              op.in_dim, nh, sub))
                new_stages.append(new_stage)
            self._stages = new_stages
        new_weights = []
        for m, handles in self.weights:
            hs = []
            for home, h in handles:
                if home == dead:
                    if h.uid in replaced:
                        h = replaced[h.uid]
                    else:                     # serialized: migrate now
                        h.evict()
                        payload = h.values if self.numeric \
                            else (m.out_dim, m.in_dim)
                        h = self.rt.place(payload,
                                          placement=self.placement,
                                          stack=survivor)
                        migrated += m.out_dim * m.in_dim * BYTES_PER_ELEM
                    home = survivor
                hs.append((home, h))
            new_weights.append((m, hs))
        self.weights = new_weights
        if self.stack_map is not None:
            self.stack_map = [survivor if s == dead else s
                              for s in self.stack_map]
        self.rt.stack.link.charge("reupload", migrated)
        inj.count("stack_failovers", 1)
        inj.count("failover_migrated_bytes", migrated)
        inj.instants.append(
            ("failover", inj.now, -1,
             f"stack {dead} weights -> stack {survivor} "
             f"({migrated} bytes)"))

    def step(self, batch: int,
             request_ids: Optional[Sequence[Hashable]] = None
             ) -> StepRecord:
        """Account (and in numeric mode, execute) one decode step over
        ``batch`` live slots.

        With ``kv_offload=True``, ``request_ids`` names the live
        requests whose KV grows this step (default ``range(batch)`` for
        direct driving) and the step additionally runs each request's
        attention sub-step on PIM (:meth:`_attention_serialized` /
        :meth:`_attention_async`).

        In async mode the step is submitted as the op DAG (stages chain,
        ops within a stage overlap on their channel groups) and
        ``pim_cycles`` is the step's timeline makespan; serialized mode
        sums per-op makespans as before.

        With a fault plan attached, a home stack that fully fail-stopped
        since the last step first fails its weights over to a survivor
        (:meth:`_maybe_failover`); the step then runs on the remapped
        homes.  A stack that dies *mid-step* aborts the attempt with
        :class:`~repro.faults.injector.NoHealthyChannelsError` — the
        step fails over and replays from its start (ops submitted
        before the abort stay on the ledgers as wasted work).
        :meth:`pipeline` does not fail over (accounting-only wave
        studies fix their topology up front).
        """
        from repro.faults.injector import NoHealthyChannelsError
        self._maybe_failover()
        self._maybe_migrate()
        try:
            return self._step_once(batch, request_ids)
        except NoHealthyChannelsError:
            failovers = (self.rt.faults.counters.get("stack_failovers", 0)
                         if self.rt.faults is not None else 0)
            self._maybe_failover()
            now = (self.rt.faults.counters.get("stack_failovers", 0)
                   if self.rt.faults is not None else 0)
            if now == failovers:
                # nothing migrated (partial stack death, or no survivor
                # to migrate to) — the fault is not recoverable here
                raise
            return self._step_once(batch, request_ids)

    def _step_once(self, batch: int,
                   request_ids: Optional[Sequence[Hashable]] = None
                   ) -> StepRecord:
        """One attempt at a decode step (see :meth:`step`)."""
        before = {d.channel_id: d.snapshot() for d in self.rt.stack}
        pim_cycles = 0.0
        flops = 0
        act_bytes = 0
        max_err = logits_err = 0.0
        rids: List[Hashable] = []
        if self.kv is not None:
            rids = list(request_ids) if request_ids is not None \
                else list(range(batch))
        attn_cycles, attn_err = 0.0, 0.0
        if self.async_mode:
            tl = self.rt.timeline
            t0 = tl.now
            kv_t0: Dict[Hashable, int] = {}
            for rid in rids:
                self.kv.begin_decode(rid)   # restore submits on timeline
                kv_t0[rid] = self.kv.tokens(rid)
            layer_idx = 0
            prev = self._step_tail      # chain steps: sampling feeds back
            for stage in self._stages:
                if rids and stage[0].name == "attn.wo":
                    # the layer's attention DAG gates its wo projection
                    ctx: List[OpHandle] = []
                    for rid in rids:
                        hs, cyc, fl, err = self._attention_async(
                            rid, layer_idx, kv_t0[rid], prev)
                        ctx.extend(hs)
                        attn_cycles += cyc
                        flops += fl
                        attn_err = max(attn_err, err)
                    prev = ctx or prev
                    layer_idx += 1
                handles = []
                for op in stage:
                    x = self._activation(op.in_dim, batch)
                    fut = self.rt.gemm(op.handle, x,
                                       placement=self.placement,
                                       execute=self.numeric,
                                       channels=op.channels, after=prev)
                    flops += fut.report.total_flops
                    if self.numeric:
                        err, lerr = self._check_numeric(
                            op.name, op.handle, x, fut.result, batch)
                        max_err = max(max_err, err)
                        logits_err = max(logits_err, lerr)
                    # consumed: only spans/retire matter downstream —
                    # don't let the op log pin every step's outputs
                    # (lm_head logits included) for the loop's lifetime
                    fut.result = None
                    handles.append(fut)
                prev = handles
            self._step_tail = prev
            pim_cycles = tl.now - t0
            act_bytes = sum(m.in_dim * batch * BYTES_PER_ELEM * m.count
                            for m in self.matmuls)
        else:
            for m, handles in self.weights:
                if not handles:
                    # routed mode: expert families dispatch through the
                    # placement bank (_routed_moe_step), not here
                    continue
                x = self._activation(m.in_dim, batch)
                for home, h in handles:
                    y, rep = self.rt.gemm(h, x, placement=self.placement,
                                          execute=self.numeric, stack=home)
                    pim_cycles += rep.makespan_cycles   # ops serialize
                    flops += rep.total_flops
                    if self.numeric:
                        err, lerr = self._check_numeric(
                            m.name, h, x, y, batch)
                        max_err = max(max_err, err)
                        logits_err = max(logits_err, lerr)
                act_bytes += m.in_dim * batch * BYTES_PER_ELEM * m.count
            if self.routing is not None:
                # routed expert sub-step: per layer, stacks run their
                # expert groups in parallel (max over stacks), layers
                # serialize like ops
                cyc, fl, ab = self._routed_moe_step(batch)
                pim_cycles += cyc
                flops += fl
                act_bytes += ab
            for rid in rids:
                cyc, fl, err = self._attention_serialized(rid)
                attn_cycles += cyc
                pim_cycles += cyc       # attention serializes like ops
                flops += fl
                attn_err = max(attn_err, err)
        max_err = max(max_err, attn_err)
        # the host roofline for the same math re-reads every live
        # request's K and V from HBM each step (no residency there)
        kv_tokens = sum(self.kv.tokens(r) for r in rids) \
            if self.kv is not None else 0
        kv_host_bytes = (kv_tokens * self.cfg.head_dim_ * BYTES_PER_ELEM
                         * 2 * self.kv.n_kv_heads * self.cfg.n_layers) \
            if self.kv is not None else 0
        h2d = sum(d.xfer.h2d_bytes - before[d.channel_id].h2d_bytes
                  for d in self.rt.stack)
        d2h = sum(d.xfer.d2h_bytes - before[d.channel_id].d2h_bytes
                  for d in self.rt.stack)
        reuse = sum(d.reuse_bytes - before[d.channel_id].reuse_bytes
                    for d in self.rt.stack)
        host_bytes = self.weight_bytes + act_bytes + kv_host_bytes
        host_compute_s = flops / hw.PEAK_FLOPS
        host_memory_s = host_bytes / hw.HBM_BW
        rec = StepRecord(
            step=len(self.steps) + 1, batch=batch,
            pim_cycles=pim_cycles, pim_s=pim_cycles / PIM_FREQ_HZ,
            h2d_bytes=h2d, d2h_bytes=d2h, reuse_bytes=reuse, flops=flops,
            host_s=max(host_compute_s, host_memory_s),
            host_bound=("compute" if host_compute_s > host_memory_s
                        else "memory"),
            numeric=self.numeric, numeric_max_err=max_err,
            logits_max_err=logits_err, overlapped=self.async_mode,
            kv_tokens=kv_tokens, kv_host_bytes=kv_host_bytes,
            attn_cycles=attn_cycles, attn_max_err=attn_err)
        self.steps.append(rec)
        if self.metrics is not None:
            m = self.metrics
            m.counter("offload.steps", unit="steps",
                      help="decode steps mirrored onto PIM").inc()
            m.counter("offload.flops", unit="flop",
                      help="decode FLOPs offloaded").inc(rec.flops)
            m.counter("offload.act_h2d_bytes", unit="bytes",
                      help="per-step activation h2d traffic").inc(rec.h2d_bytes)
            m.histogram("offload.step_pim_cycles", unit="cycles",
                        help="per-step PIM makespan (async: timeline "
                             "makespan; serialized: sum of ops)").record(
                rec.pim_cycles)
            if self.kv is not None:
                m.histogram("offload.attn_step_cycles", unit="cycles",
                            help="per-step PIM cycles in attention ops "
                                 "(append + score + softmax + context)"
                            ).record(rec.attn_cycles)
        return rec

    def _visit_groups(self) -> List[List[List[_AsyncOp]]]:
        """Group the step's stages into *visits*: maximal runs of
        consecutive stages whose ops live on the same home stack (one
        request's layer block, the pipeline's scheduling quantum)."""
        visits: List[List[List[_AsyncOp]]] = []
        cps = self.rt.stack.channels_per_stack if self.stacks > 1 \
            else len(self.rt.stack)
        last_stack = None
        for stage in self._stages:
            stk = stage[0].channels[0] // cps
            if stk != last_stack:
                visits.append([])
                last_stack = stk
            visits[-1].append(stage)
        return visits

    def pipeline(self, requests: int, steps: int,
                 batch: int = 1) -> Dict:
        """Wave-pipeline ``requests`` independent decode requests for
        ``steps`` decode steps each (async mode, accounting-only).

        Every request is its own dependency chain — its stages chain
        through ``after=`` edges (a step's first projections wait on the
        previous step's lm_head: host-side sampling feeds the next
        token) — while *different* requests share nothing but the
        resident weights, so with layer blocks homed on different stacks
        (``stacks=N``) request r+1's layer-0 block runs while request r
        is in layer 1: the cross-stack layer pipeline.  Submission is
        earliest-ready-first across requests, which lets the monotonic
        channel clocks realize the wave schedule.

        Returns the pipeline report: timeline makespan, per-stack busy
        cycles, and the op count.
        """
        if not self.async_mode:
            raise ValueError("pipeline() requires async_mode=True")
        if self.numeric:
            raise ValueError(
                "pipeline() is accounting-only; numeric mode cross-"
                "checks per-step via step()")
        tl = self.rt.timeline
        t0 = tl.now
        n0 = len(tl.ops)
        # submission is *visit*-atomic: all of a request's consecutive
        # stages on one home stack enter the clocks contiguously, so a
        # stack serves one request's layer block at a time (FIFO by
        # arrival) instead of round-robin-interleaving every queued
        # request's stages — stage-granular submission on monotonic
        # clocks locks the ring into a lockstep convoy that leaves the
        # bottleneck stack idle every period
        visits = self._visit_groups()
        total = len(visits) * steps
        tails: List[Optional[List[OpHandle]]] = [None] * requests
        ready = [0.0] * requests
        done = [0] * requests
        while True:
            live = [r for r in range(requests) if done[r] < total]
            if not live:
                break
            r = min(live, key=lambda r: (ready[r], r))
            for stage in visits[done[r] % len(visits)]:
                handles = []
                for op in stage:
                    x = self._activation(op.in_dim, batch)
                    handles.append(self.rt.gemm(
                        op.handle, x, placement=self.placement,
                        execute=False, channels=op.channels,
                        after=tails[r]))
                tails[r] = handles
            ready[r] = max(h.retire for h in tails[r])
            done[r] += 1
        makespan = tl.now - t0
        per_stack_busy: Dict[int, float] = {}
        cps = self.rt.stack.channels_per_stack if self.stacks > 1 \
            else len(self.rt.stack)
        for h in tl.ops[n0:]:
            for ch, (_, busy) in h.spans.items():
                per_stack_busy[ch // cps] = \
                    per_stack_busy.get(ch // cps, 0.0) + busy
        return {
            "requests": requests,
            "steps": steps,
            "batch": batch,
            "stacks": self.stacks,
            "makespan_cycles": makespan,
            "makespan_s": makespan / PIM_FREQ_HZ,
            "ops": len(tl.ops) - n0,
            "per_stack_busy_cycles": [per_stack_busy.get(s, 0.0)
                                      for s in range(self.stacks)],
        }

    # -- reporting -----------------------------------------------------------

    def roofline(self) -> Dict:
        """Summary over accumulated steps: steady-state transfer breakdown
        and the PIM-vs-host comparison.

        "Steady state" is the latest *full-batch* step — the serve loop's
        drain tail decodes with shrinking live batches, which would
        under-report the steady activation traffic.
        """
        assert self.steps, "run at least one step first"
        peak = max(s.batch for s in self.steps)
        steady = [s for s in self.steps if s.batch == peak][-1]
        out = {
            "arch": self.cfg.name,
            # the per-op decomposition width (channels per stack) — every
            # op is stack-restricted, so this, not stacks*channels, is
            # the width the per-channel ledgers reflect
            "channels": (len(self.rt.stack) if self.stacks == 1
                         else self.rt.stack.channels_per_stack),
            "stacks": self.stacks,
            "upload_bytes_per_stack": self.upload_bytes_per_stack,
            "host_link_bytes": (self.rt.stack.link_totals()[0]
                                if self.stacks > 1 else 0),
            "placement": self.placement,
            "matmuls_per_step": sum(m.count for m in self.matmuls),
            "weight_bytes": self.weight_bytes,
            "upload_bytes": self.upload_bytes,
            "steady_h2d_bytes": steady.h2d_bytes,
            "steady_d2h_bytes": steady.d2h_bytes,
            "steady_reuse_bytes": steady.reuse_bytes,
            "steady_pim_s": steady.pim_s,
            "steady_host_s": steady.host_s,
            "steady_host_bound": steady.host_bound,
            "steady_pim_vs_host": steady.pim_vs_host,
            "steady_kv_tokens": steady.kv_tokens,
            "steady_attn_cycles": steady.attn_cycles,
            "kv": self.kv.summary() if self.kv is not None else None,
            "steps": [s.to_json() for s in self.steps],
        }
        if self.routing is not None:
            out["moe"] = self.moe_summary()
        return out

    def dump(self, path: str) -> Dict:
        """Write the roofline trajectory as JSON (the BENCH artifact)."""
        rec = self.roofline()
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")
        return rec
