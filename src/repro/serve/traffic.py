"""Production-traffic layer: clocks, arrival traces, cost models, SLOs.

The serving loop (:mod:`repro.serve.loop`) batches whatever sits in its
queue; this module supplies everything *around* that loop that a load
study needs, all deterministic and machine-independent:

* **Virtual time** — :class:`SimClock` is a monotonic simulated-seconds
  clock the server stamps ``submitted_at``/``first_token_at``/
  ``finished_at`` from, so TTFT/TPOT/queue-delay percentiles are pure
  functions of the trace and the cost model (two runs of the same seed
  are bit-identical, on any machine).  :class:`WallClock` is the
  ``wall=True`` escape hatch: same interface, real ``time.time()``.

* **Arrival processes** — :func:`poisson_trace` (exponential
  inter-arrivals, the classic open-loop load model) and
  :func:`bursty_trace` (Gamma inter-arrivals with a chosen coefficient
  of variation — cv 3-4 matches measured production LLM traffic far
  better than Poisson's cv 1).  Both are seeded; :class:`Trace` saves /
  loads the replayable JSON format so a sweep can pin its exact
  workload in the repo.

* **MoE routing histograms** — :class:`RoutingProfile` records (or
  synthesizes: :func:`zipf_routing`, :func:`uniform_routing`) per-layer
  expert-selection counts, seeded and replayable; it drives the
  routed-traffic-aware expert placement in :mod:`repro.sharding.rules`
  and the :class:`repro.serve.offload.DecodeOffload` routed decode
  dispatch.

* **Host cost model** — :class:`HostCostModel` prices the two phases a
  disaggregated server schedules: prefill on the host XLA device (a
  roofline over the decode matmul set, same ``hw.PEAK_FLOPS`` /
  ``hw.HBM_BW`` device the offload's per-step ``host_s`` uses) and the
  KV bytes prefill must ship host -> PIM per prompt token.

* **SLOs and autoscaling** — :class:`SLO` (TTFT + TPOT bounds, the
  goodput criterion) and the slot-autoscaling policies
  :class:`StaticSlots`, :class:`QueueProportionalSlots`,
  :class:`SLOFeedbackSlots` consumed by
  :class:`repro.serve.loop.TrafficServer`.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ArchConfig
from repro.launch import hw
from repro.runtime import BYTES_PER_ELEM

# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class SimClock:
    """Virtual simulated-seconds clock; the determinism substrate.

    Only ever moves forward: :meth:`advance` by a non-negative delta,
    :meth:`advance_to` to an absolute time (a no-op if already past).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._now += dt
        return self._now

    def advance_to(self, t: float) -> float:
        self._now = max(self._now, float(t))
        return self._now


class WallClock:
    """``time.time()`` behind the :class:`SimClock` interface — the
    ``Server(wall=True)`` escape hatch.  Advancing is a no-op: wall time
    moves on its own."""

    @property
    def now(self) -> float:
        return time.time()

    def advance(self, dt: float) -> float:
        return time.time()

    def advance_to(self, t: float) -> float:
        return time.time()


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival of the workload: *when*, and how much work."""

    uid: int
    at_s: float                 # arrival time, trace-relative seconds
    prompt_len: int
    max_new: int


@dataclasses.dataclass
class Trace:
    """A replayable arrival trace: sorted requests + generator metadata.

    ``save``/``load`` round-trip through a small JSON format so a sweep
    can commit its exact workload; equality is field equality, so a
    loaded trace ``==`` the generated one.
    """

    requests: List[TraceRequest]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.requests = sorted(self.requests, key=lambda r: (r.at_s, r.uid))

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self) -> Iterator[TraceRequest]:
        return iter(self.requests)

    @property
    def duration_s(self) -> float:
        """Arrival span (first to last request)."""
        if not self.requests:
            return 0.0
        return self.requests[-1].at_s - self.requests[0].at_s

    @property
    def arrival_rate_rps(self) -> float:
        """Empirical mean arrival rate over the trace's span."""
        if len(self.requests) < 2 or self.duration_s <= 0:
            return 0.0
        return (len(self.requests) - 1) / self.duration_s

    def save(self, path: str) -> None:
        rec = {"meta": self.meta,
               "requests": [dataclasses.asdict(r) for r in self.requests]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            rec = json.load(f)
        return cls(requests=[TraceRequest(**r) for r in rec["requests"]],
                   meta=rec.get("meta", {}))


def _lengths(rng, n: int, spec: Union[int, Tuple[int, int]]) -> List[int]:
    """Materialize a per-request length column: a fixed int, or an
    inclusive ``(lo, hi)`` range drawn uniformly."""
    if isinstance(spec, int):
        return [spec] * n
    lo, hi = spec
    return [int(v) for v in rng.integers(lo, hi + 1, size=n)]


def _build(gaps, n: int, seed: int, kind: str, rate_rps: float,
           prompt_len, max_new, rng, extra: Optional[Dict] = None) -> Trace:
    prompts = _lengths(rng, n, prompt_len)
    news = _lengths(rng, n, max_new)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(gaps[i])
        reqs.append(TraceRequest(uid=i, at_s=t, prompt_len=prompts[i],
                                 max_new=news[i]))
    meta = {"kind": kind, "seed": seed, "rate_rps": rate_rps, "n": n,
            "prompt_len": list(prompt_len)
            if not isinstance(prompt_len, int) else prompt_len,
            "max_new": list(max_new)
            if not isinstance(max_new, int) else max_new}
    meta.update(extra or {})
    return Trace(requests=reqs, meta=meta)


def poisson_trace(rate_rps: float, n: int, *, seed: int = 0,
                  prompt_len: Union[int, Tuple[int, int]] = 512,
                  max_new: Union[int, Tuple[int, int]] = 32) -> Trace:
    """``n`` arrivals of a Poisson process at ``rate_rps`` requests/s
    (exponential inter-arrival gaps), seeded and replayable."""
    import numpy as np
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng((7919, seed))      # domain-separated seed
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return _build(gaps, n, seed, "poisson", rate_rps, prompt_len, max_new,
                  rng)


def bursty_trace(rate_rps: float, n: int, *, cv: float = 3.0, seed: int = 0,
                 prompt_len: Union[int, Tuple[int, int]] = 512,
                 max_new: Union[int, Tuple[int, int]] = 32) -> Trace:
    """``n`` arrivals with Gamma inter-arrivals at mean rate ``rate_rps``
    and coefficient of variation ``cv`` (> 1 = burstier than Poisson —
    production LLM traffic measures cv 3-4)."""
    import numpy as np
    if rate_rps <= 0 or cv <= 0:
        raise ValueError(f"rate_rps and cv must be > 0 "
                         f"(got {rate_rps}, {cv})")
    rng = np.random.default_rng((104729, seed))    # domain-separated seed
    shape = 1.0 / (cv * cv)                    # Gamma: cv^2 = 1/shape
    scale = 1.0 / (rate_rps * shape)           # keeps the mean at 1/rate
    gaps = rng.gamma(shape, scale, size=n)
    return _build(gaps, n, seed, "bursty", rate_rps, prompt_len, max_new,
                  rng, extra={"cv": cv})


# ---------------------------------------------------------------------------
# MoE routing histograms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoutingProfile:
    """Per-layer MoE expert-selection histogram: ``counts[layer][expert]``
    routed-token assignments (each decoded token contributes ``top_k``
    selections per MoE layer).

    This is the currency of routed-traffic-aware placement: generators
    below synthesize seeded skew (:func:`zipf_routing`,
    :func:`uniform_routing`), :class:`repro.serve.offload.DecodeOffload`
    *records* its observed selections into one (trace replay), and
    :func:`repro.sharding.rules.ame_pim_expert_placement` consumes one
    to balance expected token mass over stacks.  ``save``/``load``
    round-trip through JSON with field equality, same as :class:`Trace`.
    """

    n_layers: int               # MoE layers only (dense layers excluded)
    n_experts: int
    counts: List[List[int]]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if len(self.counts) != self.n_layers or any(
                len(row) != self.n_experts for row in self.counts):
            raise ValueError(
                f"counts must be {self.n_layers} x {self.n_experts}")

    @classmethod
    def empty(cls, n_layers: int, n_experts: int,
              meta: Optional[Dict] = None) -> "RoutingProfile":
        return cls(n_layers, n_experts,
                   [[0] * n_experts for _ in range(n_layers)],
                   meta=dict(meta or {}))

    # -- recording (trace replay) -------------------------------------------

    def record(self, layer: int, expert: int, tokens: int = 1) -> None:
        self.counts[layer][expert] += int(tokens)

    def record_counts(self, layer: int, sel: Dict[int, int]) -> None:
        row = self.counts[layer]
        for expert, tokens in sel.items():
            row[expert] += int(tokens)

    # -- views ---------------------------------------------------------------

    @property
    def total_tokens(self) -> int:
        return sum(sum(row) for row in self.counts)

    def layer_total(self, layer: int) -> int:
        return sum(self.counts[layer])

    def probs(self, layer: int) -> List[float]:
        """Selection probabilities for one layer (uniform when the layer
        has recorded nothing — an empty histogram routes like one)."""
        total = self.layer_total(layer)
        if total <= 0:
            return [1.0 / self.n_experts] * self.n_experts
        return [c / total for c in self.counts[layer]]

    def expert_mass(self) -> List[int]:
        """Per-expert token mass summed over layers."""
        return [sum(row[e] for row in self.counts)
                for e in range(self.n_experts)]

    def drift(self, other: "RoutingProfile") -> float:
        """Max over layers of the total-variation distance between the
        two normalized histograms (0 = identical mix, 1 = disjoint).
        Layers empty on either side are skipped — no evidence yet."""
        if (self.n_layers, self.n_experts) != (other.n_layers,
                                               other.n_experts):
            raise ValueError("profiles have different shapes")
        worst = 0.0
        for layer in range(self.n_layers):
            if self.layer_total(layer) <= 0 or other.layer_total(layer) <= 0:
                continue
            p, q = self.probs(layer), other.probs(layer)
            worst = max(worst, 0.5 * sum(abs(a - b) for a, b in zip(p, q)))
        return worst

    def copy(self) -> "RoutingProfile":
        return RoutingProfile(self.n_layers, self.n_experts,
                              [list(row) for row in self.counts],
                              meta=dict(self.meta))

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        rec = {"n_layers": self.n_layers, "n_experts": self.n_experts,
               "counts": self.counts, "meta": self.meta}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "RoutingProfile":
        with open(path) as f:
            rec = json.load(f)
        return cls(n_layers=rec["n_layers"], n_experts=rec["n_experts"],
                   counts=[list(row) for row in rec["counts"]],
                   meta=rec.get("meta", {}))


def uniform_routing(n_layers: int, n_experts: int, tokens_per_layer: int,
                    *, seed: int = 0) -> RoutingProfile:
    """Seeded uniform routing: ``tokens_per_layer`` multinomial draws per
    layer with equal expert probabilities — the no-skew baseline."""
    import numpy as np
    rng = np.random.default_rng((15485863, seed))   # domain-separated seed
    counts = [list(map(int, rng.multinomial(
        tokens_per_layer, [1.0 / n_experts] * n_experts)))
        for _ in range(n_layers)]
    return RoutingProfile(n_layers, n_experts, counts,
                          meta={"kind": "uniform", "seed": seed,
                                "tokens_per_layer": tokens_per_layer})


def zipf_routing(n_layers: int, n_experts: int, tokens_per_layer: int,
                 *, alpha: float = 1.0, seed: int = 0) -> RoutingProfile:
    """Seeded Zipf-skewed routing: expert selection probabilities fall as
    ``1 / rank^alpha``, with an independent per-layer permutation mapping
    ranks to expert ids (hot experts differ layer to layer, as measured
    routed traffic does).  ``alpha=1.0`` reproduces the heavy skew the
    Mixtral/DeepSeek-V3 reports describe."""
    import numpy as np
    if alpha < 0:
        raise ValueError(f"alpha must be >= 0, got {alpha}")
    rng = np.random.default_rng((86028157, seed))   # domain-separated seed
    weights = [1.0 / (r + 1) ** alpha for r in range(n_experts)]
    total = sum(weights)
    probs = [w / total for w in weights]
    counts = []
    for _ in range(n_layers):
        perm = rng.permutation(n_experts)
        ranked = rng.multinomial(tokens_per_layer, probs)
        row = [0] * n_experts
        for rank, expert in enumerate(perm):
            row[int(expert)] = int(ranked[rank])
        counts.append(row)
    return RoutingProfile(n_layers, n_experts, counts,
                          meta={"kind": "zipf", "alpha": alpha, "seed": seed,
                                "tokens_per_layer": tokens_per_layer})


# ---------------------------------------------------------------------------
# Host-side cost model (prefill roofline + KV handoff bytes)
# ---------------------------------------------------------------------------


class HostCostModel:
    """Analytic prices for the host-XLA side of a disaggregated server.

    Prefill runs on the host device (the same roofline device —
    ``hw.PEAK_FLOPS`` / ``hw.HBM_BW`` — the offload's per-step
    ``host_s`` compares against): ``prefill_s(T)`` is
    ``max(T * flops_per_token / peak, weight_bytes / bw)`` — compute-
    bound for long prompts, weight-read-bound for short ones.
    ``decode_step_s`` prices one *host* decode iteration (the Server's
    virtual clock without a PIM sidecar).  ``kv_ship_bytes(T)`` is the
    K+V a ``T``-token prefill must hand off host -> PIM.

    Families :func:`repro.serve.offload.decode_matmuls` does not model
    (ssm/hybrid) fall back to a generic dense-transformer estimate, so
    the model is always constructible.
    """

    def __init__(self, cfg: ArchConfig, *,
                 peak_flops: float = None, hbm_bw: float = None):
        self.cfg = cfg
        self.peak_flops = float(peak_flops if peak_flops is not None
                                else hw.PEAK_FLOPS)
        self.hbm_bw = float(hbm_bw if hbm_bw is not None else hw.HBM_BW)
        try:
            from repro.serve.offload import decode_matmuls
            mats = decode_matmuls(cfg)
            self.weight_bytes = sum(m.weight_bytes for m in mats)
            self.flops_per_token = 2 * sum(
                m.out_dim * m.in_dim * m.count for m in mats)
            self.act_bytes_per_token = sum(
                m.in_dim * m.count for m in mats) * BYTES_PER_ELEM
        except ValueError:      # family outside the decode matmul set
            d = getattr(cfg, "d_model", 1024)
            L = getattr(cfg, "n_layers", 16)
            vocab = getattr(cfg, "vocab_padded",
                            getattr(cfg, "vocab_size", 32000))
            params = L * 12 * d * d + vocab * d
            self.weight_bytes = params * BYTES_PER_ELEM
            self.flops_per_token = 2 * params
            self.act_bytes_per_token = L * 7 * d * BYTES_PER_ELEM
        heads = max(1, getattr(cfg, "n_kv_heads", 1) or 1)
        hd = getattr(cfg, "head_dim_", getattr(cfg, "head_dim", 64)) or 64
        L = getattr(cfg, "n_layers", 16)
        #: K + V bytes one token adds across every layer
        self.kv_bytes_per_token = L * heads * hd * 2 * BYTES_PER_ELEM

    def prefill_s(self, tokens: int) -> float:
        """Host-XLA roofline seconds to prefill ``tokens`` prompt
        tokens (always > 0 — the weight read is a hard floor)."""
        tokens = max(1, int(tokens))
        return max(tokens * self.flops_per_token / self.peak_flops,
                   self.weight_bytes / self.hbm_bw)

    def decode_step_s(self, batch: int) -> float:
        """Host-XLA roofline seconds for one decode iteration over
        ``batch`` live slots (weight-read bound at serving batch)."""
        batch = max(1, int(batch))
        return max(batch * self.flops_per_token / self.peak_flops,
                   (self.weight_bytes
                    + batch * self.act_bytes_per_token) / self.hbm_bw)

    def kv_ship_bytes(self, tokens: int) -> int:
        """K/V bytes a ``tokens``-token prefill hands off host -> PIM."""
        return int(tokens) * self.kv_bytes_per_token


# ---------------------------------------------------------------------------
# SLOs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLO:
    """A per-request latency objective: TTFT and TPOT bounds in seconds.

    A request *meets* the SLO when its TTFT is within ``ttft_s`` and its
    decode tail averages within ``tpot_s`` per token (single-token
    requests have no TPOT and are judged on TTFT alone).  Goodput is
    the rate of SLO-met completions — the paper-grade serving metric.
    """

    ttft_s: float
    tpot_s: float

    def met(self, ttft: float, tpot: Optional[float]) -> bool:
        if ttft > self.ttft_s:
            return False
        return tpot is None or tpot <= self.tpot_s


# ---------------------------------------------------------------------------
# Slot autoscaling policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StaticSlots:
    """No autoscaling: hold ``slots`` decode slots forever."""

    slots: int

    def target(self, *, queue_len: int, slots: int, live: int,
               recent_ttft: Sequence[float]) -> int:
        return self.slots


@dataclasses.dataclass
class QueueProportionalSlots:
    """Scale decode slots with queue depth: one extra slot per
    ``per_queue`` queued requests above empty, clamped to
    ``[min_slots, max_slots]``.  Purely reactive — no SLO knowledge."""

    min_slots: int = 1
    max_slots: int = 16
    per_queue: int = 4

    def target(self, *, queue_len: int, slots: int, live: int,
               recent_ttft: Sequence[float]) -> int:
        want = self.min_slots + queue_len // max(1, self.per_queue)
        return max(self.min_slots, min(self.max_slots, want))


@dataclasses.dataclass
class SLOFeedbackSlots:
    """Closed-loop policy: grow while the recent TTFT tail violates the
    SLO, shrink when it sits comfortably inside it.

    Looks at the last ``window`` admitted requests' TTFTs: if the
    worst exceeds ``slo.ttft_s`` grow by one slot; if every one is
    under ``shrink_frac`` of the bound, give a slot back.
    """

    slo: SLO
    min_slots: int = 1
    max_slots: int = 16
    window: int = 16
    shrink_frac: float = 0.5

    def target(self, *, queue_len: int, slots: int, live: int,
               recent_ttft: Sequence[float]) -> int:
        recent = list(recent_ttft)[-self.window:]
        want = slots
        if recent and max(recent) > self.slo.ttft_s:
            want = slots + 1
        elif recent and max(recent) <= self.shrink_frac * self.slo.ttft_s \
                and queue_len == 0:
            want = slots - 1
        return max(self.min_slots, min(self.max_slots, want))
