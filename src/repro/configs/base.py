"""Architecture configuration system.

Every assigned architecture is an :class:`ArchConfig`; the full configs live
in sibling modules (one per arch) and register themselves in :data:`ARCHS`.
``reduced()`` returns the family-preserving smoke-test variant; the full
configs are only ever lowered via ShapeDtypeStructs (dry-run), never
allocated on this host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
from jax import ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0               # shared (always-on) experts
    first_dense_layers: int = 0     # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    sharding: str = "ep"            # "ep": experts on model axis; "tp": inside-expert


@dataclasses.dataclass(frozen=True)
class MLAConfig:                    # DeepSeek-V3 multi-head latent attention
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:                    # Mamba2 / SSD
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64              # P
    n_groups: int = 1               # B/C groups (GQA-like)
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class HybridConfig:                 # Zamba2: shared attention block
    shared_every: int = 6           # apply the shared block every N ssm blocks
    n_shared_blocks: int = 2        # distinct shared blocks, used round-robin
    lora_rank: int = 64             # per-application LoRA on the shared block


#: tensor-parallel modes whose mesh posture is output-dim sharding with
#: no partial sums across 'model' (the paper's reduction-free dataflow;
#: 'ame_pim' shares it and adds the PIM cluster stack map) — consulted by
#: sharding.rules and models.layers so the two cannot drift
OUTPUT_SHARDED_TP_MODES = ("allgather", "ame_pim")


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numerics + distribution policy (per arch, overridable per run)."""

    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    fsdp: bool = False              # shard params/opt-state over 'data' too
    microbatches: int = 1           # grad-accumulation splits of the batch
    moment_dtype: str = "float32"   # adam m/v dtype: float32|bfloat16|int8
    factored_v: bool = False        # adafactor-style factored second moment
    sp: bool = False                # sequence-parallel residual stream
    sp_rs: bool = False             # constrain block outputs seq-sharded
                                    # (refuted iter-1: SPMD emits no RS here)
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    tp_mode: str = "allreduce"      # 'allreduce' (megatron) | 'allgather'
                                    # ('allgather' = the paper's reduction-free
                                    #  dataflow at mesh level, DESIGN.md §3)
                                    # | 'ame_pim' (allgather specs + PIM
                                    #  cluster stack map, sharding.rules)
    grad_compression: bool = False  # bf16+error-feedback cross-pod grad sync


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|vlm|hybrid|ssm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # explicit (gemma: 256); default dm/heads
    act: str = "swiglu"             # swiglu|geglu|gelu
    norm: str = "rmsnorm"           # rmsnorm|layernorm
    pos_embed: str = "rope"         # rope|learned
    qk_norm: bool = False
    rope_theta: float = 1e6
    attn_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    sliding_window: int = 0         # >0: SWA (mixtral)
    encoder_only: bool = False      # hubert
    modality: str = "text"          # text|vision_text|audio_frames
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    mtp: bool = False               # multi-token-prediction aux head
    policy: Policy = dataclasses.field(default_factory=Policy)
    source: str = ""                # provenance note

    # -- derived -----------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the 'model' axis (16) always divides it."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def quadratic_attention(self) -> bool:
        """True if the arch has no sub-quadratic path for 500k context."""
        if self.family in ("ssm",):
            return False
        if self.hybrid is not None:
            return False            # mamba backbone + sparse shared attn
        return self.sliding_window == 0

    def compute_dtype_(self):
        return jnp.bfloat16 if self.policy.compute_dtype == "bfloat16" else jnp.float32

    def param_dtype_(self):
        return jnp.bfloat16 if self.policy.param_dtype == "bfloat16" else jnp.float32

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def with_policy(self, **kw) -> "ArchConfig":
        return self.replace(policy=dataclasses.replace(self.policy, **kw))

    # -- smoke-test variant --------------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4 if self.hybrid is None else 7),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim else None,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.mla:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                  qk_nope_dim=16, qk_rope_dim=16,
                                  v_head_dim=32)
            kw["head_dim"] = None
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk=32)
        if self.hybrid:
            kw["hybrid"] = dataclasses.replace(self.hybrid, shared_every=3,
                                               lora_rank=8)
        kw["policy"] = dataclasses.replace(
            self.policy, param_dtype="float32", compute_dtype="float32",
            microbatches=1, fsdp=False)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned grid)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train|prefill|decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and why not if skipped."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.quadratic_attention:
        return False, "full quadratic attention at 500k context"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                reduced: bool = False) -> Dict[str, ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends are stubs per the brief: vision supplies precomputed
    patch embeddings, audio supplies precomputed frame embeddings.
    """
    b, t = shape.global_batch, shape.seq_len
    if reduced:
        b, t = min(b, 2), min(t, 64)
    i32, f = jnp.int32, cfg.compute_dtype_()
    d = cfg.d_model
    if shape.kind == "train":
        if cfg.modality == "audio_frames":
            return {
                "frames": ShapeDtypeStruct((b, t, d), f),
                "mask": ShapeDtypeStruct((b, t), jnp.bool_),
                "targets": ShapeDtypeStruct((b, t), i32),
            }
        out = {
            "tokens": ShapeDtypeStruct((b, t), i32),
            "targets": ShapeDtypeStruct((b, t), i32),
            "loss_mask": ShapeDtypeStruct((b, t), f),
        }
        if cfg.modality == "vision_text":
            npatch = max(t // 4, 16)
            tt = t - npatch
            out["vision_embeds"] = ShapeDtypeStruct((b, npatch, d), f)
            out["tokens"] = ShapeDtypeStruct((b, tt), i32)
            out["targets"] = ShapeDtypeStruct((b, tt), i32)
            out["loss_mask"] = ShapeDtypeStruct((b, tt), f)
        return out
    if shape.kind == "prefill":
        if cfg.modality == "audio_frames":
            return {"frames": ShapeDtypeStruct((b, t, d), f)}
        out = {"tokens": ShapeDtypeStruct((b, t), i32)}
        if cfg.modality == "vision_text":
            npatch = max(t // 4, 16)
            out["vision_embeds"] = ShapeDtypeStruct((b, npatch, d), f)
            out["tokens"] = ShapeDtypeStruct((b, t - npatch), i32)
        return out
    # decode: one new token against a cache of length t
    return {"tokens": ShapeDtypeStruct((b, 1), i32),
            "positions": ShapeDtypeStruct((b,), i32)}


#: registry, populated by the per-arch modules
ARCHS: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)
    return ARCHS[name]


def all_names():
    import repro.configs  # noqa: F401
    return sorted(ARCHS)
