"""zamba2-2.7b [hybrid] — 54 Mamba2 blocks, d_model=2560, shared attention
blocks (32H kv=32, d_ff=10240) every 6 blocks with per-application LoRA,
ssm_state=64.  [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig, HybridConfig, Policy, SSMConfig, register

ZAMBA2_2_7B = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    act="gelu",
    rope_theta=1e4,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    hybrid=HybridConfig(shared_every=6, n_shared_blocks=2, lora_rank=64),
    policy=Policy(param_dtype="float32", compute_dtype="bfloat16",
                  microbatches=8),
    source="arXiv:2411.15242",
))
