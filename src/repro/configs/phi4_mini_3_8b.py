"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064; RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]"""
from repro.configs.base import ArchConfig, Policy, register

PHI4_MINI_3_8B = register(ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    act="swiglu",
    rope_theta=1e4,
    tie_embeddings=True,
    policy=Policy(param_dtype="float32", compute_dtype="bfloat16",
                  microbatches=4),
    source="arXiv:2412.08905",
))
