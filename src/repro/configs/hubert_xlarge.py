"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504
(masked-prediction classes); encoder-only.  [arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings.  Training = masked frame prediction (CE over
504 cluster targets on masked positions).  No decode shapes (encoder).
"""
from repro.configs.base import ArchConfig, Policy, register

HUBERT_XLARGE = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
    norm="layernorm",
    pos_embed="sinusoidal",  # stand-in for HuBERT conv pos-embedding (stubbed)
    encoder_only=True,
    modality="audio_frames",
    policy=Policy(param_dtype="float32", compute_dtype="bfloat16",
                  microbatches=4),
    source="arXiv:2106.07447",
))
