"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, ssm_state=128,
SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*1024 = 2048, head_dim 64 => 32 SSD heads.  No attention =>
no KV cache; decode shapes use the recurrent state (O(1) per token), so
the long_500k cell RUNS.
"""
from repro.configs.base import ArchConfig, Policy, SSMConfig, register

MAMBA2_370M = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True,
    policy=Policy(param_dtype="float32", compute_dtype="bfloat16",
                  microbatches=4),
    source="arXiv:2405.21060",
))
