"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936; qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ArchConfig, Policy, register

QWEN3_1_7B = register(ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    act="swiglu",
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    policy=Policy(param_dtype="float32", compute_dtype="bfloat16",
                  microbatches=4),
    source="hf:Qwen/Qwen3-1.7B",
))
