"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; InternViT + LLM backbone.  [arXiv:2404.16821; unverified]

Per the brief the ViT frontend is a STUB: input_specs() provides
precomputed patch embeddings that are concatenated ahead of the text
tokens.  The config below is the language backbone only.
"""
from repro.configs.base import ArchConfig, Policy, register

INTERNVL2_76B = register(ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    act="swiglu",
    rope_theta=5e5,
    modality="vision_text",
    policy=Policy(param_dtype="bfloat16", compute_dtype="bfloat16",
                  fsdp=True, sp=True, microbatches=8, moment_dtype="bfloat16",
                  remat_policy="save_collectives",
                  grad_compression=True),
    source="arXiv:2404.16821",
))
