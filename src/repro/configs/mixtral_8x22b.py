"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768; 8 experts top-2, SWA.  [arXiv:2401.04088; hf]

8 experts cannot split a 16-way model axis, so MoE sharding is "tp":
expert-internal tensor parallelism (d_ff_expert 16384 / 16 = 1024).
SWA window 4096 => sub-quadratic => the long_500k cell RUNS (rolling cache).
"""
from repro.configs.base import ArchConfig, MoEConfig, Policy, register

MIXTRAL_8X22B = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    act="swiglu",
    rope_theta=1e6,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25, sharding="tp"),
    policy=Policy(param_dtype="bfloat16", compute_dtype="bfloat16",
                  fsdp=True, sp=True, microbatches=8, moment_dtype="bfloat16",
                  remat_policy="save_collectives",
                  grad_compression=True),
    source="arXiv:2401.04088",
))
