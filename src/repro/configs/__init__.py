"""Assigned architecture configs (+ the paper-native PIM config in
repro.core).  Importing this package registers all archs in base.ARCHS."""
from repro.configs.base import (  # noqa: F401
    ARCHS, ArchConfig, Policy, SHAPES, ShapeSpec, applicable, get,
    all_names, input_specs, register,
)
from repro.configs import (  # noqa: F401
    command_r_35b,
    deepseek_v3_671b,
    gemma_2b,
    hubert_xlarge,
    internvl2_76b,
    mamba2_370m,
    mixtral_8x22b,
    phi4_mini_3_8b,
    qwen3_1_7b,
    zamba2_2_7b,
)
