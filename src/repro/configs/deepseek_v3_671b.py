"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280; MLA, 1 shared + 256 routed top-8, MTP.  [arXiv:2412.19437; hf]

d_ff=18432 is the dense-layer (first 3 layers) intermediate size; the
assigned d_ff=2048 is the per-expert intermediate.  Memory policy: bf16
params, int8 blockwise Adam moments + factored v, 8 microbatches — the
671B config must fit 256 x 16 GB on the single-pod mesh (EXPERIMENTS.md).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, Policy, register

DEEPSEEK_V3_671B = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    act="swiglu",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  first_dense_layers=3, capacity_factor=1.25,
                  sharding="ep"),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    mtp=True,
    policy=Policy(param_dtype="bfloat16", compute_dtype="bfloat16",
                  fsdp=True, sp=True, microbatches=4, moment_dtype="int8",
                  remat_policy="save_collectives",
                  factored_v=True, grad_compression=True),
    source="arXiv:2412.19437",
))
