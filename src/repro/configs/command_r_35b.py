"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000; GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchConfig, Policy, register

COMMAND_R_35B = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    act="swiglu",
    rope_theta=4e6,
    attn_bias=False,
    tie_embeddings=True,
    policy=Policy(param_dtype="bfloat16", compute_dtype="bfloat16",
                  fsdp=True, sp=True, microbatches=8, grad_compression=True,
                  remat_policy="save_collectives"),
    source="hf:CohereForAI/c4ai-command-r-v01",
))
