"""Public jit'd wrappers for the Pallas kernels.

On this container the TPU kernels execute via ``interpret=True`` (the body
runs on CPU); on a real TPU the same calls compile to Mosaic.  The
``KernelMode`` switch is what the model stack's MatmulBackend consults:

  * ``xla``       — plain jnp ops (used for the 512-device dry-run lowering)
  * ``pallas``    — pallas_call, interpret on CPU / compiled on TPU
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ame_gemm import ame_gemm
from repro.kernels.attention import flash_attention
from repro.kernels.elementwise import ame_elementwise
from repro.kernels.ssd_scan import ssd_scan

_ON_TPU = jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _ON_TPU


def gemm(a: jnp.ndarray, b: jnp.ndarray, *, use_pallas: bool = False,
         out_dtype=None, **blocks) -> jnp.ndarray:
    """C = A @ B via the reduction-free output-stationary kernel or XLA."""
    if use_pallas:
        return ame_gemm(a, b, out_dtype=out_dtype, interpret=_interpret(),
                        **blocks)
    return ref.gemm(a, b, out_dtype=out_dtype)


def elementwise(kind: str, a: jnp.ndarray, b: jnp.ndarray, *,
                relu: bool = False, use_pallas: bool = False) -> jnp.ndarray:
    if use_pallas:
        return ame_elementwise(a, b, kind=kind, relu=relu,
                               interpret=_interpret())
    return ref.elementwise(kind, a, b, relu=relu)


def ssd(x, log_a, b, c, *, use_pallas: bool = False, chunk: int = 128):
    """Batched Mamba2 SSD scan (chunked in both paths — the sequential
    recurrence lives only in ref.py as the oracle)."""
    if use_pallas:
        return ssd_scan(x, log_a, b, c, chunk=chunk, interpret=_interpret())
    from repro.kernels.ssd_scan import ssd_chunked_jnp
    return ssd_chunked_jnp(x, log_a, b, c, chunk=chunk)


def ssd4(x, log_a, b, c, *, use_pallas: bool = False, chunk: int = 128):
    """4-D SSD: x (B,H,T,P) — heads stay a shardable axis ('model')."""
    if use_pallas:
        bsz, h, t, p = x.shape
        y = ssd_scan(x.reshape(bsz * h, t, p),
                     log_a.reshape(bsz * h, t),
                     b.reshape(bsz * h, t, -1), c.reshape(bsz * h, t, -1),
                     chunk=chunk, interpret=_interpret())
        return y.reshape(bsz, h, t, p)
    from repro.kernels.ssd_scan import ssd_chunked_jnp4
    return ssd_chunked_jnp4(x, log_a, b, c, chunk=chunk)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_pallas: bool = False, **blocks):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret(), **blocks)
    return jax.vmap(functools.partial(ref.attention, causal=causal,
                                      window=window))(q, k, v)
