"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function is the semantic specification its kernel is tested against
(interpret=True) across shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(a: jnp.ndarray, b: jnp.ndarray,
         out_dtype=None) -> jnp.ndarray:
    """C = A @ B with f32 accumulation (the ame_gemm oracle)."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def elementwise(kind: str, a: jnp.ndarray, b: jnp.ndarray,
                relu: bool = False) -> jnp.ndarray:
    """mfadd/mfsub/mfmul semantics; optional fused ReLU-on-writeback
    (the PIM data-movement activation capability, paper §2.3.2)."""
    if kind == "add":
        o = a + b
    elif kind == "sub":
        o = a - b
    elif kind == "mul":
        o = a * b
    else:
        raise ValueError(kind)
    return jax.nn.relu(o) if relu else o


def ssd_scan(x: jnp.ndarray, log_a: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray) -> jnp.ndarray:
    """Mamba2 SSD reference: sequential recurrence over time.

      S_t = exp(log_a_t) * S_{t-1} + b_t (outer) x_t        (N, P) state
      y_t = c_t @ S_t

    Shapes: x (T, P), log_a (T,), b (T, N), c (T, N) -> y (T, P).
    The state update IS the paper's reduction-free outer-product
    accumulation — rank-1 updates into a resident accumulator.
    """
    t, p = x.shape
    n = b.shape[-1]

    def step(s, inp):
        xt, lat, bt, ct = inp
        s = jnp.exp(lat) * s + bt[:, None] * xt[None, :]
        return s, ct @ s

    s0 = jnp.zeros((n, p), jnp.float32)
    _, y = jax.lax.scan(step, s0, (x.astype(jnp.float32),
                                   log_a.astype(jnp.float32),
                                   b.astype(jnp.float32),
                                   c.astype(jnp.float32)))
    return y.astype(x.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int = 0,
              scale: float | None = None) -> jnp.ndarray:
    """Naive softmax attention. q (Tq, D), k/v (Tk, D); Tq aligned to the
    *end* of the kv sequence (decode: Tq=1, Tk=cache length).

    window > 0 = sliding-window attention (each query sees the last
    ``window`` keys)."""
    tq, d = q.shape
    tk = k.shape[0]
    scale = scale if scale is not None else d ** -0.5
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    qpos = jnp.arange(tq)[:, None] + (tk - tq)
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
