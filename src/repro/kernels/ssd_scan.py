"""ssd_scan — Mamba2 SSD chunked scan as an output-stationary Pallas kernel.

The SSD recurrence  S_t = a_t * S_{t-1} + b_t ⊗ x_t,  y_t = c_t @ S_t  is the
paper's reduction-free dataflow verbatim: a rank-1 (outer-product) update
into an accumulator that never leaves local memory.  The TPU mapping keeps
the (N x P) state resident in **VMEM scratch** across the whole time walk —
grid = (BH, T/L) with the chunk dimension minor — while each chunk is
processed with MXU matmuls (the state-space-duality block form):

  y_chunk = (C * exp(cum)) @ S_in  +  tril((C @ B^T) * decay) @ X
  S_out   = exp(sum) * S_in        +  (B * exp(sum - cum))^T @ X

so inter-chunk work is the resident-accumulator path and intra-chunk work
is a small attention-like matmul block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_CHUNK = 128
NEG = -1e30


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, state_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    la = la_ref[0].astype(jnp.float32)        # (L,)  log decay (<= 0)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    c = c_ref[0].astype(jnp.float32)          # (L, N)
    l = x.shape[0]

    cum = jnp.cumsum(la)                      # inclusive log-decay prefix
    s_in = state_ref[...]                     # (N, P) resident state

    # inter-chunk: queries against the carried state
    y = (c * jnp.exp(cum)[:, None]) @ s_in

    # intra-chunk: causal decay-masked score block (state-space duality)
    ti = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    diff = jnp.where(si <= ti, cum[:, None] - cum[None, :], NEG)
    g = (c @ b.T) * jnp.exp(diff)
    y += g @ x
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: decayed carry + outer-product accumulation of the chunk
    state_ref[...] = (jnp.exp(cum[-1]) * s_in
                      + (b * jnp.exp(cum[-1] - cum)[:, None]).T @ x)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_jnp(x: jnp.ndarray, log_a: jnp.ndarray, b: jnp.ndarray,
                    c: jnp.ndarray, *, chunk: int = DEFAULT_CHUNK):
    """Pure-jnp twin of the Pallas kernel: chunked SSD with the state
    carried once per chunk (not per step) — this is the XLA-lowered path
    the dry-run sees; HBM traffic scales with T/chunk, not T."""
    bh, t, p = x.shape
    n = b.shape[-1]
    lc = min(chunk, t)
    pad = (-t) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // lc
    xs = x.reshape(bh, nc, lc, p).transpose(1, 0, 2, 3).astype(jnp.float32)
    las = log_a.reshape(bh, nc, lc).transpose(1, 0, 2).astype(jnp.float32)
    bs = b.reshape(bh, nc, lc, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    cs = c.reshape(bh, nc, lc, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    ti = jnp.arange(lc)[:, None]
    si = jnp.arange(lc)[None, :]
    causal = si <= ti

    def step(s, inp):
        xc, lac, bc, cc = inp                     # (BH, L, ...)
        cum = jnp.cumsum(lac, -1)                 # (BH, L)
        y = jnp.einsum("zln,znp->zlp", cc * jnp.exp(cum)[..., None], s)
        diff = jnp.where(causal[None], cum[:, :, None] - cum[:, None, :], NEG)
        g = jnp.einsum("zln,zmn->zlm", cc, bc) * jnp.exp(diff)
        y = y + jnp.einsum("zlm,zmp->zlp", g, xc)
        w = jnp.exp(cum[:, -1:] - cum)            # (BH, L)
        s = (jnp.exp(cum[:, -1])[:, None, None] * s
             + jnp.einsum("zln,zlp->znp", bc * w[..., None], xc))
        return s, y

    s0 = jnp.zeros((bh, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (xs, las, bs, cs))
    y = ys.transpose(1, 0, 2, 3).reshape(bh, t + pad, p)[:, :t]
    return y.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_jnp4(x: jnp.ndarray, log_a: jnp.ndarray, b: jnp.ndarray,
                     c: jnp.ndarray, *, chunk: int = DEFAULT_CHUNK):
    """4-D chunked SSD: x (B,H,T,P), log_a (B,H,T), b/c (B,H,T,N).

    Keeping batch and heads as separate leading dims lets SPMD shard them
    on ('data', 'model') natively — the (B*H)-flattened form forces either
    replication or per-layer resharding all-to-alls."""
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    lc = min(chunk, t)
    pad = (-t) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // lc
    f32 = jnp.float32
    # stacked chunk arrays stay in the input dtype (bf16): the f32 cast is
    # per-chunk inside the scan (VMEM-local on TPU), halving HBM traffic
    xs = x.reshape(bsz, h, nc, lc, p).transpose(2, 0, 1, 3, 4)
    las = log_a.reshape(bsz, h, nc, lc).transpose(2, 0, 1, 3).astype(f32)
    bs = b.reshape(bsz, h, nc, lc, n).transpose(2, 0, 1, 3, 4)
    cs = c.reshape(bsz, h, nc, lc, n).transpose(2, 0, 1, 3, 4)
    causal = jnp.arange(lc)[:, None] >= jnp.arange(lc)[None, :]

    def step(s, inp):
        xc, lac, bc, cc = inp                     # (B,H,L,...)
        xc, bc, cc = (xc.astype(f32), bc.astype(f32), cc.astype(f32))
        cum = jnp.cumsum(lac, -1)                 # (B,H,L)
        y = jnp.einsum("bhln,bhnp->bhlp", cc * jnp.exp(cum)[..., None], s)
        diff = jnp.where(causal[None, None],
                         cum[..., :, None] - cum[..., None, :], NEG)
        g = jnp.einsum("bhln,bhmn->bhlm", cc, bc) * jnp.exp(diff)
        y = y + jnp.einsum("bhlm,bhmp->bhlp", g, xc)
        w = jnp.exp(cum[..., -1:] - cum)          # (B,H,L)
        s = (jnp.exp(cum[..., -1])[..., None, None] * s
             + jnp.einsum("bhln,bhlp->bhnp", bc * w[..., None], xc))
        return s, y

    s0 = jnp.zeros((bsz, h, n, p), f32)
    _, ys = jax.lax.scan(step, s0, (xs, las, bs, cs))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(bsz, h, t + pad, p)[:, :, :t]
    return y.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, log_a: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False) -> jnp.ndarray:
    """Batched SSD scan.  x (BH, T, P), log_a (BH, T), b/c (BH, T, N).

    T is padded to a chunk multiple with log_a = 0 / b = 0 (exactly neutral:
    state carries through, outputs for the pad are dropped).
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    lc = min(chunk, t)
    pad = (-t) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad

    out = pl.pallas_call(
        _ssd_kernel,
        grid=(bh, tt // lc),
        in_specs=[
            pl.BlockSpec((1, lc, p), lambda i, tchunk: (i, tchunk, 0)),
            pl.BlockSpec((1, lc), lambda i, tchunk: (i, tchunk)),
            pl.BlockSpec((1, lc, n), lambda i, tchunk: (i, tchunk, 0)),
            pl.BlockSpec((1, lc, n), lambda i, tchunk: (i, tchunk, 0)),
        ],
        out_specs=pl.BlockSpec((1, lc, p), lambda i, tchunk: (i, tchunk, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tt, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, log_a, b, c)
    return out[:, :t]
