"""Version-compatibility shims for JAX Pallas TPU APIs.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases (and the old name later removed).  The kernels in this package
target the new spelling; this module resolves whichever one the installed
JAX provides so the same kernel source runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

#: ``pltpu.CompilerParams`` on new JAX, ``pltpu.TPUCompilerParams`` on old.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
