"""Fused elementwise PEP kernel (ADD/MUL/SUB-PEP on TPU).

The PIM elementwise PEPs are bound by a 2:1 / 3:1 data-movement-to-compute
command ratio (paper §4.2: fill + op + mov per window).  On TPU the same
workload is pure HBM-bandwidth-bound, so the adaptation is a *fused* VPU
kernel: one HBM read per operand, one write, with the arithmetic — and the
optional activation the PIM MOV can apply on the fly (§2.3.2) — folded into
the single pass.  This is exactly the paper's proposed mitigation
("fusing multiple instructions, combining operand loading and arithmetic").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BC = 512

_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
}


def _ew_kernel(a_ref, b_ref, o_ref, *, kind: str, relu: bool):
    o = _OPS[kind](a_ref[...], b_ref[...])
    if relu:  # activation fused into the writeback (the PIM MOV+ReLU path)
        o = jnp.maximum(o, 0)
    o_ref[...] = o


@functools.partial(jax.jit, static_argnames=("kind", "relu", "block_m",
                                             "block_c", "interpret"))
def ame_elementwise(a: jnp.ndarray, b: jnp.ndarray, *, kind: str = "add",
                    relu: bool = False, block_m: int = DEFAULT_BM,
                    block_c: int = DEFAULT_BC,
                    interpret: bool = False) -> jnp.ndarray:
    """Elementwise mfadd/mfsub/mfmul over (m, c) tiles, optionally +ReLU."""
    assert a.shape == b.shape and a.ndim == 2
    m, c = a.shape
    bm, bc = min(block_m, m), min(block_c, c)
    pm, pc = (-m) % bm, (-c) % bc
    if pm or pc:
        a = jnp.pad(a, ((0, pm), (0, pc)))
        b = jnp.pad(b, ((0, pm), (0, pc)))
    out = pl.pallas_call(
        functools.partial(_ew_kernel, kind=kind, relu=relu),
        grid=(a.shape[0] // bm, a.shape[1] // bc),
        in_specs=[pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:m, :c]
