"""ame_gemm — output-stationary outer-product GEMM (MAC-PEP on TPU).

TPU adaptation of the paper's reduction-free dataflow (DESIGN.md §3):

* The MAC-PEP keeps the accumulator column resident next to the MAC units
  (odd banks) for the whole K walk.  Here the accumulator tile is pinned in
  **VMEM scratch** for the whole K walk: grid = (M/bm, N/bn, K/bk) with K
  as the *minor* (sequential) dimension, so each (i, j) output tile sees
  its K-blocks back-to-back and partial sums never spill to HBM — unlike
  split-K GEMM, which writes partials and reduces (the host-side reduction
  the paper eliminates).
* The PIM unit's 16-lane FP16 rank-1 update becomes a (bm x bk)·(bk x bn)
  MXU rank-bk update; ROWNUM=128 survives as the default bm (MXU-native).
* Accumulation is f32 (MXU accumulator width), cast on the final K step —
  the single-rounding FMA semantics of the MAC datapath, block-wise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

# MXU-native defaults; ROWNUM=128 from the paper's tile mapping
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One grid step: rank-bk outer-product update into the resident acc."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def ame_gemm(a: jnp.ndarray, b: jnp.ndarray, *,
             block_m: int = DEFAULT_BM, block_n: int = DEFAULT_BN,
             block_k: int = DEFAULT_BK, out_dtype=None,
             interpret: bool = False) -> jnp.ndarray:
    """C = A(m,k) @ B(k,n), accumulation resident in VMEM (reduction-free).

    Shapes are padded up to block multiples (zero padding is exact for
    matmul).  ``interpret=True`` runs the kernel body on CPU for validation.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def vmem_bytes(block_m: int = DEFAULT_BM, block_n: int = DEFAULT_BN,
               block_k: int = DEFAULT_BK, dtype_bytes: int = 2) -> int:
    """Working-set claim: A-block + B-block (double-buffered) + f32 acc."""
    stream = 2 * (block_m * block_k + block_k * block_n) * dtype_bytes
    return stream + block_m * block_n * 4
