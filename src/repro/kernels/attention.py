"""Flash-style chunked attention Pallas kernel (prefill hot-spot).

Online-softmax attention with the running (m, l, acc) statistics resident in
VMEM scratch across the KV walk — the same output-stationary posture as
ame_gemm: the output tile's accumulator never leaves VMEM while the
contraction (KV) dimension streams through.  Supports causal masking and
sliding windows (Mixtral SWA); queries are end-aligned against the KV
sequence so the same kernel serves prefill and chunked decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG = -1e30
_STAT_LANES = 128  # m/l scratch kept 2D and lane-aligned for the VPU


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, tq: int, tk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    s = (q @ k.T) * scale                         # (bq, bk)

    bq, bk = s.shape
    qpos = (qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            + (tk - tq))                          # end-aligned query positions
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < tk                              # KV padding
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_old = m_ref[:, 0]                           # (bq,)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = (l_ref[...] * corr[:, None]
                  + jnp.broadcast_to(jnp.sum(p, -1)[:, None], l_ref.shape))
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + p @ v_ref[0].astype(jnp.float32))
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q (BH, Tq, D), k/v (BH, Tk, D) -> (BH, Tq, D); Tq end-aligned to Tk."""
    bh, tq, d = q.shape
    tk = k.shape[1]
    scale = d ** -0.5
    bq, bk = min(block_q, tq), min(block_k, tk)
    pq, pk = (-tq) % bq, (-tk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, tq=tq, tk=tk),
        grid=(bh, (tq + pq) // bq, (tk + pk) // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda i, qi, ki: (i, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda i, qi, ki: (i, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),   # running sum
            pltpu.VMEM((bq, d), jnp.float32),             # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :tq]
