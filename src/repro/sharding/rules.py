"""PartitionSpec rules: parameter/optimizer/batch/cache shardings per arch.

The tensor-parallel dataflows (DESIGN.md §3):

* ``allreduce`` (Megatron): up-projections column-sharded on 'model',
  down-projections row-sharded => partial sums all-reduced.
* ``allgather`` (the paper's reduction-free outer-product dataflow): every
  weight sharded on its *output* dim; inputs are all-gathered just-in-time
  and partial sums never cross the 'model' axis.
* ``ame_pim`` — the device-runtime flavor: mesh-level specs are the
  ``allgather`` output-dim sharding (the PIM dataflow is reduction-free
  and output-stationary, so partial sums never cross 'model' there
  either), plus a *stack* assignment for the PIM cluster: model-parallel
  layouts map layers (and experts) onto :class:`~repro.runtime.cluster.
  PIMCluster` stacks as contiguous blocks — :func:`ame_pim_layer_stacks`
  / :func:`ame_pim_stack_map`, consumed by ``repro.serve.offload.
  DecodeOffload(stacks=...)``.

FSDP ('data'-axis parameter + optimizer-state sharding) stacks on top for
the large archs (policy.fsdp).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, OUTPUT_SHARDED_TP_MODES


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _fits(shape, spec, mesh: Mesh):
    """Drop axes that don't divide the dim (e.g. 8 KV heads on model=16)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is not None and (i >= len(shape)
                               or shape[i] % _axis_size(mesh, ax) != 0):
            ax = None
        out.append(ax)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def _base_rule(pstr: str, cfg: ArchConfig) -> Tuple:
    """Logical spec for the *unstacked* parameter (innermost dims)."""
    fsdp = "data" if cfg.policy.fsdp else None
    ag = cfg.policy.tp_mode in OUTPUT_SHARDED_TP_MODES
    ep = cfg.moe is not None and cfg.moe.sharding == "ep"

    if "embed/table" in pstr:
        return ("model", fsdp)
    if "head/w" in pstr or "mtp_proj/w" in pstr:
        return (fsdp, "model")
    if "experts/wi" in pstr or "experts/wg" in pstr:
        return ("model", fsdp, None) if ep else (None, fsdp, "model")
    if "experts/wo" in pstr:
        if ep:
            return ("model", None, fsdp)
        return (None, fsdp, "model") if ag else (None, "model", fsdp)
    if "router/w" in pstr:
        return (None, None)
    if "lora_a" in pstr:
        return (fsdp, None)          # (2d, r) under a stacked groups dim
    if "lora_b" in pstr:
        return (None, None)
    if "conv_w" in pstr:
        return (None, "model")
    # attention / mla / mlp / mamba two-dim weights
    if any(s in pstr for s in ("wq/w", "wk/w", "wv/w", "wi/w", "wg/w",
                               "wuq/w", "wuk/w", "wuv/w", "wdkv/w",
                               "wdq/w", "in_proj/w")):
        return (fsdp, "model")
    if "wkr/w" in pstr:
        return (fsdp, None)
    if any(s in pstr for s in ("wo/w", "out_proj/w")):
        return (fsdp, "model") if ag else ("model", fsdp)
    return None                       # replicate (norms, scalars, biases)


def _spec_for(pstr: str, ndim: int, cfg: ArchConfig) -> Tuple:
    base = _base_rule(pstr, cfg)
    if base is None or ndim < len(base):
        return (None,) * ndim
    return (None,) * (ndim - len(base)) + tuple(base)


def param_pspecs(cfg: ArchConfig, params_shapes, mesh: Mesh):
    """PartitionSpec tree matching the params pytree."""

    def one(path, leaf):
        return _fits(leaf.shape, _spec_for(_path_str(path), leaf.ndim, cfg),
                     mesh)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_pspecs(cfg: ArchConfig, opt_shapes, mesh: Mesh):
    """Specs for the optimizer state (mirrors params with m/v wrappers)."""

    def one(path, leaf):
        pstr = _path_str(path)
        if pstr.endswith("step"):
            return P()
        # strip the m/v prefix and the codec suffix
        suffix = pstr.rsplit("/", 1)[-1]
        core = pstr.split("/", 1)[1] if "/" in pstr else pstr
        nd = leaf.ndim
        if suffix == "s":      # int8 scale: param spec minus last axis
            spec = _spec_for(core.rsplit("/", 1)[0], nd, cfg)
            spec = spec[:-1] + (None,)
        elif suffix == "r":    # factored row stat: param ndim = nd+1
            spec = _spec_for(core.rsplit("/", 1)[0], nd + 1, cfg)[:-1]
        elif suffix == "c":    # factored col stat
            full = _spec_for(core.rsplit("/", 1)[0], nd + 1, cfg)
            spec = full[:-2] + full[-1:]
        elif suffix == "q":
            spec = _spec_for(core.rsplit("/", 1)[0], nd, cfg)
        else:
            spec = _spec_for(core, nd, cfg)
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def batch_pspecs(cfg: ArchConfig, batch_shapes, mesh: Mesh):
    """Inputs: dim0 = batch, sharded over ('pod','data') when divisible."""
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(path, leaf):
        spec = (baxes,) + (None,) * (leaf.ndim - 1)
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def cache_pspecs(cfg: ArchConfig, cache_shapes, mesh: Mesh):
    """Decode caches: batch over DP axes; heads (or head_dim / latent /
    state channels) over 'model'."""
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(path, leaf):
        pstr = _path_str(path)
        nd = leaf.ndim
        if pstr.endswith("pos"):
            return _fits(leaf.shape, (None, baxes, None)[:nd], mesh)
        if "/k" in pstr or "/v" in pstr or pstr.endswith("k") or pstr.endswith("v"):
            # (L, B, T, H, hd): heads if divisible else head_dim
            spec = [None] * nd
            spec[1] = baxes
            h_ax = nd - 2
            if leaf.shape[h_ax] % _axis_size(mesh, "model") == 0:
                spec[h_ax] = "model"
            else:
                spec[nd - 1] = "model"
            return _fits(leaf.shape, tuple(spec), mesh)
        if "ckv" in pstr:
            return _fits(leaf.shape, (None, baxes, None, "model"), mesh)
        if "kr" in pstr:
            return _fits(leaf.shape, (None, baxes, None, None), mesh)
        if "conv" in pstr:
            return _fits(leaf.shape, (None, baxes, None, "model"), mesh)
        if "ssm" in pstr:
            return _fits(leaf.shape, (None, baxes, "model", None, None), mesh)
        spec = (None, baxes) + (None,) * (nd - 2)
        return _fits(leaf.shape, spec[:nd], mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# ame_pim: mapping model-parallel layouts onto PIM cluster stacks
# ---------------------------------------------------------------------------


def ame_pim_layer_stacks(n: int, stacks: int) -> List[int]:
    """Stack id for each of ``n`` layers (or experts): contiguous
    near-equal blocks, earlier stacks taking the remainder.

    Contiguity is deliberate — adjacent decode layers hand their hidden
    state to each other, so keeping neighbors on one stack minimizes the
    host-link crossings the cluster ledger charges; near-equal blocks
    keep per-stack weight capacity balanced.
    """
    if stacks < 1:
        raise ValueError(f"need at least one stack, got {stacks}")
    if n <= 0:
        return []
    q, r = divmod(n, stacks)
    out: List[int] = []
    for s in range(stacks):
        out.extend([s] * (q + (1 if s < r else 0)))
    return out


def ame_pim_stack_map(cfg: ArchConfig, stacks: int) -> Dict[str, List[int]]:
    """The ``ame_pim`` layout of one arch on a ``stacks``-stack cluster.

    ``layers`` maps each decoder layer to its home stack (contiguous
    blocks) — what ``DecodeOffload(stacks=N)`` consumes, every weight
    instance homed with its layer.  ``experts`` (MoE only) maps the
    *full* expert bank round-robin over stacks for mesh-level placement,
    where capacity (all experts resident), not per-step routing, is
    what's being spread.
    """
    out = {"layers": ame_pim_layer_stacks(cfg.n_layers, stacks)}
    if cfg.moe is not None:
        out["experts"] = [e % stacks for e in range(cfg.moe.num_experts)]
    return out
