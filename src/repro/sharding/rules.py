"""PartitionSpec rules: parameter/optimizer/batch/cache shardings per arch.

The tensor-parallel dataflows (DESIGN.md §3):

* ``allreduce`` (Megatron): up-projections column-sharded on 'model',
  down-projections row-sharded => partial sums all-reduced.
* ``allgather`` (the paper's reduction-free outer-product dataflow): every
  weight sharded on its *output* dim; inputs are all-gathered just-in-time
  and partial sums never cross the 'model' axis.
* ``ame_pim`` — the device-runtime flavor: mesh-level specs are the
  ``allgather`` output-dim sharding (the PIM dataflow is reduction-free
  and output-stationary, so partial sums never cross 'model' there
  either), plus a *stack* assignment for the PIM cluster: model-parallel
  layouts map layers (and experts) onto :class:`~repro.runtime.cluster.
  PIMCluster` stacks as contiguous blocks — :func:`ame_pim_layer_stacks`
  / :func:`ame_pim_stack_map`, consumed by ``repro.serve.offload.
  DecodeOffload(stacks=...)``.

FSDP ('data'-axis parameter + optimizer-state sharding) stacks on top for
the large archs (policy.fsdp).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, OUTPUT_SHARDED_TP_MODES


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _fits(shape, spec, mesh: Mesh):
    """Drop axes that don't divide the dim (e.g. 8 KV heads on model=16)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is not None and (i >= len(shape)
                               or shape[i] % _axis_size(mesh, ax) != 0):
            ax = None
        out.append(ax)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path)


def _base_rule(pstr: str, cfg: ArchConfig) -> Tuple:
    """Logical spec for the *unstacked* parameter (innermost dims)."""
    fsdp = "data" if cfg.policy.fsdp else None
    ag = cfg.policy.tp_mode in OUTPUT_SHARDED_TP_MODES
    ep = cfg.moe is not None and cfg.moe.sharding == "ep"

    if "embed/table" in pstr:
        return ("model", fsdp)
    if "head/w" in pstr or "mtp_proj/w" in pstr:
        return (fsdp, "model")
    if "experts/wi" in pstr or "experts/wg" in pstr:
        return ("model", fsdp, None) if ep else (None, fsdp, "model")
    if "experts/wo" in pstr:
        if ep:
            return ("model", None, fsdp)
        return (None, fsdp, "model") if ag else (None, "model", fsdp)
    if "router/w" in pstr:
        return (None, None)
    if "lora_a" in pstr:
        return (fsdp, None)          # (2d, r) under a stacked groups dim
    if "lora_b" in pstr:
        return (None, None)
    if "conv_w" in pstr:
        return (None, "model")
    # attention / mla / mlp / mamba two-dim weights
    if any(s in pstr for s in ("wq/w", "wk/w", "wv/w", "wi/w", "wg/w",
                               "wuq/w", "wuk/w", "wuv/w", "wdkv/w",
                               "wdq/w", "in_proj/w")):
        return (fsdp, "model")
    if "wkr/w" in pstr:
        return (fsdp, None)
    if any(s in pstr for s in ("wo/w", "out_proj/w")):
        return (fsdp, "model") if ag else ("model", fsdp)
    return None                       # replicate (norms, scalars, biases)


def _spec_for(pstr: str, ndim: int, cfg: ArchConfig) -> Tuple:
    base = _base_rule(pstr, cfg)
    if base is None or ndim < len(base):
        return (None,) * ndim
    return (None,) * (ndim - len(base)) + tuple(base)


def param_pspecs(cfg: ArchConfig, params_shapes, mesh: Mesh):
    """PartitionSpec tree matching the params pytree."""

    def one(path, leaf):
        return _fits(leaf.shape, _spec_for(_path_str(path), leaf.ndim, cfg),
                     mesh)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_pspecs(cfg: ArchConfig, opt_shapes, mesh: Mesh):
    """Specs for the optimizer state (mirrors params with m/v wrappers)."""

    def one(path, leaf):
        pstr = _path_str(path)
        if pstr.endswith("step"):
            return P()
        # strip the m/v prefix and the codec suffix
        suffix = pstr.rsplit("/", 1)[-1]
        core = pstr.split("/", 1)[1] if "/" in pstr else pstr
        nd = leaf.ndim
        if suffix == "s":      # int8 scale: param spec minus last axis
            spec = _spec_for(core.rsplit("/", 1)[0], nd, cfg)
            spec = spec[:-1] + (None,)
        elif suffix == "r":    # factored row stat: param ndim = nd+1
            spec = _spec_for(core.rsplit("/", 1)[0], nd + 1, cfg)[:-1]
        elif suffix == "c":    # factored col stat
            full = _spec_for(core.rsplit("/", 1)[0], nd + 1, cfg)
            spec = full[:-2] + full[-1:]
        elif suffix == "q":
            spec = _spec_for(core.rsplit("/", 1)[0], nd, cfg)
        else:
            spec = _spec_for(core, nd, cfg)
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def batch_pspecs(cfg: ArchConfig, batch_shapes, mesh: Mesh):
    """Inputs: dim0 = batch, sharded over ('pod','data') when divisible."""
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(path, leaf):
        spec = (baxes,) + (None,) * (leaf.ndim - 1)
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def cache_pspecs(cfg: ArchConfig, cache_shapes, mesh: Mesh):
    """Decode caches: batch over DP axes; heads (or head_dim / latent /
    state channels) over 'model'."""
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(path, leaf):
        pstr = _path_str(path)
        nd = leaf.ndim
        if pstr.endswith("pos"):
            return _fits(leaf.shape, (None, baxes, None)[:nd], mesh)
        if "/k" in pstr or "/v" in pstr or pstr.endswith("k") or pstr.endswith("v"):
            # (L, B, T, H, hd): heads if divisible else head_dim
            spec = [None] * nd
            spec[1] = baxes
            h_ax = nd - 2
            if leaf.shape[h_ax] % _axis_size(mesh, "model") == 0:
                spec[h_ax] = "model"
            else:
                spec[nd - 1] = "model"
            return _fits(leaf.shape, tuple(spec), mesh)
        if "ckv" in pstr:
            return _fits(leaf.shape, (None, baxes, None, "model"), mesh)
        if "kr" in pstr:
            return _fits(leaf.shape, (None, baxes, None, None), mesh)
        if "conv" in pstr:
            return _fits(leaf.shape, (None, baxes, None, "model"), mesh)
        if "ssm" in pstr:
            return _fits(leaf.shape, (None, baxes, "model", None, None), mesh)
        spec = (None, baxes) + (None,) * (nd - 2)
        return _fits(leaf.shape, spec[:nd], mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# ame_pim: mapping model-parallel layouts onto PIM cluster stacks
# ---------------------------------------------------------------------------


def ame_pim_layer_stacks(n: int, stacks: int) -> List[int]:
    """Stack id for each of ``n`` layers (or experts): contiguous
    near-equal blocks, earlier stacks taking the remainder.

    Contiguity is deliberate — adjacent decode layers hand their hidden
    state to each other, so keeping neighbors on one stack minimizes the
    host-link crossings the cluster ledger charges; near-equal blocks
    keep per-stack weight capacity balanced.
    """
    if stacks < 1:
        raise ValueError(f"need at least one stack, got {stacks}")
    if n <= 0:
        return []
    q, r = divmod(n, stacks)
    out: List[int] = []
    for s in range(stacks):
        out.extend([s] * (q + (1 if s < r else 0)))
    return out


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    """A routed-traffic-aware expert -> stack assignment.

    ``homes[moe_layer][expert]`` is that expert's home stacks, primary
    first — more than one entry means the expert is *replicated* (its
    routed GEMVs pick a copy per step by least-loaded home).
    ``layer_loads[moe_layer][stack]`` is the expected token mass the
    profile predicts for each stack, with a replicated expert's mass
    split evenly over its copies — the planning-time balance estimate
    the observed ``moe.tokens_stack*`` gauges are checked against.
    """

    stacks: int
    policy: str
    replicate: int
    homes: Tuple[Tuple[Tuple[int, ...], ...], ...]
    layer_loads: Tuple[Tuple[float, ...], ...]

    @staticmethod
    def _max_over_mean(loads) -> float:
        total = sum(loads)
        if total <= 0:
            return 1.0
        return max(loads) / (total / len(loads))

    @property
    def max_over_mean(self) -> float:
        """Aggregate (all layers) expected max/mean stack token load."""
        agg = [sum(layer[s] for layer in self.layer_loads)
               for s in range(self.stacks)]
        return self._max_over_mean(agg)

    @property
    def worst_layer_max_over_mean(self) -> float:
        """Worst single layer's expected max/mean stack token load —
        the figure that bounds the per-layer expert-parallel makespan."""
        if not self.layer_loads:
            return 1.0
        return max(self._max_over_mean(layer) for layer in self.layer_loads)


def ame_pim_expert_placement(profile, stacks: int, *, replicate: int = 0,
                             policy: str = "greedy") -> ExpertPlacement:
    """Place one :class:`~repro.serve.traffic.RoutingProfile`'s expert
    bank onto ``stacks`` stacks, layer by layer.

    ``policy="greedy"`` is the skew-driven token balancer: per MoE
    layer, experts are assigned heaviest-first to the currently
    least-loaded stack (longest-processing-time bin packing), and the
    top ``replicate`` experts by mass get extra copies on stacks not
    already hosting them — copy counts scale with mass
    (``ceil(2 * share * stacks)``, clamped to [2, stacks]), so a
    Zipf-hot expert lands on enough stacks that its routed traffic can
    level the load; each copy is placed as an independent
    ``mass/copies`` unit.  ``policy="roundrobin"`` reproduces the
    traffic-blind legacy map (``expert % stacks``, replicas on the
    following stacks) as the comparison baseline.
    """
    if stacks < 1:
        raise ValueError(f"need at least one stack, got {stacks}")
    if policy not in ("greedy", "roundrobin"):
        raise ValueError(f"unknown placement policy {policy!r}")
    replicate = max(0, min(int(replicate), profile.n_experts))
    homes: List[Tuple[Tuple[int, ...], ...]] = []
    layer_loads: List[Tuple[float, ...]] = []
    for layer in range(profile.n_layers):
        row = profile.counts[layer]
        # an empty layer routes uniformly — place it that way too
        masses = [float(c) for c in row] if sum(row) > 0 \
            else [1.0] * profile.n_experts
        by_mass = sorted(range(profile.n_experts),
                         key=lambda e: (-masses[e], e))
        total_mass = sum(masses)
        replicated = set(by_mass[:replicate]) if stacks > 1 else set()
        copies = {
            e: (max(2, min(stacks,
                           math.ceil(2 * masses[e] / total_mass * stacks)))
                if e in replicated else 1)
            for e in range(profile.n_experts)}
        load = [0.0] * stacks
        layer_homes: List[List[int]] = [[] for _ in range(profile.n_experts)]
        if policy == "roundrobin":
            for e in range(profile.n_experts):
                layer_homes[e] = [(e + j) % stacks
                                  for j in range(copies[e])]
                for s in layer_homes[e]:
                    load[s] += masses[e] / copies[e]
        else:
            # every copy is an independent unit of mass/copies; place
            # units heaviest-first onto the least-loaded stack that does
            # not already host a copy of the same expert
            units = sorted(
                ((masses[e] / copies[e], e, j)
                 for e in range(profile.n_experts)
                 for j in range(copies[e])),
                key=lambda u: (-u[0], u[1], u[2]))
            for mass, e, _ in units:
                avail = [s for s in range(stacks)
                         if s not in layer_homes[e]] or list(range(stacks))
                s = min(avail, key=lambda i: (load[i], i))
                layer_homes[e].append(s)
                load[s] += mass
        homes.append(tuple(tuple(h) for h in layer_homes))
        layer_loads.append(tuple(load))
    return ExpertPlacement(stacks=stacks, policy=policy, replicate=replicate,
                           homes=tuple(homes),
                           layer_loads=tuple(layer_loads))


def ame_pim_stack_map(cfg: ArchConfig, stacks: int, *, profile=None,
                      replicate: int = 0) -> Dict[str, Any]:
    """The ``ame_pim`` layout of one arch on a ``stacks``-stack cluster.

    ``layers`` maps each decoder layer to its home stack (contiguous
    blocks) — what ``DecodeOffload(stacks=N)`` consumes, every weight
    instance homed with its layer.  ``experts`` (MoE only) maps the
    *full* expert bank over stacks for mesh-level placement: round-robin
    by default (capacity-balanced), or — when a
    :class:`~repro.serve.traffic.RoutingProfile` is supplied — the
    greedy token balancer's aggregate-mass assignment, with the full
    per-layer :class:`ExpertPlacement` (incl. ``replicate`` hot-expert
    copies) under ``expert_placement``.
    """
    out: Dict[str, Any] = {"layers": ame_pim_layer_stacks(cfg.n_layers,
                                                          stacks)}
    if cfg.moe is not None:
        if profile is None:
            out["experts"] = [e % stacks
                              for e in range(cfg.moe.num_experts)]
        else:
            pl = ame_pim_expert_placement(profile, stacks,
                                          replicate=replicate)
            # flat capacity view: aggregate-mass greedy, primaries only
            mass = profile.expert_mass()
            order = sorted(range(profile.n_experts),
                           key=lambda e: (-mass[e], e))
            load = [0.0] * stacks
            flat = [0] * profile.n_experts
            for e in order:
                s = min(range(stacks), key=lambda i: (load[i], i))
                flat[e] = s
                load[s] += float(mass[e])
            out["experts"] = flat
            out["expert_placement"] = pl
    return out
