"""Logical-axis sharding context.

Model code annotates tensors with *logical* axes ('batch', 'model', 'expert',
None); the active mesh (set by the launcher) decides what they resolve to:

  'batch'  -> ('pod', 'data') on the multi-pod mesh, ('data',) single-pod
  'model'  -> 'model'   (TP/EP axis)
  'fsdp'   -> 'data'    (parameter/optimizer-state sharding axis)

With no mesh set (CPU smoke tests) every constraint is a no-op, so the same
model code runs anywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def resolve_axis(logical, mesh: Mesh):
    """Map a logical axis name to mesh axis name(s)."""
    names = mesh.axis_names
    if logical is None:
        return None
    if logical == "batch":
        return ("pod", "data") if "pod" in names else "data"
    if logical == "batch_heads":
        # a flattened (batch*heads) dim: batch-major -> DP axes, heads ->
        # 'model'; the merged dim shards over all of them
        base = ("pod", "data") if "pod" in names else ("data",)
        return base + ("model",) if "model" in names else base
    if logical == "fsdp":
        return "data"
    if logical in names:
        return logical
    return None


def spec(*logical) -> P:
    """Resolve logical axes against the current mesh into a PartitionSpec."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*(resolve_axis(a, mesh) for a in logical))


def constrain(x, *logical):
    """with_sharding_constraint by logical axes; no-op without a mesh.

    Axes whose size does not divide the mesh axis are dropped (replicated)
    — e.g. 8 KV heads on a 16-way model axis.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = []
    for dim, a in enumerate(logical):
        r = resolve_axis(a, mesh)
        if r is not None:
            ax_size = 1
            for n in (r if isinstance(r, tuple) else (r,)):
                ax_size *= mesh.shape[n]
            if x.shape[dim] % ax_size != 0:
                r = None
        resolved.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def named_sharding(*logical) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*logical))
