"""Fault scenarios: what fails, when, and how the failure behaves.

Real PIM silicon fails in ways the clean runtime model ignores: the PrIM
characterization reports DPU/rank-level faults on production UPMEM parts,
and the PIM-adoption literature names reliability as a first-class
integration barrier.  This module is the *scenario* half of the fault
subsystem — plain frozen dataclasses describing failures, plus a small
text DSL for writing them down — with zero runtime behavior of its own.
The *mechanism* half (firing events against a live runtime, recovery,
accounting) is :mod:`repro.faults.injector`.

A :class:`FaultPlan` bundles:

* :class:`ChannelFault` — fail-stop of one pseudo-channel (cluster-flat
  id) at a cycle: the channel is excluded from every later placement
  decomposition, its resident shards are lost (re-upload charged at next
  use), and pinned undrained outputs are replayed onto a survivor.
* :class:`StackFault` — fail-stop of a whole stack (all its channels).
* :class:`LinkTransient` — transient host-link transfer corruption: each
  link charge retransmits with probability ``prob`` per attempt, charged
  as retry-with-exponential-backoff busy windows on the link ledger.
  Draws come from the plan's seeded generator, so runs are reproducible.
* :class:`LinkDegradation` — a bandwidth-degradation window: link
  charges inside ``[start_cycle, end_cycle)`` cost ``factor`` x their
  cycles (extra occupancy charged as ``degrade`` ledger events).
* :class:`ServeFault` — a serving-layer fault: the request decoding in
  ``slot`` at serving iteration ``at_iter`` is knocked out and requeued
  with backoff (or failed after ``Server.max_retries``).

An **empty plan is strictly additive**: attaching ``FaultPlan()`` to a
runtime leaves ledgers ``==``-equal and traces byte-identical to a run
with no faults attached at all — the same discipline as the
observability layer (see docs/robustness.md).

The scenario DSL (one statement per line or ``;``-separated)::

    kill channel 3 @ 1000        # fail-stop flat channel 3 at cycle 1000
    kill stack 1 @ 5e6           # fail-stop all of stack 1
    flaky link p=0.01 backoff=64 retries=8 cap=4096
    slow link x2 @ 1000:5000     # link charges cost 2x in the window
    fail slot 0 @ iter 3         # knock out the request in serve slot 0

Parse with :meth:`FaultPlan.parse`; ``PIMRuntime(faults=...)`` and
``Server(faults=...)`` accept either a plan or a DSL string.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ChannelFault:
    """Fail-stop of one pseudo-channel (cluster-flat id) at a cycle."""

    at_cycle: float
    channel: int

    def __post_init__(self):
        if self.at_cycle < 0:
            raise ValueError(f"at_cycle must be >= 0, got {self.at_cycle}")
        if self.channel < 0:
            raise ValueError(f"channel must be >= 0, got {self.channel}")


@dataclasses.dataclass(frozen=True)
class StackFault:
    """Fail-stop of a whole stack (all its pseudo-channels) at a cycle."""

    at_cycle: float
    stack: int

    def __post_init__(self):
        if self.at_cycle < 0:
            raise ValueError(f"at_cycle must be >= 0, got {self.at_cycle}")
        if self.stack < 0:
            raise ValueError(f"stack must be >= 0, got {self.stack}")


@dataclasses.dataclass(frozen=True)
class LinkTransient:
    """Transient host-link corruption: retransmit with probability
    ``prob`` per attempt, exponential backoff between attempts.

    Each retransmit re-charges the transfer's bytes and cycles on the
    link plus a backoff pause that doubles per attempt, capped at
    ``backoff_cap_cycles``; after ``max_retries`` the transfer is
    assumed through (fail-stop link loss is a :class:`StackFault`'s
    job, not this one's).
    """

    prob: float
    backoff_cycles: int = 64
    max_retries: int = 8
    backoff_cap_cycles: int = 4096

    def __post_init__(self):
        if not 0.0 <= self.prob < 1.0:
            raise ValueError(f"prob must be in [0, 1), got {self.prob}")
        if self.backoff_cycles < 0 or self.backoff_cap_cycles < 0:
            raise ValueError("backoff cycles must be >= 0")
        if self.max_retries < 1:
            raise ValueError("max_retries must be >= 1")


@dataclasses.dataclass(frozen=True)
class LinkDegradation:
    """Host-link bandwidth degradation window: charges landing inside
    ``[start_cycle, end_cycle)`` occupy ``factor`` x their cycles."""

    start_cycle: float
    end_cycle: float
    factor: float

    def __post_init__(self):
        if not 0 <= self.start_cycle < self.end_cycle:
            raise ValueError(
                f"need 0 <= start < end, got "
                f"[{self.start_cycle}, {self.end_cycle})")
        if self.factor < 1.0:
            raise ValueError(
                f"factor must be >= 1 (a slowdown), got {self.factor}")


@dataclasses.dataclass(frozen=True)
class ServeFault:
    """Knock out the request decoding in serve slot ``slot`` at serving
    iteration ``at_iter`` (1-based; the server requeues with backoff)."""

    at_iter: int
    slot: int

    def __post_init__(self):
        if self.at_iter < 1:
            raise ValueError(f"at_iter is 1-based, got {self.at_iter}")
        if self.slot < 0:
            raise ValueError(f"slot must be >= 0, got {self.slot}")


# -- the DSL ----------------------------------------------------------------

_KILL_CH_RE = re.compile(
    r"^kill\s+(?:channel|ch)\s+(\d+)\s*@\s*([0-9.eE+]+)$")
_KILL_STACK_RE = re.compile(
    r"^kill\s+stack\s+(\d+)\s*@\s*([0-9.eE+]+)$")
_FLAKY_RE = re.compile(
    r"^flaky\s+link\s+p=([0-9.eE+-]+)"
    r"(?:\s+backoff=(\d+))?(?:\s+retries=(\d+))?(?:\s+cap=(\d+))?$")
_SLOW_RE = re.compile(
    r"^slow\s+link\s+x([0-9.]+)\s*@\s*([0-9.eE+]+)\s*:\s*([0-9.eE+]+)$")
_SERVE_RE = re.compile(
    r"^fail\s+slot\s+(\d+)\s*@\s*iter\s+(\d+)$")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded fault scenario.

    Attach to :class:`~repro.runtime.scheduler.PIMRuntime` via
    ``faults=`` (channel/stack/link faults) and to
    :class:`~repro.serve.loop.Server` via ``faults=`` (serve faults).
    ``seed`` drives every random draw (link transients), so the same
    plan replays identically — ledger-equal across runs, the CI
    determinism gate.
    """

    seed: int = 0
    channel_faults: Tuple[ChannelFault, ...] = ()
    stack_faults: Tuple[StackFault, ...] = ()
    link_transient: Optional[LinkTransient] = None
    link_degradations: Tuple[LinkDegradation, ...] = ()
    serve_faults: Tuple[ServeFault, ...] = ()

    def __post_init__(self):
        # tolerate lists; frozen dataclass needs object.__setattr__
        object.__setattr__(self, "channel_faults",
                           tuple(self.channel_faults))
        object.__setattr__(self, "stack_faults", tuple(self.stack_faults))
        object.__setattr__(self, "link_degradations",
                           tuple(self.link_degradations))
        object.__setattr__(self, "serve_faults", tuple(self.serve_faults))

    @property
    def empty(self) -> bool:
        """True when the plan injects nothing (the strictly-additive
        attach: ledgers ==-equal, traces byte-identical)."""
        return not (self.channel_faults or self.stack_faults
                    or self.link_transient or self.link_degradations
                    or self.serve_faults)

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse the scenario DSL (module docstring) into a plan."""
        ch, st, deg, srv = [], [], [], []
        transient = None
        for raw in re.split(r"[;\n]", text):
            stmt = raw.split("#", 1)[0].strip()
            if not stmt:
                continue
            m = _KILL_CH_RE.match(stmt)
            if m:
                ch.append(ChannelFault(at_cycle=float(m.group(2)),
                                       channel=int(m.group(1))))
                continue
            m = _KILL_STACK_RE.match(stmt)
            if m:
                st.append(StackFault(at_cycle=float(m.group(2)),
                                     stack=int(m.group(1))))
                continue
            m = _FLAKY_RE.match(stmt)
            if m:
                if transient is not None:
                    raise ValueError(
                        f"duplicate 'flaky link' statement: {stmt!r}")
                kw = {}
                if m.group(2):
                    kw["backoff_cycles"] = int(m.group(2))
                if m.group(3):
                    kw["max_retries"] = int(m.group(3))
                if m.group(4):
                    kw["backoff_cap_cycles"] = int(m.group(4))
                transient = LinkTransient(prob=float(m.group(1)), **kw)
                continue
            m = _SLOW_RE.match(stmt)
            if m:
                deg.append(LinkDegradation(start_cycle=float(m.group(2)),
                                           end_cycle=float(m.group(3)),
                                           factor=float(m.group(1))))
                continue
            m = _SERVE_RE.match(stmt)
            if m:
                srv.append(ServeFault(at_iter=int(m.group(2)),
                                      slot=int(m.group(1))))
                continue
            raise ValueError(f"unparseable fault statement: {stmt!r}")
        return cls(seed=seed, channel_faults=tuple(ch),
                   stack_faults=tuple(st), link_transient=transient,
                   link_degradations=tuple(deg), serve_faults=tuple(srv))


def as_plan(faults) -> FaultPlan:
    """Coerce a ``faults=`` argument (plan or DSL string) to a plan."""
    if isinstance(faults, FaultPlan):
        return faults
    if isinstance(faults, str):
        return FaultPlan.parse(faults)
    raise TypeError(
        f"faults= expects a FaultPlan or a scenario-DSL string, "
        f"got {type(faults).__name__}")
