"""Fault injection mechanism: fire a plan's events against a runtime.

One :class:`FaultInjector` is built per ``PIMRuntime(faults=plan)`` and
threads the plan through every layer the scheduler touches:

* **clock** — serialized runtimes advance a private fault clock by each
  op's ``cluster_makespan_cycles``; async runtimes read the timeline
  frontier.  Events fire when the clock passes their ``at_cycle``,
  polled at every op boundary (fail-stop is modeled at op granularity:
  an op already dispatched completes, the next one sees the failure —
  the retry unit real serving systems use).
* **placement** — :meth:`healthy` maps an op's requested device set
  (``stack=`` / ``channels=`` / whole runtime) to its surviving subset,
  reusing the scheduler's ``channels=`` flat-subset decompositions.
  When nothing in the requested set has failed the request is returned
  *unchanged*, so fault-free ops take byte-identical code paths.
* **residency** — a failed channel's resident shards are lost: their
  uids are flagged and the natural re-ship at next miss is additionally
  charged on the host-link ledger as ``reupload`` traffic (cluster
  runtimes) and marked with a replay-neutral ``# RECOVER`` trace event.
  Pinned undrained outputs (the only copy of a result until
  ``to_host``) are *replayed* onto a survivor channel: the producer's
  recorded busy cycles are re-charged there from the last host copy and
  the pending drain re-homed, so ``DeviceTensor.to_host`` still
  delivers the result.
* **link** — the :class:`~repro.runtime.cluster.HostLinkLedger` calls
  :meth:`on_link_charge` after each charge; transient retransmits and
  degradation-window slowdowns append ``retry`` / ``degrade`` ledger
  events (never recursing through ``charge``).

Everything the injector does is observable: ``faults.*`` counters in an
attached metrics registry, Chrome-trace instant events (``instants``),
``# FAULT`` / ``# RECOVER`` trace markers, and
``RuntimeReport.failed_channels``.
"""
from __future__ import annotations

import weakref
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.faults.plan import FaultPlan
from repro.runtime.cluster import host_link_cycles
from repro.runtime.residency import DeviceTensor, box_bytes


class FaultError(RuntimeError):
    """Base class for unrecoverable fault-injection conditions."""


class NoHealthyChannelsError(FaultError):
    """An op's entire requested device set has failed — nothing left to
    decompose over.  Recovery above this layer (e.g. stack failover in
    the decode offload) must re-place the work before retrying."""


class FaultInjector:
    """Runtime-side mechanism for one :class:`FaultPlan`.

    Built by ``PIMRuntime(faults=...)``; the scheduler calls
    :meth:`on_op` at every op boundary (poll + healthy-subset remap),
    :meth:`advance` after each serialized op, :meth:`on_reship` on
    handle misses, and registers kept outputs via :meth:`register` /
    :meth:`note_output`.  All random draws come from one
    ``default_rng(plan.seed)``, so a plan replays identically.
    """

    def __init__(self, plan: FaultPlan, runtime):
        self.plan = plan
        self.rt = runtime
        total = len(runtime.stack)
        cluster = runtime._cluster
        n_stacks = cluster.n_stacks if cluster is not None else 1
        cps = cluster.channels_per_stack if cluster is not None else total
        events: List[Tuple[float, int, str, int]] = []
        for i, f in enumerate(plan.channel_faults):
            if not 0 <= f.channel < total:
                raise ValueError(
                    f"ChannelFault channel {f.channel} out of range for "
                    f"{total} flat channels")
            events.append((f.at_cycle, i, "channel", f.channel))
        for i, f in enumerate(plan.stack_faults):
            if not 0 <= f.stack < n_stacks:
                raise ValueError(
                    f"StackFault stack {f.stack} out of range for "
                    f"{n_stacks} stacks")
            events.append((f.at_cycle, len(plan.channel_faults) + i,
                           "stack", f.stack))
        #: due events in (cycle, declaration) order — the tiebreak index
        #: keeps simultaneous faults deterministic
        self._pending = sorted(events)
        self._cps = cps
        self.rng = np.random.default_rng(plan.seed)
        self.failed: Set[int] = set()
        #: uids whose resident shards were lost to a channel failure —
        #: their next miss's re-ship is recovery traffic
        self.lost_uids: Set[int] = set()
        self._reshipped: Set[int] = set()
        #: uid -> weakref(DeviceTensor) for pinned-output replay
        self._tensors: Dict[int, "weakref.ref"] = {}
        #: (uid, channel) -> producer busy cycles (the replay charge)
        self._output_busy: Dict[Tuple[int, int], float] = {}
        self._serial_clock = 0.0
        #: Chrome-trace instant events: (kind, cycle, flat channel or -1
        #: for the host link, label)
        self.instants: List[Tuple[str, float, int, str]] = []
        #: plain mirror of the faults.* counters (works without a
        #: metrics registry attached)
        self.counters: Dict[str, float] = {}

    # -- clock ---------------------------------------------------------------

    @property
    def now(self) -> float:
        """The fault clock: timeline frontier (async) or the accumulated
        serialized makespan."""
        tl = self.rt.timeline
        return tl.now if tl is not None else self._serial_clock

    def advance(self, cycles: float) -> None:
        """Advance the serialized fault clock by one op's makespan."""
        self._serial_clock += cycles

    # -- counters / observability --------------------------------------------

    def count(self, name: str, value: float) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value
        m = self.rt.metrics
        if m is not None:
            m.counter(f"faults.{name}",
                      help="fault-injection counter (repro.faults)"
                      ).inc(value)

    # -- event firing --------------------------------------------------------

    def poll(self) -> None:
        """Fire every pending fault whose cycle has passed."""
        while self._pending and self._pending[0][0] <= self.now:
            at, _, kind, target = self._pending.pop(0)
            if kind == "channel":
                self._fail_channel(target, at)
            else:
                for c in range(target * self._cps,
                               (target + 1) * self._cps):
                    self._fail_channel(c, at)
                self.count("stack_failures", 1)

    def is_failed(self, channel: int) -> bool:
        return channel in self.failed

    def _fail_channel(self, ch: int, at: float) -> None:
        if ch in self.failed:
            return
        dev = self.rt.stack[ch]
        self.failed.add(ch)
        dev.failed = True
        dev.events.append(("fault", float(at)))
        self.instants.append(
            ("fault", float(at), ch, f"channel {ch} fail-stop"))
        self.count("channel_failures", 1)
        lost = sum(dev.resident_bytes_of(u) for u in dev.resident)
        if lost:
            self.count("lost_resident_bytes", lost)
        # pinned undrained outputs first: the only copy of those results
        # lives on-channel, so they replay onto a survivor before the
        # residency table is wiped
        for uid in [u for u in list(dev.resident) if u in dev.pinned]:
            self._replay_output(uid, ch, at)
        for uid in list(dev.resident):
            self.lost_uids.add(uid)
            dev.drop_resident(uid)

    def _pick_survivor(self, ch: int) -> int:
        """A healthy channel to inherit ``ch``'s replayed work: same
        stack preferred (no extra link crossing), lowest flat id wins."""
        total = len(self.rt.stack)
        s0 = (ch // self._cps) * self._cps
        same = [c for c in range(s0, min(s0 + self._cps, total))
                if c not in self.failed]
        if same:
            return same[0]
        any_ = [c for c in range(total) if c not in self.failed]
        if not any_:
            raise NoHealthyChannelsError(
                "every channel has failed; nothing can inherit replayed "
                "outputs")
        return any_[0]

    def _replay_output(self, uid: int, ch: int, at: float) -> None:
        """Replay a pinned undrained output from the last host copy onto
        a survivor: re-charge the producer's busy cycles there, re-home
        the pending d2h boxes, charge cross-stack moves on the link."""
        ref = self._tensors.get(uid)
        handle: Optional[DeviceTensor] = ref() if ref is not None else None
        busy = self._output_busy.pop((uid, ch), 0.0)
        survivor = self._pick_survivor(ch)
        sdev = self.rt.stack[survivor]
        moved: List[Tuple[int, int, int, int]] = []
        if handle is not None:
            pending = []
            for c, box in handle.pending_d2h:
                if c == ch:
                    moved.append(box)
                    pending.append((survivor, box))
                else:
                    pending.append((c, box))
            handle.pending_d2h = pending
            for box in moved:
                # capacity may refuse; the pending entry still points at
                # the survivor, so the eventual to_host drains there
                sdev.add_resident(uid, box, pin=True)
        nbytes = sum(box_bytes(b) for b in moved)
        if busy > 0:
            sdev.charge_analytic(busy, 0, 0)
            tl = self.rt.timeline
            if tl is not None:
                tl.submit("replay", {survivor: busy}, 0, [])
            else:
                self._serial_clock += busy
        sdev.events.append(("recover", nbytes))
        cluster = self.rt._cluster
        if cluster is not None and \
                cluster.stack_of(survivor) != cluster.stack_of(ch):
            # switched topology attributes the migration to the
            # destination stack's private link; shared falls through to
            # the single ledger (link_for returns it unchanged)
            cluster.link_for(cluster.stack_of(survivor)).charge(
                "reupload", nbytes)
        self.count("replayed_outputs", 1)
        self.count("replayed_bytes", nbytes)
        self.count("replay_cycles", busy)
        self.instants.append(
            ("recover", self.now, survivor,
             f"replayed output uid={uid} ch{ch}->ch{survivor}"))

    # -- scheduler hooks -----------------------------------------------------

    def on_op(self, stack: Optional[int],
              channels: Optional[Sequence[int]]
              ) -> Tuple[Optional[int], Optional[Sequence[int]]]:
        """Op-boundary hook: fire due events, then map the op's requested
        device set to its healthy subset."""
        self.poll()
        return self.healthy(stack, channels)

    def healthy(self, stack: Optional[int],
                channels: Optional[Sequence[int]]
                ) -> Tuple[Optional[int], Optional[Sequence[int]]]:
        """The surviving portion of a requested (stack=, channels=) set.

        Unchanged requests are returned as-is — fault-free ops keep
        their exact decomposition (and its caches).  A request whose
        channels partially failed becomes a flat ``channels=`` subset;
        a fully-failed request raises :class:`NoHealthyChannelsError`.
        """
        if not self.failed:
            return stack, channels
        if channels is not None:
            req = sorted(channels)
        elif stack is not None:
            req = list(range(stack * self._cps, (stack + 1) * self._cps))
        else:
            req = list(range(len(self.rt.stack)))
        alive = [c for c in req if c not in self.failed]
        if not alive:
            raise NoHealthyChannelsError(
                f"all requested channels {req} have failed "
                f"(failed={sorted(self.failed)})")
        if len(alive) == len(req):
            return stack, channels
        return None, tuple(alive)

    def end_op(self) -> None:
        """Close one op: uids whose lost shards re-shipped this op leave
        the lost set (recovery traffic is charged once per loss)."""
        if self._reshipped:
            self.lost_uids.difference_update(self._reshipped)
            self._reshipped.clear()

    def on_reship(self, dev, uid: int, nbytes: int) -> None:
        """A handle miss just re-shipped ``nbytes`` of tensor ``uid``:
        if the residency was lost to a fault, account it as recovery —
        link ``reupload`` traffic on clusters, a ``# RECOVER`` trace
        event either way."""
        if uid not in self.lost_uids:
            return
        self._reshipped.add(uid)
        dev.events.append(("recover", nbytes))
        cluster = self.rt._cluster
        if cluster is not None:
            # charge the re-ship on the receiving stack's link (the
            # shared ledger when link_topology="shared")
            cluster.link_for(cluster.stack_of(dev.channel_id)).charge(
                "reupload", nbytes)
        self.count("reupload_bytes", nbytes)
        self.instants.append(
            ("recover", self.now, dev.channel_id,
             f"re-shipped {nbytes}B of lost uid={uid}"))

    # -- residency registration (pinned-output replay inputs) ---------------

    def register(self, handle: DeviceTensor) -> None:
        self._tensors[handle.uid] = weakref.ref(handle)

    def note_output(self, uid: int, channel: int, busy: float) -> None:
        """Record the producer busy cycles behind one kept output shard
        (what a replay re-charges on the survivor)."""
        self._output_busy[(uid, channel)] = \
            max(self._output_busy.get((uid, channel), 0.0), busy)

    # -- host-link hook ------------------------------------------------------

    def on_link_charge(self, ledger, kind: str, nbytes: int,
                       cycles: int) -> None:
        """Post-charge link hook: degradation windows and transient
        retransmits append their own ledger events (``degrade`` /
        ``retry``) without recursing through ``charge``."""
        now = self.now
        for d in self.plan.link_degradations:
            if d.start_cycle <= now < d.end_cycle:
                extra = int(-(-cycles * (d.factor - 1.0) // 1))  # ceil
                if extra > 0:
                    # degrade events carry the *extra cycles* in the
                    # count slot (no new bytes move; the link is just
                    # occupied longer)
                    ledger.charge_raw("degrade", 0, extra)
                    self.count("degraded_cycles", extra)
        lt = self.plan.link_transient
        if lt is None:
            return
        retries = 0
        backoff = lt.backoff_cycles
        while retries < lt.max_retries and self.rng.random() < lt.prob:
            retries += 1
            pause = min(backoff, lt.backoff_cap_cycles)
            ledger.charge_raw("retry", nbytes,
                              host_link_cycles(nbytes) + pause)
            backoff *= 2
        if retries:
            self.count("link_retries", retries)
            self.count("retransmitted_bytes", nbytes * retries)
            self.instants.append(
                ("retry", now, -1,
                 f"link retransmit x{retries} ({nbytes}B {kind})"))

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Counter snapshot plus failure state (the RuntimeReport /
        bench-facing view)."""
        out = dict(self.counters)
        out["failed_channels"] = float(len(self.failed))
        return out
