"""Deterministic fault injection + recovery for the PIM runtime stack.

Scenario half: :mod:`repro.faults.plan` (frozen dataclasses + text DSL).
Mechanism half: :mod:`repro.faults.injector` (firing, recovery,
accounting).  Attach via ``PIMRuntime(faults=...)`` /
``Server(faults=...)`` / ``DecodeOffload(faults=...)``; see
docs/robustness.md for the model and its invariants.
"""
from repro.faults.injector import (
    FaultError,
    FaultInjector,
    NoHealthyChannelsError,
)
from repro.faults.plan import (
    ChannelFault,
    FaultPlan,
    LinkDegradation,
    LinkTransient,
    ServeFault,
    StackFault,
    as_plan,
)

__all__ = [
    "ChannelFault",
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "LinkDegradation",
    "LinkTransient",
    "NoHealthyChannelsError",
    "ServeFault",
    "StackFault",
    "as_plan",
]
