"""AdamW with large-scale memory policies, pure JAX.

Moment storage is policy-driven (configs.base.Policy):
  * moment_dtype: float32 | bfloat16 | int8   (int8 = blockwise-quantized
    8-bit Adam a la Dettmers: per-row absmax scales, error bounded by the
    row dynamic range — what lets the 671B config fit a single pod)
  * factored_v: Adafactor-style rank-1 second moment for >=2D tensors.

Also: global-norm clipping, decoupled weight decay with a mask, linear
warmup + cosine decay schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    factored_v: bool = False


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = c.peak_lr * step / max(c.warmup_steps, 1)
    prog = jnp.clip((step - c.warmup_steps)
                    / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.peak_lr * (c.end_lr_frac + (1 - c.end_lr_frac)
                       * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < c.warmup_steps, warm, cos)


# -- int8 blockwise moment codec ----------------------------------------------


def _q8_encode(x: jnp.ndarray, sqrt_domain: bool = False):
    """Per-row (last-dim) absmax int8 quantization.

    Non-negative tensors (the second moment) are stored in the sqrt domain,
    which is the quantity the update actually consumes (1/sqrt(v)) — this
    halves the dynamic range the 8 bits must cover."""
    if sqrt_domain:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _q8_decode(enc, sqrt_domain: bool = False) -> jnp.ndarray:
    x = enc["q"].astype(jnp.float32) * enc["s"]
    return jnp.square(x) if sqrt_domain else x


def _encode_moment(x: jnp.ndarray, dtype: str, sqrt_domain: bool = False):
    if dtype == "int8":
        return _q8_encode(x, sqrt_domain)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _decode_moment(enc, dtype: str, sqrt_domain: bool = False) -> jnp.ndarray:
    if dtype == "int8":
        return _q8_decode(enc, sqrt_domain)
    return enc.astype(jnp.float32)


# -- factored second moment ----------------------------------------------------


def _v_init(p: jnp.ndarray, c: AdamWConfig):
    if c.factored_v and p.ndim >= 2:
        return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
    return _encode_moment(jnp.zeros_like(p, jnp.float32), c.moment_dtype)


def _v_update(v, g2: jnp.ndarray, c: AdamWConfig):
    """Returns (new_v_store, v_hat_full)."""
    if c.factored_v and g2.ndim >= 2:
        r = c.b2 * v["r"] + (1 - c.b2) * g2.mean(-1)
        col = c.b2 * v["c"] + (1 - c.b2) * g2.mean(-2)
        denom = jnp.maximum(r.mean(-1, keepdims=True), 1e-30)
        vhat = (r / denom)[..., None] * col[..., None, :]
        return {"r": r, "c": col}, vhat
    vv = c.b2 * _decode_moment(v, c.moment_dtype, sqrt_domain=True) \
        + (1 - c.b2) * g2
    return _encode_moment(vv, c.moment_dtype, sqrt_domain=True), vv


# -- public API ----------------------------------------------------------------


def init(params, c: AdamWConfig) -> Dict[str, Any]:
    zeros_m = jax.tree.map(
        lambda p: _encode_moment(jnp.zeros_like(p, jnp.float32),
                                 c.moment_dtype), params)
    v = jax.tree.map(lambda p: _v_init(p, c), params)
    return {"m": zeros_m, "v": v, "step": jnp.zeros((), jnp.int32)}


CHUNK_BYTES = 256 * 2 ** 20    # slice dim0 of leaves above this (f32 temps)


def _is_big(x) -> bool:
    return x.ndim >= 3 and x.size * 4 > CHUNK_BYTES


def global_norm(tree) -> jnp.ndarray:
    def sumsq(x):
        if _is_big(x):   # chunk so the f32 square never materializes fully
            return jnp.sum(jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x))
        return jnp.sum(jnp.square(x.astype(jnp.float32)))
    return jnp.sqrt(sum(sumsq(x) for x in jax.tree_util.tree_leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms, biases, scalars."""
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    flat = "/".join(str(n) for n in names)
    return not any(s in flat for s in ("scale", "bias", "a_log", "d_skip",
                                       "dt_bias", "ln", "norm", "mask_emb"))


def apply(params, grads, state, c: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(c, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    is_q8 = c.moment_dtype == "int8"

    def upd(path, p, g, m, v):
        decay = bool(c.weight_decay) and _decay_mask(path)

        def body(p_, g_, m_, v_):
            g32 = g_.astype(jnp.float32) * clip
            mm = c.b1 * _decode_moment(m_, c.moment_dtype) + (1 - c.b1) * g32
            v_new, vhat = _v_update(v_, jnp.square(g32), c)
            u = (mm / b1c) / (jnp.sqrt(vhat / b2c) + c.eps)
            if decay:
                u = u + c.weight_decay * p_.astype(jnp.float32)
            newp = (p_.astype(jnp.float32) - lr * u).astype(p_.dtype)
            return newp, _encode_moment(mm, c.moment_dtype), v_new

        # layer-stacked giants (e.g. 58x256-expert weight banks) update in
        # slices along dim0 so the f32 decode/update temporaries stay small;
        # the barrier pins the converts inside the loop (otherwise XLA sinks
        # them through the dynamic-slice and materializes full f32 copies)
        if _is_big(p):
            return jax.lax.map(
                lambda a: body(*jax.lax.optimization_barrier(a)),
                (p, g, m, v))
        return body(p, g, m, v)

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    treedef = flat[1]
    pl = [x for _, x in flat[0]]
    gl = treedef.flatten_up_to(grads)
    ml = treedef.flatten_up_to(state["m"])
    vl = treedef.flatten_up_to(state["v"])
    # chain big-leaf updates through optimization barriers so the scheduler
    # cannot run several leaves' f32 temporaries concurrently (peak memory)
    out = []
    prev = None
    for pt, p, g, m, v in zip(paths, pl, gl, ml, vl):
        if prev is not None and (_is_big(p) or _is_big(prev)):
            p, g, prev = jax.lax.optimization_barrier((p, g, prev))
        res = upd(pt, p, g, m, v)
        out.append(res)
        prev = res[0]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def from_policy(policy, total_steps: int = 10_000,
                peak_lr: float = 3e-4) -> AdamWConfig:
    return AdamWConfig(peak_lr=peak_lr, total_steps=total_steps,
                       moment_dtype=policy.moment_dtype,
                       factored_v=policy.factored_v)
