"""Cross-pod gradient compression with error feedback.

At 1000+ nodes the pod-to-pod (DCN) links are the scarce resource; the
intra-pod ICI all-reduce is cheap by comparison.  The compressed sync
halves cross-pod bytes (f32 -> bf16) while error feedback keeps the
optimizer trajectory unbiased: the quantization residual of step t is
added back into step t+1's gradient before compression, so errors do not
accumulate (Karimireddy et al., "EF signSGD" analysis applies to any
deterministic compressor).

Usage inside a step (see launch/steps.make_train_step(grad_compression=..)):

    grads, ef = compress_psum(grads, ef_state, axis="pod")

which lowers to: g + ef -> bf16 -> psum over 'pod' -> f32, ef' = (g+ef) - Q.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_state(grads_shapes) -> Any:
    """Error-feedback residual buffer, one per gradient leaf (f32)."""
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.bfloat16), grads_shapes)


def compress(g: jnp.ndarray, ef: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (bf16 payload, new error-feedback residual)."""
    corrected = g.astype(jnp.float32) + ef.astype(jnp.float32)
    q = corrected.astype(jnp.bfloat16)
    new_ef = (corrected - q.astype(jnp.float32)).astype(jnp.bfloat16)
    return q, new_ef


def compress_tree(grads, ef_state):
    pairs = jax.tree.map(compress, grads, ef_state)
    q = jax.tree.map(lambda p: p[0], pairs,
                     is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda p: p[1], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    return q, ef


def psum_compressed(grads, ef_state, axis: str = "pod"):
    """Inside shard_map over ``axis``: compressed mean-reduce of grads."""
    q, ef = compress_tree(grads, ef_state)
    n = jax.lax.psum(1, axis)
    summed = jax.tree.map(
        lambda x: (jax.lax.psum(x, axis).astype(jnp.float32) / n), q)
    return summed, ef
