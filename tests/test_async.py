"""Async dependency-aware timeline scheduler invariants.

* a fully chained DAG reproduces the serialized makespan exactly;
* per-channel busy cycles are conserved under any overlap (the timeline
  places intervals, it never changes what is charged);
* async-mode ledgers are ``==``-equal to serialized-mode ledgers and
  1-stack async traces with timestamps stripped are byte-identical to
  serialized traces;
* dependencies are inferred from DeviceTensor reads/writes (place ->
  consumer, keep_output -> epilogue) and host-link windows block
  dependents;
* channel-subset ops (the concurrent-group lever) keep residency and
  leave untouched channels untouched;
* DecodeOffload async mode: overlapped steps beat serialized steps,
  numeric cross-check still passes, the multi-request pipeline conserves
  busy, and seeded activations + the content-addressed XLA reference
  cache make repeated runs reproducible.
"""
import numpy as np
import pytest

from repro.runtime import (
    PIMRuntime,
    emit_trace,
    parse_trace,
    strip_timestamps,
    subset_shards,
)

rng = np.random.default_rng(42)


def rand(*shape):
    return (rng.standard_normal(shape) * 0.1).astype(np.float16)


A = rand(256, 128)
B = rand(128, 64)
C = rand(256, 64)


# ---------------------------------------------------------------------------
# timeline invariants
# ---------------------------------------------------------------------------


def test_chained_dag_reproduces_serialized_makespan():
    rt = PIMRuntime(channels=4, async_mode=True)
    h1 = rt.gemm(A, B, placement="balanced")
    h2 = rt.gemm(A, B, placement="balanced", after=[h1])
    h3 = rt.elementwise("add", A, A, placement="balanced", after=[h2])
    total = sum(h.report.makespan_cycles for h in (h1, h2, h3))
    assert rt.timeline.now == pytest.approx(total)
    assert h2.start == pytest.approx(h1.retire)
    assert h3.start == pytest.approx(h2.retire)


def test_independent_disjoint_subsets_overlap():
    rt = PIMRuntime(channels=4, async_mode=True)
    h1 = rt.gemm(A, B, placement="balanced", channels=(0, 1))
    h2 = rt.gemm(A, B, placement="balanced", channels=(2, 3))
    assert h1.start == h2.start == 0.0
    assert rt.timeline.now == max(h1.retire, h2.retire)
    assert rt.timeline.now < h1.report.makespan_cycles \
        + h2.report.makespan_cycles


def test_per_channel_busy_conserved_under_overlap():
    ops = [(A, B), (rand(256, 128), rand(128, 64)), (A, rand(128, 64))]
    rs = PIMRuntime(channels=4)
    ra = PIMRuntime(channels=4, async_mode=True)
    sync_busy = {ch: 0.0 for ch in range(4)}
    for a, b in ops:
        _, rep = rs.gemm(a, b, placement="balanced")
        for c in rep.per_channel:
            sync_busy[c.channel] += c.busy_cycles
        ra.gemm(a, b, placement="balanced")
    for ch in range(4):
        assert ra.timeline.channel_busy(ch) == pytest.approx(sync_busy[ch])
    # unchained independent ops pack per channel: max-of-sums
    assert ra.timeline.now == pytest.approx(max(sync_busy.values()))


def test_async_ledgers_equal_serialized_ledgers():
    rs = PIMRuntime(channels=4)
    ra = PIMRuntime(channels=4, async_mode=True)
    _, rep_s = rs.gemm(A, B, placement="balanced")
    h = ra.gemm(A, B, placement="balanced")
    assert rep_s == h.report
    assert np.array_equal(np.asarray(h.result),
                          np.asarray(rs.gemm(A, B, placement="balanced")[0]))


def test_async_trace_strips_to_serialized_trace():
    rs = PIMRuntime(channels=2)
    ra = PIMRuntime(channels=2, async_mode=True)
    ws = rs.place(A, placement="balanced")
    wa = ra.place(A, placement="balanced")
    x = rand(128)
    rs.gemv(ws, x, placement="balanced")
    ha = ra.gemv(wa, x, placement="balanced")
    tr_s = emit_trace(rs.stack)
    tr_a = emit_trace(ra.stack)
    assert tr_a != tr_s                       # markers present
    assert strip_timestamps(tr_a) == tr_s     # ... and only markers
    st = parse_trace(tr_a)
    assert st.op_starts and st.op_ends
    for ch, (start, busy) in ha.spans.items():
        assert st.op_starts[(ch, ha.op_id)] == pytest.approx(start, abs=1e-3)
        assert st.op_ends[(ch, ha.op_id)] == pytest.approx(start + busy,
                                                           abs=1e-3)
    # serialized traces carry no markers at all
    assert not parse_trace(tr_s).op_starts


def test_dep_inference_place_and_keep_output():
    rt = PIMRuntime(channels=4, async_mode=True)
    w = rt.place(A, placement="row-striped")
    place_op = rt.timeline.ops[-1]
    assert place_op.name == "place"
    h1 = rt.gemm(w, B, placement="row-striped", keep_output=True)
    assert place_op.op_id in h1.deps          # read-after-place
    h2 = rt.elementwise("add", h1.result, C, placement="row-striped")
    assert h1.op_id in h2.deps                # epilogue reads kept output
    assert h2.start >= h1.retire


def test_explicit_after_edges_serialize_disjoint_ops():
    rt = PIMRuntime(channels=4, async_mode=True)
    h1 = rt.gemm(A, B, placement="balanced", channels=(0, 1))
    h2 = rt.gemm(A, B, placement="balanced", channels=(2, 3), after=[h1])
    assert h2.start >= h1.retire              # no overlap despite disjoint


def test_link_window_charged_inside_timeline():
    a = rand(256, 128)
    b = rand(128, 128)
    rt = PIMRuntime(channels=2, stacks=2, async_mode=True)
    h1 = rt.gemm(a, b, placement="2d-block")      # replicates boxes
    assert h1.report.host_link_bytes > 0
    assert h1.link_window is not None
    assert h1.retire >= h1.link_window[1]         # dependents wait for it
    h2 = rt.gemm(a, b, placement="2d-block")      # independent link user
    assert h2.link_window[0] >= h1.link_window[1]  # link serializes
    h3 = rt.gemm(a, b, placement="2d-block", after=[h2])
    assert h3.start >= h2.retire >= h2.link_window[1]


def test_subset_ops_keep_residency_and_untouched_channels():
    rt = PIMRuntime(channels=8, async_mode=True)
    sub = (1, 3, 5)
    w = rt.place(A, placement="balanced", channels=sub)
    x = rand(128)
    rt.gemv(w, x, placement="balanced", channels=sub)
    h = rt.gemv(w, x, placement="balanced", channels=sub)
    weight_bytes = A.size * 2
    assert h.report.total_reuse_bytes == weight_bytes
    for ch in range(8):
        dev = rt.stack[ch]
        if ch in sub:
            assert dev.xfer.h2d_bytes > 0
        else:
            assert dev.xfer.h2d_bytes == 0 and dev.compute_cycles == 0


def test_subset_validation():
    rt = PIMRuntime(channels=2, stacks=2)
    with pytest.raises(ValueError):
        rt.gemm(A, B, stack=0, channels=(0, 1))       # mutually exclusive
    with pytest.raises(ValueError):
        rt.gemm(A, B, channels=(3, 4))                # out of range
    with pytest.raises(ValueError):
        subset_shards("balanced", 256, 128, 1, (1, 1), 2)   # duplicate


def test_gemv_async_returns_vector_result():
    rt = PIMRuntime(channels=4, async_mode=True)
    w = rt.place(A, placement="balanced")
    x = rand(128)
    h = rt.gemv(w, x, placement="balanced")
    assert h.name == "gemv" and h.report.op == "gemv"
    ref = PIMRuntime(channels=4).gemv(A, x, placement="balanced")[0]
    assert np.array_equal(np.asarray(h.result), np.asarray(ref))


# ---------------------------------------------------------------------------
# async decode offload
# ---------------------------------------------------------------------------


def _cfg():
    from repro.configs import get
    return get("qwen3-1.7b").reduced()


def test_async_offload_step_beats_serialized():
    from repro.serve.offload import DecodeOffload

    cfg = _cfg()
    sync = DecodeOffload(cfg, channels=16, stacks=4, placement="balanced")
    asy = DecodeOffload(cfg, channels=16, stacks=4, placement="balanced",
                        async_mode=True)
    sync.step(1), asy.step(1)                 # warm past the upload tail
    rec_s, rec_a = sync.step(1), asy.step(1)
    assert rec_a.overlapped and not rec_s.overlapped
    assert rec_a.pim_cycles < rec_s.pim_cycles
    # weights stay fully amortized on the subset placements too
    assert rec_a.reuse_bytes == asy.weight_bytes
    assert rec_a.h2d_bytes == asy.steps[0].h2d_bytes   # activations only


def test_async_offload_numeric_crosschecks_xla():
    from repro.serve.offload import DecodeOffload

    off = DecodeOffload(_cfg(), channels=4, placement="balanced",
                        numeric=True, async_mode=True)
    rec = off.step(2)
    assert rec.numeric and rec.numeric_max_err < off.atol
    assert rec.logits_max_err < off.atol
    assert off.last_logits is not None
    assert off.last_logits.shape == (_cfg().vocab_padded, 2)


def test_async_offload_steps_chain_on_timeline():
    from repro.serve.offload import DecodeOffload

    off = DecodeOffload(_cfg(), channels=8, placement="balanced",
                        async_mode=True)
    r1 = off.step(1)
    tail = off._step_tail
    lm1 = tail[-1]                      # step 1's lm_head op
    r2 = off.step(1)
    # step 2's first stage waits on step 1's lm_head (sampling feedback)
    n_step_ops = sum(len(stage) for stage in off._stages)
    first = off.rt.timeline.ops[-n_step_ops]
    assert lm1.op_id in first.deps
    assert first.start >= lm1.retire
    assert r1.pim_cycles > 0 and r2.pim_cycles > 0
    assert off._step_tail != tail


def test_pipeline_conserves_busy_and_overlaps():
    from repro.serve.offload import DecodeOffload

    cfg = _cfg()

    def fresh():
        return DecodeOffload(cfg, channels=8, stacks=2,
                             placement="balanced", async_mode=True)

    p1 = fresh().pipeline(1, 2)
    p2 = fresh().pipeline(2, 2)
    assert p2["makespan_cycles"] <= 2 * p1["makespan_cycles"]
    assert p2["makespan_cycles"] >= p1["makespan_cycles"]
    assert sum(p2["per_stack_busy_cycles"]) == pytest.approx(
        2 * sum(p1["per_stack_busy_cycles"]))
    assert p2["ops"] == 2 * p1["ops"]


def test_pipeline_rejects_sync_and_numeric():
    from repro.serve.offload import DecodeOffload

    cfg = _cfg()
    with pytest.raises(ValueError):
        DecodeOffload(cfg, channels=8).pipeline(2, 1)
    with pytest.raises(ValueError):
        DecodeOffload(cfg, channels=4, numeric=True,
                      async_mode=True).pipeline(2, 1)


def test_visit_groups_follow_home_stacks():
    from repro.serve.offload import DecodeOffload

    cfg = _cfg()                               # 4 layers
    off = DecodeOffload(cfg, channels=8, stacks=4, placement="balanced",
                        async_mode=True)
    visits = off._visit_groups()
    assert len(visits) == 4                    # one layer block per stack
    cps = off.rt.stack.channels_per_stack
    for v, visit in enumerate(visits):
        for stage in visit:
            for op in stage:
                assert all(c // cps == v for c in op.channels)
    # lm_head rides the last layer's stack
    assert visits[-1][-1][0].name == "lm_head"


def test_group_split_sums_and_improves():
    from repro.serve.offload import _group_split, _probe_cycles

    shapes = ((128, 128), (64, 128), (64, 128))
    split = _group_split(shapes, 16, "balanced")
    assert sum(split) == 16 and all(c >= 1 for c in split)
    conc = max(_probe_cycles(m, k, c, "balanced")
               for (m, k), c in zip(shapes, split))
    serial = sum(_probe_cycles(m, k, 16, "balanced") for m, k in shapes)
    assert conc < serial                       # overlap actually wins


def test_group_split_keeps_a_channel_for_tiny_ops():
    """Regression: a heavily skewed group (wide-GQA q vs tiny k/v) must
    never starve the small ops to zero channels — the largest-remainder
    overshoot used to decrement exactly the clamped entries."""
    from repro.serve.offload import _group_split

    for shapes in [((2048, 128), (64, 128), (64, 128)),
                   ((4096, 64), (32, 64), (32, 64), (32, 64))]:
        split = _group_split(shapes, 16, "balanced")
        assert sum(split) == 16 and all(c >= 1 for c in split), split


def test_seeded_runs_reproduce_and_share_ref_cache():
    from repro.serve import offload as off_mod
    from repro.serve.offload import DecodeOffload

    cfg = _cfg()
    a = DecodeOffload(cfg, channels=4, placement="balanced", numeric=True,
                      seed=5)
    a.step(2)
    n_cached = len(off_mod._REF_CACHE)
    assert n_cached > 0
    b = DecodeOffload(cfg, channels=4, placement="balanced", numeric=True,
                      seed=5)
    b.step(2)
    # same seed: identical weights + activations -> identical logits and
    # no new reference entries (content-addressed cache shared)
    assert np.array_equal(a.last_logits, b.last_logits)
    assert len(off_mod._REF_CACHE) == n_cached
    c = DecodeOffload(cfg, channels=4, placement="balanced", numeric=True,
                      seed=6)
    c.step(2)
    assert not np.array_equal(a.last_logits, c.last_logits)
    assert len(off_mod._REF_CACHE) > n_cached
