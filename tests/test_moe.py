"""Routed-traffic-aware MoE expert parallelism (PR 10).

Covers the routing-histogram currency (:class:`repro.serve.traffic.
RoutingProfile` + seeded Zipf/uniform generators), the skew-driven
placer (:func:`repro.sharding.rules.ame_pim_expert_placement`:
greedy token balancing, mass-proportional hot-expert replication,
round-robin baseline), the routed :class:`repro.serve.offload.
DecodeOffload` (per-expert dispatch, replica selection, drift-driven
migration), and the per-stack switched link topology (multicast
charging, ``# LINK`` / ``# MIGRATE`` trace round-trips, strict
additivity of the default shared topology).
"""
import dataclasses

import pytest

from repro.configs import get
from repro.obs.metrics import MetricsRegistry
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.offload import DecodeOffload
from repro.serve.traffic import RoutingProfile, uniform_routing, zipf_routing
from repro.sharding.rules import (
    ame_pim_expert_placement,
    ame_pim_stack_map,
)


def _reduced_moe():
    return get("mixtral-8x22b").reduced()


def _profile(cfg, tokens=512, seed=3, alpha=1.0):
    n_moe = cfg.n_layers - cfg.moe.first_dense_layers
    return zipf_routing(n_moe, cfg.moe.num_experts, tokens,
                        alpha=alpha, seed=seed)


# ---------------------------------------------------------------------------
# RoutingProfile
# ---------------------------------------------------------------------------

def test_routing_generators_deterministic():
    a = zipf_routing(4, 8, 1000, alpha=1.0, seed=5)
    b = zipf_routing(4, 8, 1000, alpha=1.0, seed=5)
    assert a.counts == b.counts and a.meta == b.meta
    c = zipf_routing(4, 8, 1000, alpha=1.0, seed=6)
    assert a.counts != c.counts
    u1 = uniform_routing(4, 8, 1000, seed=5)
    u2 = uniform_routing(4, 8, 1000, seed=5)
    assert u1.counts == u2.counts
    assert all(sum(row) == 1000 for row in a.counts)


def test_zipf_is_skewed_uniform_is_not():
    z = zipf_routing(6, 8, 4000, alpha=1.0, seed=1)
    u = uniform_routing(6, 8, 4000, seed=1)
    zmax = max(max(row) for row in z.counts)
    umax = max(max(row) for row in u.counts)
    assert zmax > 2 * umax  # rank-1 expert draws ~37% vs ~12.5%


def test_routing_profile_save_load_roundtrip(tmp_path):
    p = zipf_routing(3, 4, 256, seed=9)
    path = tmp_path / "routing.json"
    p.save(str(path))
    q = RoutingProfile.load(str(path))
    assert (q.n_layers, q.n_experts) == (p.n_layers, p.n_experts)
    assert q.counts == p.counts and q.meta == p.meta


def test_routing_profile_record_and_probs():
    p = RoutingProfile.empty(2, 4)
    assert p.probs(0) == [0.25] * 4          # empty layer routes uniformly
    p.record(0, 1, 3)
    p.record_counts(0, {1: 1, 2: 4})
    assert p.counts[0] == [0, 4, 4, 0]
    assert p.layer_total(0) == 8 and p.total_tokens == 8
    assert p.probs(0) == [0.0, 0.5, 0.5, 0.0]
    assert p.expert_mass() == [0, 4, 4, 0]


def test_routing_profile_drift():
    a = RoutingProfile(1, 2, [[8, 0]])
    b = RoutingProfile(1, 2, [[0, 8]])
    assert a.drift(a.copy()) == 0.0
    assert a.drift(b) == 1.0
    empty = RoutingProfile.empty(1, 2)
    assert a.drift(empty) == 0.0             # no evidence -> no drift
    with pytest.raises(ValueError):
        a.drift(RoutingProfile.empty(1, 3))


def test_routing_profile_shape_validation():
    with pytest.raises(ValueError):
        RoutingProfile(2, 2, [[1, 2]])
    with pytest.raises(ValueError):
        RoutingProfile(1, 2, [[1, 2, 3]])


# ---------------------------------------------------------------------------
# Skew-driven placement
# ---------------------------------------------------------------------------

def test_placement_deterministic():
    prof = zipf_routing(6, 8, 2048, seed=4)
    a = ame_pim_expert_placement(prof, 4, replicate=2)
    b = ame_pim_expert_placement(prof, 4, replicate=2)
    assert a == b                            # frozen dataclass, tuple fields


def test_greedy_beats_roundrobin_balance():
    prof = zipf_routing(8, 8, 4096, alpha=1.0, seed=3)
    rr = ame_pim_expert_placement(prof, 4, policy="roundrobin")
    greedy = ame_pim_expert_placement(prof, 4, replicate=4)
    assert greedy.max_over_mean <= 1.15
    assert greedy.worst_layer_max_over_mean < rr.worst_layer_max_over_mean
    assert greedy.max_over_mean < rr.max_over_mean


def test_roundrobin_zero_replication_is_legacy_map():
    prof = zipf_routing(5, 8, 1024, seed=7)
    rr = ame_pim_expert_placement(prof, 4, replicate=0, policy="roundrobin")
    for row in rr.homes:
        assert row == tuple((e % 4,) for e in range(8))


def test_replication_copy_counts():
    prof = zipf_routing(4, 8, 4096, alpha=1.0, seed=3)
    plc = ame_pim_expert_placement(prof, 4, replicate=2)
    for layer in range(prof.n_layers):
        row = prof.counts[layer]
        by_mass = sorted(range(8), key=lambda e: (-row[e], e))
        hot, homes = by_mass[0], plc.homes[layer]
        assert 2 <= len(homes[hot]) <= 4     # replicated, distinct stacks
        assert len(set(homes[hot])) == len(homes[hot])
        for e in by_mass[2:]:
            assert len(homes[e]) == 1        # beyond top-2: single home
    # one stack: replication is meaningless and must collapse to 1 copy
    solo = ame_pim_expert_placement(prof, 1, replicate=4)
    assert all(h == (0,) for row in solo.homes for h in row)


def test_placement_validation():
    prof = zipf_routing(2, 4, 128, seed=0)
    with pytest.raises(ValueError):
        ame_pim_expert_placement(prof, 0)
    with pytest.raises(ValueError):
        ame_pim_expert_placement(prof, 2, policy="hash")


def test_stack_map_default_unchanged():
    cfg = _reduced_moe()
    base = ame_pim_stack_map(cfg, 2)
    n = cfg.moe.num_experts
    assert base["experts"] == [e % 2 for e in range(n)]
    assert "expert_placement" not in base
    routed = ame_pim_stack_map(cfg, 2, profile=_profile(cfg), replicate=1)
    assert routed["expert_placement"].replicate == 1
    assert base["layers"] == routed["layers"]


# ---------------------------------------------------------------------------
# Routed decode offload
# ---------------------------------------------------------------------------

def test_routed_offload_validation():
    cfg = _reduced_moe()
    prof = _profile(cfg)
    with pytest.raises(ValueError):          # dense config cannot route
        DecodeOffload(get("qwen3-1.7b").reduced(), channels=4,
                      routing=zipf_routing(2, 4, 64))
    with pytest.raises(ValueError):          # async + routing unsupported
        DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                      async_mode=True)
    with pytest.raises(ValueError):          # profile shape must match cfg
        DecodeOffload(cfg, channels=4, stacks=2,
                      routing=zipf_routing(2, 2, 64))


def test_routed_offload_seed_deterministic():
    cfg = _reduced_moe()
    prof = _profile(cfg)

    def run():
        off = DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                            replicate_experts=1)
        recs = [off.step(4) for _ in range(3)]
        return recs, list(off.tokens_per_stack), dict(off.moe_counters), \
            emit_trace(off.rt.stack)

    ra, rb = run(), run()
    assert ra == rb


def test_routed_offload_balances_and_hits_replicas():
    cfg = _reduced_moe()
    prof = _profile(cfg, tokens=2048)
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                        replicate_experts=1)
    for _ in range(4):
        off.step(8)
    ms = off.moe_summary()
    assert ms["routed_tokens"] > 0
    assert sum(ms["tokens_per_stack"]) == ms["routed_tokens"]
    assert ms["replica_hit_rate"] >= 0.0
    assert ms["observed_max_over_mean"] < 2.0
    assert "moe" in off.roofline()


def test_routed_offload_metrics_counters():
    cfg = _reduced_moe()
    reg = MetricsRegistry()
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=_profile(cfg),
                        replicate_experts=1, metrics=reg)
    off.step(4)
    snap = reg.snapshot()
    assert snap["moe.routed_tokens"]["value"] \
        == off.moe_counters["routed_tokens"]
    assert snap["moe.replica_hits"]["value"] \
        == off.moe_counters["replica_hits"]
    assert snap["moe.tokens_stack0"]["value"] == off.tokens_per_stack[0]
    assert snap["moe.tokens_stack1"]["value"] == off.tokens_per_stack[1]


def test_set_routing_validates_and_keeps_placement():
    cfg = _reduced_moe()
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=_profile(cfg),
                        replicate_experts=1)
    before = off._placement
    with pytest.raises(ValueError):
        off.set_routing(zipf_routing(1, 2, 64))
    off.set_routing(_profile(cfg, seed=11))
    assert off._placement is before          # swap distribution, not homes


# ---------------------------------------------------------------------------
# Migration under drift
# ---------------------------------------------------------------------------

def test_migration_fires_and_roundtrips():
    cfg = _reduced_moe()
    prof = _profile(cfg, seed=3)
    drift = _profile(cfg, seed=43)
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                        replicate_experts=1, migrate_threshold=0.05,
                        migrate_min_tokens=16, link_topology="switched")
    off.step(4)
    off.set_routing(drift)
    for _ in range(4):
        off.step(4)
    assert off.moe_counters["migrations"] >= 1
    reup = sum(n for led in off.rt.stack.all_links()
               for k, n in led.events if k == "reupload")
    assert reup > 0                          # moves charged on dest links
    st = parse_trace(emit_trace(off.rt.stack))
    assert st.migrate_events
    for layer, expert, src, dst, nbytes in st.migrate_events:
        assert nbytes == off.expert_bytes
        assert 0 <= src < 2 and 0 <= dst < 2
        assert 0 <= expert < cfg.moe.num_experts


def test_no_migration_without_threshold():
    cfg = _reduced_moe()
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=_profile(cfg),
                        replicate_experts=1)
    for _ in range(4):
        off.step(8)
    assert off.moe_counters["migrations"] == 0
    st = parse_trace(emit_trace(off.rt.stack))
    assert st.migrate_events == []


# ---------------------------------------------------------------------------
# Link topology: shared default strictly additive, switched per-stack
# ---------------------------------------------------------------------------

def test_shared_topology_default_additive():
    cfg = _reduced_moe()

    def run(**kw):
        off = DecodeOffload(cfg, channels=4, stacks=2, **kw)
        recs = [off.step(4) for _ in range(3)]
        return off.rt.stack.link, recs, emit_trace(off.rt.stack)

    bare, shared = run(), run(link_topology="shared")
    assert bare[0] == shared[0]              # ==-equal ledgers
    assert bare[1] == shared[1]
    assert bare[2] == shared[2]              # byte-identical traces
    st = parse_trace(bare[2])
    assert st.link_stacks_seen == [] and st.migrate_events == []


def test_switched_topology_trace_roundtrip():
    cfg = _reduced_moe()
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=_profile(cfg),
                        replicate_experts=1, link_topology="switched")
    for _ in range(3):
        off.step(4)
    stack = off.rt.stack
    assert len(stack.links) == 2
    assert all(led.label == f"link{s}" for s, led in enumerate(stack.links))
    tr = emit_trace(stack)
    st = parse_trace(tr)
    for s, led in enumerate(stack.links):
        if led.events:
            assert s in st.link_stacks_seen
            assert st.host_link_bytes_per_link[s] == led.bytes
    # reset preserves the topology, clears the ledgers
    stack.reset()
    assert len(stack.links) == 2
    assert all(not led.events for led in stack.links)


def test_switched_multicast_charges_source_once():
    cfg = _reduced_moe()
    prof = _profile(cfg, tokens=2048)

    def xstack_bytes(topology):
        off = DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                            replicate_experts=1, link_topology=topology)
        off.step(8)
        return sum(n for led in off.rt.stack.all_links()
                   for k, n in led.events if k == "xstack")

    # the multicast union of off-home tokens can never exceed the
    # shared topology's per-destination sum at equal routing
    assert xstack_bytes("switched") <= xstack_bytes("shared")


def test_bad_link_topology_rejected():
    cfg = _reduced_moe()
    with pytest.raises(ValueError):
        DecodeOffload(cfg, channels=4, stacks=2, link_topology="mesh")
