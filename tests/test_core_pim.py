"""Core layer tests: strict interpreter vs oracle vs fast engine (bit-exact),
Table-1 mapping, CSRs, pointer table, cost-model calibration vs the paper."""
import numpy as np
import pytest

from repro.core import cost as cost_mod
from repro.core.engine import AMEEngine
from repro.runtime import pim_gemm, pim_gemv
from repro.core.isa import (
    AMEOp,
    PIMOpcode,
    ROWNUM,
    UnsupportedOnPIM,
    pim_mapping,
)
from repro.core.pep import (
    ChannelMemoryMap,
    banks_to_tile,
    ew_invocations,
    init_channel,
    mac_invocations,
    run_ew_strict,
    run_mac_strict,
    scalars_to_bank0,
    tile_to_banks,
)

F16 = np.float16
RNG = np.random.default_rng(0)


def rand_tile(m, c, scale=1.0):
    return (RNG.standard_normal((m, c)) * scale).astype(F16)


# ---------------------------------------------------------------------------
# order-exact FP16 oracles (round after multiply, round after add)
# ---------------------------------------------------------------------------


def oracle_gemm_f16(a, b):
    """Ascending-k outer products; each MAC is a fused multiply-accumulate
    (single rounding at the FP16 register writeback)."""
    m, k = a.shape
    _, n = b.shape
    acc = np.zeros((m, n), F16)
    for kk in range(k):
        acc = (acc.astype(np.float32)
               + a[:, kk:kk + 1].astype(np.float32)
               @ b[kk:kk + 1, :].astype(np.float32)).astype(F16)
    return acc


def oracle_sub_f16(a, b):
    return (a + (b * F16(-1.0)).astype(F16)).astype(F16)


# ---------------------------------------------------------------------------
# strict interpreter (Listing 1) vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,m,c", [
    ("add", 128, 16), ("add", 37, 24), ("mul", 128, 8), ("mul", 16, 40),
    ("sub", 128, 16), ("sub", 64, 8),
])
def test_strict_elementwise(kind, m, c):
    ch, mm = init_channel(nblocks=8192, b_region_blocks=64, tile_cols=64)
    a, b = rand_tile(m, c), rand_tile(m, c)
    tile_to_banks(ch.state.even_banks, mm.tiles[0], a)
    tile_to_banks(ch.state.even_banks, mm.tiles[1], b)
    cmds = run_ew_strict(ch, mm, kind, mm.tiles[0], mm.tiles[1], mm.accs[0], c)
    got = banks_to_tile(ch.state.odd_banks, mm.accs[0], m, c)
    ref = {"add": lambda: (a + b).astype(F16),
           "mul": lambda: (a * b).astype(F16),
           "sub": lambda: oracle_sub_f16(a, b)}[kind]()
    np.testing.assert_array_equal(got, ref)
    # command count matches the Listing-1 instruction mix
    passes = sum(p for _, p in ew_invocations(c))
    per = {"add": 24, "mul": 24, "sub": 32}[kind]
    extra = 8 * len(ew_invocations(c)) if kind == "sub" else 0
    assert cmds == passes * per + extra


@pytest.mark.parametrize("m,k,n", [(128, 8, 4), (128, 16, 2), (64, 24, 3),
                                   (128, 8, 1), (16, 8, 8)])
def test_strict_mac_outer_product(m, k, n):
    ch, mm = init_channel(nblocks=8192, b_region_blocks=64, tile_cols=64)
    a = rand_tile(m, k, 0.5)
    b = rand_tile(k, n, 0.5)
    tile_to_banks(ch.state.even_banks, mm.tiles[0], a)
    scalars_to_bank0(ch.state.even_banks, mm.b_scalars, b.T)  # K-major per col
    tile_to_banks(ch.state.odd_banks, mm.accs[0], np.zeros((m, n), F16))
    cmds = run_mac_strict(ch, mm, mm.tiles[0], mm.accs[0], k, n)
    got = banks_to_tile(ch.state.odd_banks, mm.accs[0], m, n)
    np.testing.assert_array_equal(got, oracle_gemm_f16(a, b))
    passes = sum(i.passes for i in mac_invocations(k, n))
    assert cmds == passes * 26  # 1 fill + 8 srf + 8 bcast-add + 8 mac + 1 mov


def test_strict_mac_accumulates_into_existing_acc():
    ch, mm = init_channel(nblocks=4096, b_region_blocks=64, tile_cols=64)
    a, b = rand_tile(128, 8), rand_tile(8, 4)
    acc0 = rand_tile(128, 4)
    tile_to_banks(ch.state.even_banks, mm.tiles[0], a)
    scalars_to_bank0(ch.state.even_banks, mm.b_scalars, b.T)
    tile_to_banks(ch.state.odd_banks, mm.accs[0], acc0)
    run_mac_strict(ch, mm, mm.tiles[0], mm.accs[0], 8, 4)
    got = banks_to_tile(ch.state.odd_banks, mm.accs[0], 128, 4)
    ref = acc0.copy()
    for kk in range(8):
        ref = (ref.astype(np.float32)
               + a[:, kk:kk + 1].astype(np.float32)
               @ b[kk:kk + 1, :].astype(np.float32)).astype(F16)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# fast engine is bit-exact with the strict interpreter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(128, 16, 4), (96, 8, 8), (128, 40, 2)])
def test_fast_engine_bitexact_vs_strict(m, k, n):
    a = rand_tile(m, k, 0.5)
    b = rand_tile(k, n, 0.5)
    # strict
    ch, mm = init_channel(nblocks=4096, b_region_blocks=64, tile_cols=64)
    tile_to_banks(ch.state.even_banks, mm.tiles[0], a)
    scalars_to_bank0(ch.state.even_banks, mm.b_scalars, b.T)
    tile_to_banks(ch.state.odd_banks, mm.accs[0], np.zeros((m, n), F16))
    run_mac_strict(ch, mm, mm.tiles[0], mm.accs[0], k, n)
    strict = banks_to_tile(ch.state.odd_banks, mm.accs[0], m, n)
    # fast
    eng = AMEEngine()
    eng.msettilem(m), eng.msettilek(k), eng.msettilen(n)
    eng.mld(0, a)
    eng.mld(1, b)
    eng.mfmacc(0, 0, 1)
    fast = np.asarray(eng.mst(0))
    np.testing.assert_array_equal(strict, fast)


@pytest.mark.parametrize("kind", ["add", "mul", "sub"])
def test_fast_engine_elementwise_bitexact_vs_strict(kind):
    m, c = 77, 19
    a, b = rand_tile(m, c), rand_tile(m, c)
    ch, mm = init_channel(nblocks=4096, b_region_blocks=64, tile_cols=64)
    tile_to_banks(ch.state.even_banks, mm.tiles[0], a)
    tile_to_banks(ch.state.even_banks, mm.tiles[1], b)
    run_ew_strict(ch, mm, kind, mm.tiles[0], mm.tiles[1], mm.accs[0], c)
    strict = banks_to_tile(ch.state.odd_banks, mm.accs[0], m, c)
    eng = AMEEngine()
    eng.msettilem(m), eng.msettilek(c)
    eng.mld(0, a)
    eng.mld(1, b)
    getattr(eng, f"mf{kind}")(0, 0, 1)
    np.testing.assert_array_equal(strict, np.asarray(eng.mst(0)))


# ---------------------------------------------------------------------------
# AME semantics: Table-1 mapping, CSRs, pointer table
# ---------------------------------------------------------------------------


def test_table1_unsupported_ops_raise():
    eng = AMEEngine()
    eng.mld(0, rand_tile(8, 8))
    eng.mld(1, rand_tile(8, 8))
    with pytest.raises(UnsupportedOnPIM):
        eng.mfmax(0, 0, 1)
    with pytest.raises(UnsupportedOnPIM):
        eng.mfmin(0, 0, 1)
    with pytest.raises(UnsupportedOnPIM):
        eng.mfmacc(0, 0, 1, widen=True)
    with pytest.raises(UnsupportedOnPIM):
        pim_mapping(AMEOp.MFMACC_WIDEN)


def test_table1_supported_mappings():
    assert pim_mapping(AMEOp.MFADD_MM) == (PIMOpcode.ADD,)
    assert pim_mapping(AMEOp.MFSUB_MM) == (PIMOpcode.MUL, PIMOpcode.ADD)
    assert pim_mapping(AMEOp.MFMUL_MV) == (PIMOpcode.MUL,)
    assert pim_mapping(AMEOp.MFMACC) == (PIMOpcode.MAC,)


def test_csr_clamping():
    eng = AMEEngine()
    assert eng.msettilem(1000) == ROWNUM
    assert eng.msettilek(10 ** 6) == 4096
    assert eng.msettilen(0) == 1


def test_pointer_table_transposed_load_and_slide():
    eng = AMEEngine()
    a = rand_tile(16, 32)
    eng.mld_t(0, a)                       # zero-copy transpose
    assert eng.tr[0].shape == (32, 16)
    np.testing.assert_array_equal(np.asarray(eng.tr[0].resolve()), a.T)
    eng.mslide(0, rows=2, cols=1)
    np.testing.assert_array_equal(np.asarray(eng.tr[0].resolve()), a.T[2:, 1:])
    eng.mmov(1, 0)
    assert eng.tr[1].shape == eng.tr[0].shape


def test_mv_broadcast_form():
    eng = AMEEngine()
    a = rand_tile(32, 16)
    v = rand_tile(1, 16)[0]
    eng.msettilem(32), eng.msettilek(16)
    eng.mld(0, a)
    eng.mfadd(0, 0, v)                    # .mv.i form
    ref = (a + np.broadcast_to(v, a.shape)).astype(F16)
    np.testing.assert_array_equal(np.asarray(eng.mst(0)), ref)


# ---------------------------------------------------------------------------
# cost model calibration vs the paper (§4, Figs 8/9, Table 3)
# ---------------------------------------------------------------------------


def test_paper_headline_numbers():
    s = cost_mod.summary()
    # 59.4 FLOP/cycle saturated; 14.9 GFLOP/s at 250 MHz (paper abstract)
    assert abs(s["mfmacc_flop_per_cycle_saturated"] - 59.4) < 0.1
    assert abs(s["mfmacc_flop_per_cycle_saturated"] * 250e6 / 1e9 - 14.9) < 0.1
    # 256 MAC-PEP invocations at max tile (paper §4.2)
    assert s["mfmacc_launches_maxtile"] == 256
    # setup <1% of runtime at max tile (paper §4.2)
    assert s["setup_share_maxtile"] < 0.01
    # bounded by <= half the 128 FLOP/cycle theoretical peak (paper §4.2)
    assert s["mfmacc_flop_per_cycle_saturated"] <= 64.0


def test_mac_invocation_decomposition():
    from repro.core.pep import mac_pass_coords
    # paper: "supports both 128x2048x1 GEMV and 128x8x256 GEMM in a single
    # execution" — both are exactly one PEP launch
    assert len(mac_invocations(2048, 1)) == 1
    assert len(mac_invocations(8, 256)) == 1
    assert len(mac_invocations(4096, 128)) == 256
    # the global pass schedule covers every (j, k0) exactly once, j-outer /
    # k-inner (ascending k per column = hardware accumulation order)
    k, n = 48, 3
    invs = mac_invocations(k, n)
    coords = [mac_pass_coords(i.start + t, k)
              for i in invs for t in range(i.passes)]
    expect = [(j, 8 * c) for j in range(n) for c in range(6)]
    assert coords == expect


def test_elementwise_double_invocation_at_max_tile():
    # paper §4.2: mfadd/mfmul/mfsub require a double PEP invocation at 128x4096
    rep = cost_mod.elementwise_cost("add", 128, 4096)
    assert rep.launches == 2
    rep = cost_mod.mfmacc_cost(128, 4096, 128)
    assert rep.launches == 256


def test_fig9_scaling_monotone_saturation():
    sizes = [8, 32, 128, 512, 1024, 2048]
    effs = [cost_mod.mfmacc_cost(128, s, 1).flop_per_cycle for s in sizes]
    assert all(b > a for a, b in zip(effs, effs[1:]))  # monotone rising
    assert effs[-1] > 0.95 * cost_mod.saturated_flop_per_cycle("mac")
    # small tiles are setup-dominated (well under half the plateau)
    assert effs[0] < 0.5 * effs[-1]


def test_sub_slower_than_add():
    add = cost_mod.elementwise_cost("add", 128, 2048)
    sub = cost_mod.elementwise_cost("sub", 128, 2048)
    assert sub.cycles > add.cycles
    assert sub.flop_per_cycle < add.flop_per_cycle


# ---------------------------------------------------------------------------
# end-to-end PIM GEMM/GEMV
# ---------------------------------------------------------------------------


def test_pim_gemm_against_fp32(tolerant=True):
    a = rand_tile(256, 160, 0.2)
    b = rand_tile(160, 192, 0.2)
    out, rep = pim_gemm(a, b)
    ref = a.astype(np.float32) @ b.astype(np.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=0.25, rtol=0.05)
    assert rep.total_flops == 2 * 256 * 160 * 192
    assert rep.makespan_cycles > 0


def test_pim_gemv_matches_gemm_column():
    a = rand_tile(128, 64, 0.3)
    x = rand_tile(64, 1, 0.3)[:, 0]
    y, rep = pim_gemv(a, x)
    ref = oracle_gemm_f16(a, x[:, None])[:, 0]
    np.testing.assert_array_equal(np.asarray(y), ref)


def test_no_multi_channel_flop_scaling():
    """Regression for the old ``AMEEngine(channels=N)`` double-count: the
    engine is strictly single-channel — one mfmacc charges exactly its own
    FLOPs, and multi-channel FLOP totals live in the runtime (where they
    equal the single-channel total for the same problem; see
    tests/test_runtime.py)."""
    eng = AMEEngine()
    eng.mld(0, rand_tile(128, 64))
    eng.mld(1, rand_tile(64, 4))
    eng.msettilek(64), eng.msettilen(4)
    r = eng.mfmacc(0, 0, 1)
    assert r.flops == 2 * 128 * 64 * 4
    assert r.cycles == cost_mod.mfmacc_cost(128, 64, 4).cycles
    assert not hasattr(eng, "channels")
