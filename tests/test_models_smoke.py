"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions, and prefill/decode parity checks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_names, applicable, get, input_specs
from repro.models import model as lm
from repro.models.layers import XLA

RNG = np.random.default_rng(7)


def make_batch(cfg, shape, reduced=True):
    """Concrete arrays matching input_specs."""
    specs = input_specs(cfg, shape, reduced=reduced)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "targets") else s.shape[-1]
            out[name] = jnp.asarray(RNG.integers(0, hi, s.shape), jnp.int32)
        elif s.dtype == jnp.bool_:
            out[name] = jnp.asarray(RNG.random(s.shape) < 0.3)
        elif name == "loss_mask":
            out[name] = jnp.ones(s.shape, s.dtype)
        else:
            out[name] = jnp.asarray(RNG.standard_normal(s.shape) * 0.1, s.dtype)
    return out


@pytest.mark.parametrize("name", all_names())
def test_train_step_smoke(name):
    cfg = get(name).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPES["train_4k"])
    loss, metrics = jax.jit(
        lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    assert float(metrics["ce"]) > 0
    # one grad step must be finite too
    g = jax.jit(jax.grad(lambda p, b: lm.loss_fn(p, b, cfg)[0]))(params, batch)
    flat = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat), name


@pytest.mark.parametrize("name", [n for n in all_names()
                                  if not get(n).encoder_only])
def test_prefill_decode_smoke(name):
    cfg = get(name).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, t = 2, 32
    batch = make_batch(cfg, SHAPES["prefill_32k"])
    batch = {k: v[:, :t] if v.ndim > 1 else v for k, v in batch.items()}
    if "tokens" in batch:
        batch["tokens"] = batch["tokens"][:, :t]
    logits, caches = jax.jit(
        lambda p, bb: lm.prefill(p, bb, cfg, cache_len=t + 8))(params, batch)
    assert logits.shape[0] == 2 and np.isfinite(np.asarray(logits)).all()
    # a few decode steps
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos_base = t if cfg.modality == "text" else logits.shape[0]
    pos = jnp.full((2,), t, jnp.int32)
    step = jax.jit(lambda p, tk, ps, c: lm.decode_step(p, tk, ps, c, cfg))
    for i in range(3):
        logits, caches = step(params, tok, pos + i, caches)
        assert np.isfinite(np.asarray(logits)).all(), f"{name} step {i}"
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def _decode_chain_logits(cfg, params, tokens, cache_len):
    """Prefill 1 token, then decode the rest one-by-one."""
    b, t = tokens.shape
    logits, caches = lm.prefill(params, {"tokens": tokens[:, :1]}, cfg,
                                cache_len=cache_len)
    outs = [logits]
    step = jax.jit(lambda p, tk, ps, c: lm.decode_step(p, tk, ps, c, cfg))
    for i in range(1, t):
        lg, caches = step(params, tokens[:, i:i + 1],
                          jnp.full((b,), i, jnp.int32), caches)
        outs.append(lg)
    return jnp.stack(outs, 1)  # (B, T, Vp)


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-370m", "gemma-2b",
                                  "mixtral-8x22b", "deepseek-v3-671b",
                                  "zamba2-2.7b"])
def test_decode_matches_full_forward(name):
    """Sequential decode must reproduce the full-sequence forward logits.

    MoE capacity is raised so no token is dropped — capacity drops are a
    *semantic* difference between a 16-token forward and 1-token decodes,
    not a parity bug (covered by test_moe_capacity_drops)."""
    import dataclasses
    cfg = get(name).reduced()
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    b, t = 2, 16
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t)), jnp.int32)

    # full forward logits via prefill over the whole sequence
    full_logits, _ = lm.prefill(params, {"tokens": tokens}, cfg, cache_len=t)
    # prefill(1) + decode chain
    chain = _decode_chain_logits(cfg, params, tokens, cache_len=t)
    np.testing.assert_allclose(
        np.asarray(chain[:, -1]), np.asarray(full_logits),
        atol=2e-2, rtol=2e-2)


def test_moe_capacity_drops():
    """With a tiny capacity factor, some tokens are dropped (output becomes
    the shared/residual path only) — outputs change but stay finite."""
    import dataclasses
    cfg = get("mixtral-8x22b").reduced()
    lo = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    hi = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(hi, jax.random.PRNGKey(3))
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    l_lo, _ = lm.prefill(params, {"tokens": tokens}, lo, cache_len=16)
    l_hi, _ = lm.prefill(params, {"tokens": tokens}, hi, cache_len=16)
    assert np.isfinite(np.asarray(l_lo)).all()
    assert float(jnp.abs(l_lo - l_hi).max()) > 1e-4   # drops visibly differ


@pytest.mark.parametrize("name", ["mixtral-8x22b"])
def test_swa_rolling_cache_decode(name):
    """Rolling cache with window smaller than the sequence stays finite and
    matches the full forward (window masks identically)."""
    import dataclasses
    cfg = get(name).reduced()        # window 16
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(2))
    b, t = 1, 24                      # longer than the 16-slot rolling cache
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    full_logits, _ = lm.prefill(params, {"tokens": tokens}, cfg, cache_len=t)
    chain = _decode_chain_logits(cfg, params, tokens, cache_len=t)
    np.testing.assert_allclose(np.asarray(chain[:, -1]),
                               np.asarray(full_logits), atol=2e-2, rtol=2e-2)


def test_hubert_masked_prediction_loss_only_on_mask():
    cfg = get("hubert-xlarge").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, t = 2, 32
    frames = jnp.asarray(RNG.standard_normal((b, t, cfg.d_model)) * 0.1,
                         jnp.float32)
    targets = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    m0 = jnp.zeros((b, t), bool).at[:, :4].set(True)
    l0, _ = lm.loss_fn(params, {"frames": frames, "mask": m0,
                                "targets": targets}, cfg)
    # flipping targets OUTSIDE the mask must not change the loss
    targets2 = targets.at[:, 10:].set((targets[:, 10:] + 1) % cfg.vocab_size)
    l1, _ = lm.loss_fn(params, {"frames": frames, "mask": m0,
                                "targets": targets2}, cfg)
    assert abs(float(l0) - float(l1)) < 1e-6


def test_param_counts_full_configs():
    """Full-config parameter counts estimated analytically (no allocation)
    land in the right ballpark for the headline sizes."""
    import repro.launch.params as pc
    approx = {
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "command-r-35b": (30e9, 42e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "phi4-mini-3.8b": (3.0e9, 4.8e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "mixtral-8x22b": (120e9, 150e9),
        "internvl2-76b": (62e9, 80e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
        "mamba2-370m": (0.30e9, 0.50e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for name, (lo, hi) in approx.items():
        n = pc.count_params(get(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
