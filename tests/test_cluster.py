"""Multi-stack cluster tests: single-stack clusters are bit-identical
(ledgers + traces) to bare stacks, fixed-total-channel reshapes keep
makespan parity with host-link bytes only where shards cross stacks,
cross-stack K-split drains charge the link, ``# STACK`` / ``# HOSTLINK``
/ ``# SPILL`` markers round-trip through the trace, residency capacity
bounds evict LRU-first as spill, the synchronous-DMA transfer mode, the
``place`` input validation, the ``ame_pim`` sharding rules, and the
multi-stack decode offload."""
import numpy as np
import pytest

from repro.configs import get
from repro.runtime import (
    HOST_LINK_BYTES_PER_CYCLE,
    PIMCluster,
    PIMRuntime,
    PIMStack,
    PLACEMENTS,
    cluster_shards,
    host_link_cycles,
    pim_gemm,
    placement_shards,
)
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.offload import DecodeOffload
from repro.sharding.rules import ame_pim_layer_stacks, ame_pim_stack_map

RNG = np.random.default_rng(7)


def rand(*shape, scale=0.15):
    return (RNG.standard_normal(shape) * scale).astype(np.float16)


# ---------------------------------------------------------------------------
# single-stack cluster == bare stack, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
def test_single_stack_cluster_identical_to_bare_stack(placement):
    a, b = rand(256, 512), rand(512, 96)
    bare = PIMRuntime(channels=4)
    clus = PIMRuntime(stack=PIMCluster(1, 4))
    y_b, rep_b = bare.gemm(a, b, placement=placement)
    y_c, rep_c = clus.gemm(a, b, placement=placement)
    assert np.array_equal(np.asarray(y_b), np.asarray(y_c))
    assert rep_b == rep_c                     # ==-equal ledgers
    assert rep_c.host_link_bytes == 0 and rep_c.stacks == 1
    assert emit_trace(bare.stack) == emit_trace(clus.stack)   # byte-equal


def test_single_stack_cluster_identical_for_elementwise_and_residency():
    a, b = rand(256, 256), rand(256, 256)
    bare, clus = PIMRuntime(channels=4), PIMRuntime(stack=PIMCluster(1, 4))
    for rt in (bare, clus):
        w = rt.place(a, placement="balanced", other_dim=256)
        rt.elementwise("mul", a, b, placement="row-striped")
        rt.gemm(w, b, placement="balanced")
    assert emit_trace(bare.stack) == emit_trace(clus.stack)


def test_runtime_stacks_1_is_bare_stack():
    rt = PIMRuntime(channels=4, stacks=1)
    assert isinstance(rt.stack, PIMStack)
    assert rt.n_stacks == 1


# ---------------------------------------------------------------------------
# stack-axis placement: flat geometry preserved at fixed total channels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
@pytest.mark.parametrize("stacks,cps", [(2, 8), (4, 4)])
def test_cluster_shards_preserve_flat_geometry(placement, stacks, cps):
    flat = placement_shards(placement, 512, 1024, 64, stacks * cps)
    clus = cluster_shards(placement, 512, 1024, 64, stacks, cps)
    assert len(flat) == len(clus)
    for f, c in zip(flat, clus):
        assert c.stack == f.channel // cps
        assert c.channel == f.channel % cps
        assert (c.m0, c.m1, c.k0, c.k1, c.n0, c.n1) == \
            (f.m0, f.m1, f.k0, f.k1, f.n0, f.n1)


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
def test_fixed_total_channels_makespan_parity(placement):
    a, b = rand(512, 768), rand(768, 128)
    y_f, rep_f = pim_gemm(a, b, channels=16, placement=placement)
    y_c, rep_c = pim_gemm(a, b, channels=4, placement=placement, stacks=4)
    assert np.array_equal(np.asarray(y_f), np.asarray(y_c))
    assert rep_c.makespan_cycles == rep_f.makespan_cycles
    # per-channel ledgers identical under the flat view
    for cf, cc in zip(rep_f.per_channel, rep_c.per_channel):
        assert (cf.compute_cycles, cf.flops, cf.commands, cf.h2d_bytes,
                cf.d2h_bytes) == (cc.compute_cycles, cc.flops, cc.commands,
                                  cc.h2d_bytes, cc.d2h_bytes)


def test_host_link_bytes_only_where_shards_cross_stacks():
    a, b = rand(2048, 512), rand(512, 128)    # 16 row blocks: all 16 busy
    # row-striped: every channel gets full B -> B crosses all 4 stacks;
    # per-channel A rows never repeat -> only B charges the link
    _, rep = pim_gemm(a, b, channels=4, placement="row-striped", stacks=4)
    b_bytes = b.size * 2
    assert rep.host_link_bytes == 3 * b_bytes
    assert rep.host_link_cycles == 3 * host_link_cycles(b_bytes)
    # one stack of the same cluster shape: nothing crosses
    rt = PIMRuntime(channels=4, stacks=4)
    _, rep1 = rt.gemm(a, b, placement="row-striped", stack=2)
    assert rep1.host_link_bytes == 0
    # restricted ops report only the participating stack's channels
    assert rep1.channels == 4 and len(rep1.per_channel) == 4
    assert all(c.stack == 2 for c in rep1.per_channel)
    assert any(c.busy_cycles > 0 for c in rep1.per_channel)


def test_place_charges_link_for_cross_stack_replication():
    # 2d-block role=B: column slabs replicate across the 2 row groups,
    # which straddle the 2 stacks -> one extra copy of B crosses the link
    b = np.zeros((512, 96), np.float16)
    rt = PIMRuntime(channels=4, stacks=2)
    rt.place(b, placement="2d-block", role="B", other_dim=256)
    assert rt.stack.link.bytes == b.size * 2
    # pinned to one stack: no crossing
    rt2 = PIMRuntime(channels=4, stacks=2)
    rt2.place(b, placement="2d-block", role="B", other_dim=256, stack=1)
    assert rt2.stack.link.bytes == 0
    assert all(d.xfer.h2d_bytes == 0 for d in rt2.stack.stacks[0])


def test_cross_stack_ksplit_drains_charge_link():
    # balanced on a 1-row-block GEMV splits K across all channels: the
    # partial drains of the single reduction group span every stack, so
    # all partials beyond the home stack's cross the link
    a, x = rand(128, 4096), rand(4096)
    rt = PIMRuntime(channels=2, stacks=2)
    _, rep = rt.gemv(a, x, placement="balanced")
    partial_bytes = 128 * 1 * 2          # one partial column per shard
    expected = partial_bytes * 2         # the two stack-1 partials
    drain = sum(n for k, n in rt.stack.link.events if k == "drain")
    assert drain == expected
    assert rep.host_link_bytes >= expected
    # single stack, same shape: no link at all
    rt1 = PIMRuntime(channels=4)
    rt1.gemv(a, x, placement="balanced")
    assert not hasattr(rt1.stack, "link")


def test_cluster_makespan_folds_link_in():
    a, b = rand(512, 512), rand(512, 64)      # 4 blocks: both stacks busy
    _, rep = pim_gemm(a, b, channels=2, placement="row-striped", stacks=2)
    assert rep.host_link_bytes > 0
    assert rep.cluster_makespan_cycles == max(rep.makespan_cycles,
                                              rep.host_link_cycles)


def test_stack_restricted_op_requires_cluster():
    rt = PIMRuntime(channels=4)
    with pytest.raises(ValueError, match="stack="):
        rt.gemm(rand(128, 128), rand(128, 128), stack=0)
    rtc = PIMRuntime(channels=2, stacks=2)
    with pytest.raises(ValueError, match="out of range"):
        rtc.gemm(rand(128, 128), rand(128, 128), stack=5)


def test_analytic_and_numeric_cluster_ledgers_identical():
    a, b = rand(256, 512), rand(512, 96)
    for placement in sorted(PLACEMENTS):
        rx = PIMRuntime(channels=4, stacks=2)
        ra = PIMRuntime(channels=4, stacks=2)
        _, rep_x = rx.gemm(a, b, placement=placement)
        _, rep_a = ra.gemm(a, b, placement=placement, execute=False)
        for cx, ca in zip(rep_x.per_channel, rep_a.per_channel):
            assert (cx.compute_cycles, cx.flops, cx.commands) \
                == (ca.compute_cycles, ca.flops, ca.commands)
        assert rep_x.host_link_bytes == rep_a.host_link_bytes


# ---------------------------------------------------------------------------
# trace markers round-trip
# ---------------------------------------------------------------------------


def test_stack_markers_roundtrip_through_trace():
    rt = PIMRuntime(channels=2, stacks=2)
    a, b = rand(512, 256), rand(256, 32)      # 4 blocks: both stacks busy
    _, rep = rt.gemm(a, b, placement="row-striped")
    text = emit_trace(rt.stack)
    stats = parse_trace(text)
    assert stats.stacks_seen == [0, 1]
    # per-stack PIM command counts match the device ledgers
    for sid, stk in enumerate(rt.stack.stacks):
        assert stats.pim_per_stack[sid] == \
            sum(d.compute_commands for d in stk)
        assert stats.mem_writes_per_stack[sid] == \
            sum(d.xfer.h2d_cycles for d in stk)
    # host-link marker bytes equal the ledger
    assert stats.total_host_link_bytes == rt.stack.link.bytes > 0
    assert stats.host_link_events == len(rt.stack.link.events)


def test_single_stack_trace_has_no_stack_markers():
    rt = PIMRuntime(stack=PIMCluster(1, 2))
    rt.gemm(rand(128, 128), rand(128, 32))
    text = emit_trace(rt.stack)
    assert "# STACK" not in text and "# HOSTLINK" not in text
    stats = parse_trace(text)
    assert stats.stacks_seen == [] and stats.host_link_events == 0


def test_spill_markers_roundtrip_through_trace():
    cap = 128 * 256 * 2                       # one 128-row box of 256 cols
    rt = PIMRuntime(channels=2, capacity_bytes=cap)
    w1 = rt.place(rand(256, 256), placement="balanced")
    rt.place(rand(256, 256), placement="balanced")    # evicts w1
    stats = parse_trace(emit_trace(rt.stack))
    assert sum(stats.spill_bytes.values()) == \
        sum(d.spill_bytes for d in rt.stack) == 2 * cap


# ---------------------------------------------------------------------------
# residency capacity guard (LRU spill)
# ---------------------------------------------------------------------------


def test_capacity_unbounded_by_default():
    rt = PIMRuntime(channels=2)
    w = rt.place(rand(512, 256), placement="balanced")
    assert all(d.capacity_bytes is None for d in rt.stack)
    _, rep = rt.gemv(w, rand(256), placement="balanced")
    assert rep.total_spill_bytes == 0
    assert rep.total_reuse_bytes == w.shape[0] * w.shape[1] * 2


def test_capacity_evicts_lru_and_recharges_as_reship():
    a1, a2, x = rand(256, 256), rand(256, 256), rand(256)
    box = 128 * 256 * 2
    rt = PIMRuntime(channels=2, capacity_bytes=box)
    w1 = rt.place(a1, placement="balanced")
    w2 = rt.place(a2, placement="balanced")   # evicts w1 per channel
    assert sum(d.spill_bytes for d in rt.stack) == 2 * box
    # w2 is the resident one: zero weight h2d
    y2, rep2 = rt.gemv(w2, x, placement="balanced")
    assert rep2.total_reuse_bytes == 2 * box
    # w1 was spilled: full re-ship (which evicts w2 again), numerics exact
    y1, rep1 = rt.gemv(w1, x, placement="balanced")
    assert rep1.total_reuse_bytes == 0
    assert rep1.total_h2d_bytes >= 2 * box
    y_ref, _ = PIMRuntime(channels=2).gemv(a1, x, placement="balanced")
    assert np.array_equal(np.asarray(y1), np.asarray(y_ref))


def test_capacity_touch_order_is_lru_not_fifo():
    box = 128 * 128 * 2
    dev_rt = PIMRuntime(channels=1, capacity_bytes=2 * box)
    w1 = dev_rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    w2 = dev_rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    # touch w1 so w2 becomes the LRU victim
    dev_rt.gemm(w1, rand(128, 128), placement="row-striped")
    w3 = dev_rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    dev = dev_rt.stack[0]
    assert dev.resident_bytes_of(w1.uid) == box      # survived
    assert dev.resident_bytes_of(w2.uid) == 0        # evicted
    assert dev.resident_bytes_of(w3.uid) == box


def test_keep_output_drains_immediately_when_capacity_refuses():
    a, b = rand(128, 128), rand(128, 128)
    # capacity too small for the 128x128 output box: keep_output cannot
    # actually keep it, so the drain is charged now, not deferred
    rt = PIMRuntime(channels=1, capacity_bytes=1024)
    h, rep = rt.gemm(a, b, placement="row-striped", keep_output=True)
    out_bytes = 128 * 128 * 2
    assert rep.total_d2h_bytes == out_bytes         # drained at op time
    assert h.pending_d2h == []                      # nothing deferred
    d2h_before = sum(d.xfer.d2h_bytes for d in rt.stack)
    out = h.to_host()                               # no second drain
    assert sum(d.xfer.d2h_bytes for d in rt.stack) == d2h_before
    y_ref, _ = PIMRuntime(channels=1).gemm(a, b, placement="row-striped")
    assert np.array_equal(np.asarray(out), np.asarray(y_ref))
    # unbounded: the drain is deferred as before
    rt2 = PIMRuntime(channels=1)
    h2, rep2 = rt2.gemm(a, b, placement="row-striped", keep_output=True)
    assert rep2.total_d2h_bytes == 0 and len(h2.pending_d2h) == 1


def test_kept_output_is_pinned_until_drained():
    a, b = rand(128, 128), rand(128, 128)
    out_bytes = 128 * 128 * 2
    # capacity holds exactly one box: the kept output occupies it
    rt = PIMRuntime(channels=1, capacity_bytes=out_bytes)
    # operand residency won't stick (A evicted to fit the output or
    # refused outright) but the undrained output must never be spilled
    h, rep = rt.gemm(a, b, placement="row-striped", keep_output=True)
    assert rep.total_d2h_bytes == 0 and len(h.pending_d2h) == 1
    w = rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    dev = rt.stack[0]
    assert dev.resident_bytes_of(h.uid) == out_bytes   # survived (pinned)
    assert dev.resident_bytes_of(w.uid) == 0           # refused, streamed
    out = h.to_host()                                  # drain + unpin
    assert dev.xfer.d2h_bytes == out_bytes
    y_ref, _ = PIMRuntime(channels=1).gemm(a, b, placement="row-striped")
    assert np.array_equal(np.asarray(out), np.asarray(y_ref))
    # drained output is evictable again: the next place can claim the slot
    w2 = rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    assert dev.resident_bytes_of(w2.uid) == out_bytes
    assert dev.resident_bytes_of(h.uid) == 0


def test_doomed_insert_spills_nothing():
    # capacity 3 boxes: a pinned 2-box output + a 1-box tensor; a 2-box
    # insert cannot fit even after evicting w1, so it must be refused
    # up-front without costing w1 its residency
    box = 128 * 128 * 2
    rt = PIMRuntime(channels=1, capacity_bytes=3 * box)
    h, _ = rt.gemm(rand(256, 128), rand(128, 128),
                   placement="row-striped", keep_output=True)   # pinned
    w1 = rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    dev = rt.stack[0]
    w2 = rt.place(rand(256, 128), placement="row-striped", other_dim=128)
    assert dev.resident_bytes_of(w2.uid) == 0      # refused (2-box block)
    assert dev.resident_bytes_of(w1.uid) == box    # untouched
    assert dev.spill_bytes == 0                    # nothing spilled


def test_oversized_box_streams_without_residency():
    rt = PIMRuntime(channels=1, capacity_bytes=1024)
    w = rt.place(rand(128, 128), placement="row-striped", other_dim=128)
    assert rt.stack.resident_bytes == 0
    # charged as plain h2d both times, no spill events
    _, rep = rt.gemm(w, rand(128, 128), placement="row-striped")
    assert rep.total_reuse_bytes == 0 and rep.total_spill_bytes == 0
    assert rep.total_h2d_bytes > 0


# ---------------------------------------------------------------------------
# synchronous-DMA (non-overlapped) transfer mode
# ---------------------------------------------------------------------------


def test_sync_dma_mode_is_strict_sequence():
    a, b = rand(512, 2048), rand(2048, 128)
    _, rep_o = PIMRuntime(channels=4).gemm(a, b, placement="row-striped")
    _, rep_s = PIMRuntime(channels=4, overlap=False).gemm(
        a, b, placement="row-striped")
    for co, cs in zip(rep_o.per_channel, rep_s.per_channel):
        # identical ledgers, only the busy model differs
        assert (co.h2d_cycles, co.compute_cycles, co.d2h_cycles) \
            == (cs.h2d_cycles, cs.compute_cycles, cs.d2h_cycles)
        assert cs.busy_cycles == \
            cs.h2d_cycles + cs.compute_cycles + cs.d2h_cycles
        assert cs.busy_cycles >= co.busy_cycles
    assert rep_s.makespan_cycles >= rep_o.makespan_cycles


def test_sync_dma_strictly_slower_when_transfers_overlap_compute():
    # multi-tile shard: overlap hides everything but the first tile pair
    a, b = rand(1024, 4096), rand(4096, 256)
    _, rep_o = PIMRuntime(channels=2).gemm(a, b, execute=False)
    _, rep_s = PIMRuntime(channels=2, overlap=False).gemm(
        a, b, execute=False)
    assert rep_s.makespan_cycles > rep_o.makespan_cycles


# ---------------------------------------------------------------------------
# place() input validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    np.zeros(16, np.float16),                  # 1D
    np.zeros((2, 3, 4), np.float16),           # 3D
    (16,),                                     # 1-tuple shape
    (2, 3, 4),                                 # 3-tuple shape
])
def test_place_rejects_non_2d_with_clear_error(bad):
    rt = PIMRuntime(channels=2)
    with pytest.raises(ValueError, match="2D"):
        rt.place(bad)


def test_place_scalar_rejected():
    with pytest.raises(ValueError, match="2D"):
        PIMRuntime(channels=1).place(np.float16(3.0))


# ---------------------------------------------------------------------------
# ame_pim sharding rules
# ---------------------------------------------------------------------------


def test_ame_pim_layer_stacks_contiguous_balanced():
    assert ame_pim_layer_stacks(8, 4) == [0, 0, 1, 1, 2, 2, 3, 3]
    assert ame_pim_layer_stacks(5, 2) == [0, 0, 0, 1, 1]
    assert ame_pim_layer_stacks(2, 4) == [0, 1]
    assert ame_pim_layer_stacks(6, 1) == [0] * 6
    assert ame_pim_layer_stacks(0, 4) == []
    with pytest.raises(ValueError):
        ame_pim_layer_stacks(4, 0)


def test_ame_pim_layer_stacks_properties():
    for n in (1, 7, 16, 61):
        for stacks in (1, 2, 4, 8):
            m = ame_pim_layer_stacks(n, stacks)
            assert len(m) == n
            assert m == sorted(m)                       # contiguous blocks
            sizes = [m.count(s) for s in range(stacks)]
            if n >= stacks:
                assert min(sizes) >= 1                  # every stack used
                assert max(sizes) - min(sizes) <= 1     # near-equal
            else:
                assert sizes[:n] == [1] * n and sum(sizes) == n


def test_ame_pim_stack_map_covers_layers_and_experts():
    cfg = get("mixtral-8x22b")
    sm = ame_pim_stack_map(cfg, 4)
    assert len(sm["layers"]) == cfg.n_layers
    assert set(sm["experts"]) == set(range(4))
    dense = get("qwen3-1.7b")
    assert "experts" not in ame_pim_stack_map(dense, 2)


def test_ame_pim_tp_mode_shares_allgather_specs():
    from repro.sharding.rules import _base_rule

    ag = get("qwen3-1.7b").with_policy(tp_mode="allgather")
    pim = get("qwen3-1.7b").with_policy(tp_mode="ame_pim")
    for pstr in ("layers/attn/wo/w", "layers/mlp/wi/w", "head/w",
                 "embed/table", "layers/attn/wq/w"):
        assert _base_rule(pstr, ag) == _base_rule(pstr, pim), pstr


# ---------------------------------------------------------------------------
# multi-stack decode offload
# ---------------------------------------------------------------------------


def test_decode_offload_multi_stack_analytic():
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=4, stacks=4)
    for _ in range(3):
        rec = off.step(4)
    assert rec.reuse_bytes == off.weight_bytes     # weights amortized
    roof = off.roofline()
    assert roof["stacks"] == 4
    uploads = roof["upload_bytes_per_stack"]
    assert len(uploads) == 4 and all(u > 0 for u in uploads)
    assert sum(uploads) == off.upload_bytes
    assert roof["host_link_bytes"] == 0            # stack-restricted ops


def test_decode_offload_multi_stack_matches_single_stack_cycles():
    # stack-restricted ops use the same channels-wide decomposition, so
    # per-step pim cycles are identical to a single stack of that width
    cfg = get("qwen3-1.7b").reduced()
    off1 = DecodeOffload(cfg, channels=4, stacks=1)
    off4 = DecodeOffload(cfg, channels=4, stacks=4)
    r1, r4 = off1.step(2), off4.step(2)
    assert r1.pim_cycles == r4.pim_cycles
    assert r1.h2d_bytes == r4.h2d_bytes


def test_decode_offload_homes_whole_layer_on_one_stack():
    # one layer's attention, experts, and router share a home stack
    # (ame_pim layers map), lm_head follows the last layer
    cfg = get("mixtral-8x22b").reduced()
    assert cfg.moe is not None
    off = DecodeOffload(cfg, channels=4, stacks=2)
    homes = {m.name: [h for h, _ in handles]
             for m, handles in off.weights}
    active = cfg.moe.top_k + cfg.moe.n_shared
    for ell in range(cfg.n_layers):
        layer_home = homes["attn.wq"][ell]
        assert homes["attn.wo"][ell] == layer_home
        assert homes["moe.router"][ell] == layer_home
        for slot in range(active):
            assert homes["moe.expert.wi"][ell * active + slot] == layer_home
    assert homes["lm_head"] == [homes["attn.wq"][cfg.n_layers - 1]]
    rec = off.step(2)
    assert rec.reuse_bytes == off.weight_bytes
    assert off.roofline()["host_link_bytes"] == 0


@pytest.mark.slow
def test_decode_offload_multi_stack_numeric_crosschecks_xla():
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=4, stacks=4, numeric=True)
    rec = off.step(2)
    assert rec.numeric and rec.numeric_max_err < off.atol
    assert rec.logits_max_err < off.atol
    assert off.last_logits is not None
