"""Unit tests for the trip-count-aware HLO static analyzer."""
import textwrap

from repro.launch import hloanalysis as ha

HLO = textwrap.dedent("""\
    HloModule jit_step

    %add_comp (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %a = f32[] add(%x, %y)
    }

    %body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %arg = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %w = f32[8,16]{1,0} get-tuple-element(%arg), index=1
      %lhs = f32[8,4]{1,0} constant({...})
      %rhs = f32[4,16]{1,0} constant({...})
      %d = f32[8,16]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add_comp
      %s = f32[8,16]{1,0} add(%ar, %w)
      ROOT %t = (s32[], f32[8,16]) tuple(%i, %s)
    }

    %cond (arg: (s32[], f32[8,16])) -> pred[] {
      %arg = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %c = s32[] constant(7)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %i0 = s32[] constant(0)
      %tup = (s32[], f32[8,16]) tuple(%i0, %p0)
      %w = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
      %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
      %ag = f32[8,64]{1,0} all-gather(%out), replica_groups=[64,4]<=[256], dimensions={1}
      %sl = f32[8,16]{1,0} slice(%ag), slice={[0:8],[0:16]}
      ROOT %r = f32[8,16]{1,0} add(%sl, %out)
    }
    """)


def test_parse_and_multipliers():
    comps = ha.parse_hlo(HLO)
    assert "__entry__" in comps and comps["__entry__"].name.startswith("main")
    mult = ha._multipliers(comps)
    assert mult["body"] == 7.0          # known_trip_count
    assert mult["cond"] == 7.0
    assert mult[comps["__entry__"].name] == 1.0


def test_dot_flops_trip_count_scaled():
    rep = ha.analyze(HLO)
    # dot: 2 * (8*16) * 4 = 1024 flops, x7 loop passes
    assert rep.dot_flops == 7 * 1024


def test_collective_accounting():
    rep = ha.analyze(HLO)
    # all-reduce inside the loop: 2 * 512B * 15/16, x7
    ar = rep.collectives["all-reduce"]
    assert ar["count"] == 7
    assert abs(ar["link_bytes"] - 7 * 2 * 512 * 15 / 16) < 1e-6
    # all-gather at top level: out 8*64*4 = 2048B * 3/4, x1
    ag = rep.collectives["all-gather"]
    assert ag["count"] == 1
    assert abs(ag["link_bytes"] - 2048 * 3 / 4) < 1e-6


def test_elementwise_flops_counted():
    rep = ha.analyze(HLO)
    # adds: body 8*16 x7 + entry 8*16 (+ scalar add comp x ~counts)
    assert rep.flops >= 7 * 1024 + 7 * 128 + 128


def test_hbm_traffic_skips_control_ops():
    rep = ha.analyze(HLO)
    assert rep.hbm_bytes > 0
    # parameter/tuple/gte contribute nothing directly
    text_no_loop = HLO.replace('backend_config={"known_trip_count":{"n":"7"}}',
                               "")
    rep2 = ha.analyze(text_no_loop)
    assert rep2.unknown_trip_loops == 1   # trip count now unknown
    assert rep2.dot_flops == 1024         # counted once


def test_memmodel_all_cells_estimable():
    from repro.configs import SHAPES, all_names, applicable, get
    from repro.launch import memmodel
    for name in all_names():
        cfg = get(name)
        for shape in SHAPES.values():
            if not applicable(cfg, shape)[0]:
                continue
            est = memmodel.estimate(cfg, shape)
            assert est["total"] > 0
            assert est["fits_16g"], (name, shape.name,
                                     est["total"] / 2 ** 30)
