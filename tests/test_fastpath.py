"""Fast-vs-reference equivalence for the execution fast paths.

Pins the PR's core contracts:
* the batched numeric executors (whole-shard jitted scan) are bit-exact
  with the per-tile ``gemm_on_engine``/``ew_on_engine`` walk;
* closed-form analytic ledgers (cycles/flops/commands/bytes) exactly
  match the generator-walk ledgers across ragged shapes, all placements,
  1/4/16 channels;
* traces emitted from the fast paths are byte-identical to the
  reference paths' (ShardSpan expansion);
* placement shard decomposition is memoized;
* RuntimeReport.summary() survives an empty per_channel tuple;
* DecodeOffload(numeric=True) logits match the XLA decode path within
  FP16 accumulation tolerance and charge the analytic sidecar's ledgers.
"""
import numpy as np
import pytest

from repro.core import cost as cost_mod
from repro.core.engine import (
    AMEEngine,
    ShardSpan,
    ew_on_engine,
    ew_on_engine_batched,
    ew_tiles,
    gemm_on_engine,
    gemm_on_engine_batched,
    gemm_tiles,
)
from repro.runtime import (
    PLACEMENTS,
    PIMRuntime,
    RuntimeReport,
    get_placement,
    pim_gemm,
    pim_gemv,
    placement_shards,
)
from repro.runtime.trace import emit_trace, parse_trace

RNG = np.random.default_rng(19)


def rand(m, n, scale=0.2):
    return (RNG.standard_normal((m, n)) * scale).astype(np.float16)


def ledgers(rep):
    return [(c.channel, c.compute_cycles, c.flops, c.commands,
             c.h2d_bytes, c.d2h_bytes, c.h2d_cycles, c.d2h_cycles,
             c.lead_in_cycles, c.reuse_bytes, c.dedupe_bytes)
            for c in rep.per_channel]


# ---------------------------------------------------------------------------
# closed-form shard costs == generator-walk sums, exactly
# ---------------------------------------------------------------------------

GEMM_SHARDS = [
    (1, 1, 1),
    (127, 7, 1),
    (128, 4096, 128),       # the paper's max tile, exactly one class
    (129, 4097, 2),         # ragged edge on every axis
    (256, 8192, 129),
    (1000, 100, 7),
    (512, 4096, 512),
]


@pytest.mark.parametrize("rows,ks,ns", GEMM_SHARDS)
def test_gemm_shard_cost_equals_tile_walk(rows, ks, ns):
    walk = [cost_mod.mfmacc_cost(i1 - i0, c1 - c0, j1 - j0)
            for i0, i1, j0, j1, c0, c1 in gemm_tiles(rows, ks, ns)]
    agg = cost_mod.gemm_shard_cost(rows, ks, ns)
    assert agg.launches == sum(r.launches for r in walk)
    assert agg.passes == sum(r.passes for r in walk)
    assert agg.commands == sum(r.commands for r in walk)
    assert agg.flops == sum(r.flops for r in walk)
    assert agg.cycles == sum(r.cycles for r in walk)   # exact, not approx


@pytest.mark.parametrize("kind", ["add", "sub", "mul"])
@pytest.mark.parametrize("rows,cols", [(1, 1), (127, 4097), (128, 2048),
                                       (300, 96), (1000, 8200)])
def test_ew_shard_cost_equals_tile_walk(kind, rows, cols):
    walk = [cost_mod.elementwise_cost(kind, i1 - i0, c1 - c0)
            for i0, i1, c0, c1 in ew_tiles(rows, cols)]
    agg = cost_mod.ew_shard_cost(kind, rows, cols)
    assert agg.launches == sum(r.launches for r in walk)
    assert agg.commands == sum(r.commands for r in walk)
    assert agg.flops == sum(r.flops for r in walk)
    assert agg.cycles == sum(r.cycles for r in walk)


def test_shard_span_expands_to_walk_records():
    span = ShardSpan("mac", 300, 4200, 130)
    recs = list(span.records())
    walk = [(i1 - i0, c1 - c0, j1 - j0)
            for i0, i1, j0, j1, c0, c1 in gemm_tiles(300, 4200, 130)]
    assert [(r.m, r.k, r.n) for r in recs] == walk
    span = ShardSpan("sub", 300, 4200)
    assert [(r.m, r.k) for r in span.records()] == \
        [(i1 - i0, c1 - c0) for i0, i1, c0, c1 in ew_tiles(300, 4200)]


# ---------------------------------------------------------------------------
# engine-level: batched executors bit-exact + ledger-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 64, 32), (300, 520, 130),
                                   (129, 4097, 2), (64, 8, 1)])
def test_engine_batched_gemm_bit_exact(m, k, n):
    a, b = rand(m, k), rand(k, n)
    e1, e2 = AMEEngine(), AMEEngine()
    out_t = gemm_on_engine(e1, a, b)
    out_b = gemm_on_engine_batched(e2, a, b)
    np.testing.assert_array_equal(out_t, out_b)
    assert e1.total_cycles == e2.total_cycles
    assert e1.total_flops == e2.total_flops
    assert e1.total_commands == e2.total_commands
    assert sum(r.launches for r in e1.log) == \
        sum(r.launches for r in e2.log)


@pytest.mark.parametrize("kind", ["add", "sub", "mul"])
def test_engine_batched_ew_bit_exact(kind):
    a, b = rand(300, 4200), rand(300, 4200)
    e1, e2 = AMEEngine(), AMEEngine()
    out_t = ew_on_engine(e1, kind, a, b)
    out_b = ew_on_engine_batched(e2, kind, a, b)
    np.testing.assert_array_equal(out_t, out_b)
    assert e1.total_cycles == e2.total_cycles
    assert e1.total_commands == e2.total_commands


# ---------------------------------------------------------------------------
# runtime-level: fast paths vs reference across placements / channels
# ---------------------------------------------------------------------------

SHAPES = [(128, 64, 32), (300, 520, 130), (256, 2048, 1), (1000, 100, 7)]


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
@pytest.mark.parametrize("channels", [1, 4, 16])
def test_runtime_batched_gemm_bit_exact_and_ledger_parity(placement,
                                                          channels):
    for m, k, n in SHAPES:
        a, b = rand(m, k), rand(k, n)
        out_t, rep_t = PIMRuntime(channels, engine="tiled").gemm(
            a, b, placement=placement)
        out_b, rep_b = PIMRuntime(channels, engine="batched").gemm(
            a, b, placement=placement)
        np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_b))
        assert ledgers(rep_t) == ledgers(rep_b), (placement, channels,
                                                  (m, k, n))
        assert rep_t.makespan_cycles == rep_b.makespan_cycles


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
@pytest.mark.parametrize("channels", [1, 4, 16])
def test_analytic_closed_form_ledger_parity(placement, channels):
    for m, k, n in SHAPES + [(512, 4096, 512)]:
        a = np.zeros((m, k), np.float16)
        b = np.zeros((k, n), np.float16)
        _, rep_w = PIMRuntime(channels, engine="tiled").gemm(
            a, b, placement=placement, execute=False)
        _, rep_c = PIMRuntime(channels, engine="batched").gemm(
            a, b, placement=placement, execute=False)
        assert ledgers(rep_w) == ledgers(rep_c), (placement, channels,
                                                  (m, k, n))


@pytest.mark.parametrize("kind", ["add", "sub", "mul"])
def test_runtime_batched_elementwise_parity(kind):
    a, b = rand(300, 96), rand(300, 96)
    out_t, rep_t = PIMRuntime(4, engine="tiled").elementwise(kind, a, b)
    out_b, rep_b = PIMRuntime(4, engine="batched").elementwise(kind, a, b)
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_b))
    assert ledgers(rep_t) == ledgers(rep_b)
    _, rep_wa = PIMRuntime(4, engine="tiled").elementwise(
        kind, a, b, execute=False)
    _, rep_ca = PIMRuntime(4, engine="batched").elementwise(
        kind, a, b, execute=False)
    assert ledgers(rep_wa) == ledgers(rep_ca) == ledgers(rep_t)


def test_gemv_batched_matches_tiled():
    a, x = rand(1000, 2048, 0.1), rand(2048, 1, 0.1)[:, 0]
    y_t, rep_t = pim_gemv(a, x, channels=16, placement="balanced",
                          engine="tiled")
    y_b, rep_b = pim_gemv(a, x, channels=16, placement="balanced",
                          engine="batched")
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_b))
    assert ledgers(rep_t) == ledgers(rep_b)


def test_residency_paths_identical_across_engines():
    """Resident-handle ops charge the same ledgers and stay bit-exact on
    both executors (the decode steady-state regime)."""
    a, x = rand(256, 2048, 0.1), rand(2048, 1, 0.1)[:, 0]
    outs, reps = [], []
    for mode in ("tiled", "batched"):
        rt = PIMRuntime(16, engine=mode)
        w = rt.place(a, placement="balanced")
        rt.gemv(w, x, placement="balanced")           # warm: marks resident
        y, rep = rt.gemv(w, x, placement="balanced")  # steady state
        outs.append(np.asarray(y))
        reps.append(rep)
    np.testing.assert_array_equal(*outs)
    assert ledgers(reps[0]) == ledgers(reps[1])
    assert reps[0].total_reuse_bytes == reps[1].total_reuse_bytes > 0


# ---------------------------------------------------------------------------
# traces: fast paths emit byte-identical command streams
# ---------------------------------------------------------------------------

def test_trace_byte_identical_across_paths():
    a, b = rand(200, 4100, 0.1), rand(4100, 24, 0.1)
    texts = {}
    for tag, (mode, execute) in {
            "tiled": ("tiled", True), "batched": ("batched", True),
            "analytic": ("batched", False)}.items():
        rt = PIMRuntime(2, engine=mode)
        rt.gemm(a, b, execute=execute)
        rt.elementwise("sub", rand(140, 40), rand(140, 40),
                       execute=execute)
        texts[tag] = emit_trace(rt.stack)
    assert texts["tiled"] == texts["batched"] == texts["analytic"]
    stats = parse_trace(texts["batched"])
    assert stats.pim_commands > 0 and stats.launches > 0


# ---------------------------------------------------------------------------
# satellites: memoized placements, summary() guard
# ---------------------------------------------------------------------------

def test_placement_shards_memoized_and_correct():
    s1 = placement_shards("balanced", 640, 512, 4, 16)
    s2 = placement_shards("balanced", 640, 512, 4, 16)
    assert s1 is s2                       # cache hit returns same tuple
    assert isinstance(s1, tuple)
    assert list(s1) == get_placement("balanced")(640, 512, 4, 16)
    s3 = placement_shards("balanced", 640, 512, 5, 16)
    assert s3 is not s1


def test_summary_survives_empty_per_channel():
    rep = RuntimeReport(op="gemm", shape=(0, 0, 0), placement="row-striped",
                        channels=0, per_channel=())
    text = rep.summary()
    assert "makespan=0" in text
    assert rep.flop_per_cycle == 0.0


def test_runtime_rejects_unknown_engine():
    with pytest.raises(AssertionError):
        PIMRuntime(1, engine="warp")
    with pytest.raises(AssertionError):
        PIMRuntime(1).gemm(rand(8, 8), rand(8, 4), engine="warp")


# ---------------------------------------------------------------------------
# numeric decode-on-PIM (the unlocked ROADMAP item)
# ---------------------------------------------------------------------------

def test_decode_offload_numeric_logits_match_xla():
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=16, placement="balanced",
                        numeric=True)
    ana = DecodeOffload(cfg, channels=16, placement="balanced")
    for _ in range(2):
        rn, ra = off.step(4), ana.step(4)
        # logits within FP16 accumulation tolerance of the XLA path
        assert rn.numeric and rn.logits_max_err < 1e-2
        assert rn.numeric_max_err < 1e-2
        # identical ledgers to the accounting-only sidecar
        assert (rn.pim_cycles, rn.h2d_bytes, rn.d2h_bytes, rn.reuse_bytes,
                rn.flops) == (ra.pim_cycles, ra.h2d_bytes, ra.d2h_bytes,
                              ra.reuse_bytes, ra.flops)
    assert off.last_logits is not None
    assert off.last_logits.shape == (cfg.vocab_padded, 4)
    # steady state: weights fully amortized on the numeric path too
    assert off.steps[-1].reuse_bytes == off.weight_bytes


def test_decode_offload_numeric_rejects_large_configs():
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    with pytest.raises(ValueError):
        DecodeOffload(get("qwen3-1.7b"), numeric=True)


def test_decode_offload_numeric_detects_divergence():
    """The cross-check actually fires: corrupt a resident weight mirror
    and the next numeric step must raise.

    The XLA reference is cached per (weight, batch) key — a repeat of
    the same batch replays the pre-sabotage cache — so the divergence
    must surface on the first *fresh* key: a step with a new batch.
    """
    from repro.configs import get
    from repro.serve import offload as offload_mod
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=4, placement="balanced", numeric=True)
    off.step(2)
    ref = DecodeOffload._xla_reference
    try:
        # sabotage the XLA reference, not the shared mirror
        DecodeOffload._xla_reference = staticmethod(
            lambda w, x: ref(w, x) + 1.0)
        off.step(2)                  # same batch: cached refs still match
        with pytest.raises(AssertionError):
            off.step(3)              # fresh (weight, batch) key recomputes
    finally:
        DecodeOffload._xla_reference = staticmethod(ref)
        # the failing step cached sabotaged references under the real
        # content keys before its assert fired — evict them so later
        # numeric offloads over the same seeded weights stay clean
        offload_mod._REF_CACHE.clear()
