"""Production-traffic serving under load (PR 9).

Covers the traffic layer (:mod:`repro.serve.traffic`: virtual clocks,
seeded Poisson/bursty arrival traces + the replayable JSON format, the
host prefill cost model, SLOs, autoscaling policies), the virtual-time
stamping of :class:`repro.serve.loop.Server`, and the disaggregated
:class:`repro.serve.loop.TrafficServer` — prefill/decode phase layouts,
host-link contention windows, admission control, autoscaling, SLO
goodput accounting, and strict zero-traffic additivity.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.loop import Request, Server, TrafficServer
from repro.serve.offload import DecodeOffload
from repro.serve.traffic import (
    SLO,
    HostCostModel,
    QueueProportionalSlots,
    SimClock,
    SLOFeedbackSlots,
    StaticSlots,
    Trace,
    TraceRequest,
    WallClock,
    bursty_trace,
    poisson_trace,
)

RNG = np.random.default_rng(0)


def _small():
    return get("qwen3-1.7b").reduced()


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------


def test_sim_clock_monotonic():
    c = SimClock()
    assert c.now == 0.0
    assert c.advance(1.5) == 1.5
    assert c.advance_to(1.0) == 1.5      # no-op: already past
    assert c.advance_to(3.0) == 3.0
    with pytest.raises(ValueError):
        c.advance(-0.1)


def test_wall_clock_tracks_time():
    import time
    c = WallClock()
    t0 = time.time()
    c.advance(1e6)                       # a no-op: wall time is its own
    assert abs(c.now - t0) < 5.0


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------


def test_poisson_trace_seeded_and_sorted():
    a = poisson_trace(10.0, 200, seed=3)
    b = poisson_trace(10.0, 200, seed=3)
    assert a == b                        # same seed -> identical trace
    assert a != poisson_trace(10.0, 200, seed=4)
    ats = [r.at_s for r in a]
    assert ats == sorted(ats)
    assert len(a) == 200
    # empirical rate within 25% of nominal at n=200
    assert a.arrival_rate_rps == pytest.approx(10.0, rel=0.25)


def test_bursty_trace_burstier_than_poisson():
    def cv(tr):
        gaps = np.diff([r.at_s for r in tr])
        return gaps.std() / gaps.mean()

    p = poisson_trace(5.0, 500, seed=1)
    b = bursty_trace(5.0, 500, cv=3.0, seed=1)
    assert cv(b) > 1.5 * cv(p)           # Gamma cv=3 vs Poisson cv=1
    assert b.arrival_rate_rps == pytest.approx(5.0, rel=0.35)
    assert b.meta["cv"] == 3.0


def test_trace_lengths_ranges_and_validation():
    tr = poisson_trace(2.0, 64, seed=5, prompt_len=(16, 64),
                       max_new=(4, 8))
    assert all(16 <= r.prompt_len <= 64 for r in tr)
    assert all(4 <= r.max_new <= 8 for r in tr)
    assert len({r.prompt_len for r in tr}) > 1
    with pytest.raises(ValueError):
        poisson_trace(0.0, 4)
    with pytest.raises(ValueError):
        bursty_trace(1.0, 4, cv=-1.0)


def test_trace_save_load_round_trip(tmp_path):
    tr = bursty_trace(3.0, 32, cv=2.0, seed=9, prompt_len=(8, 16))
    path = tmp_path / "trace.json"
    tr.save(str(path))
    back = Trace.load(str(path))
    assert back == tr
    rec = json.loads(path.read_text())
    assert rec["meta"]["kind"] == "bursty" and rec["meta"]["seed"] == 9


# ---------------------------------------------------------------------------
# host cost model, SLOs, autoscalers
# ---------------------------------------------------------------------------


def test_host_cost_model_rooflines():
    cost = HostCostModel(get("qwen3-1.7b"))
    assert cost.prefill_s(1) > 0         # weight read is a hard floor
    assert cost.prefill_s(65536) > 4 * cost.prefill_s(64)
    assert cost.kv_ship_bytes(100) == 100 * cost.kv_bytes_per_token
    assert cost.decode_step_s(1) > 0


def test_host_cost_model_generic_fallback():
    class Odd:                           # family outside decode_matmuls
        family = "ssm"
        d_model, n_layers, vocab_size = 256, 4, 1000
    cost = HostCostModel(Odd())
    assert cost.weight_bytes > 0 and cost.flops_per_token > 0
    assert cost.prefill_s(128) > 0


def test_slo_met():
    slo = SLO(ttft_s=1.0, tpot_s=0.1)
    assert slo.met(0.5, 0.05)
    assert slo.met(0.5, None)            # single-token: TTFT only
    assert not slo.met(1.5, 0.05)
    assert not slo.met(0.5, 0.2)


def test_autoscaling_policies():
    st = StaticSlots(slots=6)
    assert st.target(queue_len=99, slots=2, live=0, recent_ttft=[]) == 6
    qp = QueueProportionalSlots(min_slots=2, max_slots=8, per_queue=4)
    assert qp.target(queue_len=0, slots=2, live=0, recent_ttft=[]) == 2
    assert qp.target(queue_len=8, slots=2, live=0, recent_ttft=[]) == 4
    assert qp.target(queue_len=999, slots=2, live=0, recent_ttft=[]) == 8
    fb = SLOFeedbackSlots(SLO(ttft_s=1.0, tpot_s=0.1),
                          min_slots=1, max_slots=4)
    grow = fb.target(queue_len=1, slots=2, live=2, recent_ttft=[2.0])
    assert grow == 3                     # tail violates -> +1
    shrink = fb.target(queue_len=0, slots=2, live=1, recent_ttft=[0.2])
    assert shrink == 1                   # comfortably inside -> -1
    hold = fb.target(queue_len=0, slots=2, live=1, recent_ttft=[0.8])
    assert hold == 2


# ---------------------------------------------------------------------------
# Request / Server virtual-time satellites
# ---------------------------------------------------------------------------


def test_request_eq_is_identity_not_ndarray():
    a = Request(uid=1, prompt=np.zeros(4, np.int32))
    b = Request(uid=1, prompt=np.zeros(4, np.int32))
    assert a != b and a == a
    assert a in [b, a]                   # no "truth value is ambiguous"


def _xla_server(**kw):
    import jax

    from repro.models import model as lm

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    off = DecodeOffload(cfg, channels=4, numeric=True, kv_offload=True)
    return Server(cfg, params, cache_len=48, pim_offload=off, **kw), off


def _drive(srv, n=3, max_new=4):
    rng = np.random.default_rng(7)
    for uid in range(n):
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(1, 127, 6).astype(np.int32),
                           max_new=max_new))
    srv.run_until_drained()
    return srv.latency_summary()


def test_server_virtual_clock_deterministic():
    sa = _drive(_xla_server(slots=2)[0])
    sb = _drive(_xla_server(slots=2)[0])
    assert sa == sb                      # virtual time: bit-identical
    assert sa["ttft_s"]["count"] == 3 and sa["ttft_s"]["p50"] > 0
    # every percentile block carries the serving tail + queue delay
    for key in ("ttft_s", "tpot_s", "queue_delay_s"):
        assert "p99.9" in sa[key], key
    assert sa["queue_delay_s"]["max"] > 0    # 3 reqs through 2 slots


def test_server_wall_escape_hatch():
    import time
    srv, _ = _xla_server(slots=2, wall=True)
    t0 = time.time()
    _drive(srv, n=1)
    req = srv.completed[0]
    assert t0 <= req.submitted_at <= req.first_token_at \
        <= req.finished_at <= time.time()


def test_serve_fault_kv_released_before_retry_prefill():
    """Slot knock-out under load: the faulted request's paged KV must be
    fully released before its retry re-prefills — no leaked pages, and
    resident bytes return to baseline after the drain."""
    srv, off = _xla_server(slots=2, faults="fail slot 0 @ iter 2")
    assert off.kv.resident_kv_bytes == 0     # baseline
    reprefilled_with_live_kv = []
    orig = off.kv_prefill

    def spy(rid, tokens, **kw):
        if rid in off.kv._reqs:              # KV leaked across the retry
            reprefilled_with_live_kv.append(rid)
        return orig(rid, tokens, **kw)

    off.kv_prefill = spy
    rng = np.random.default_rng(11)
    for uid in range(4):
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(1, 127, 6).astype(np.int32),
                           max_new=6))
    srv.run_until_drained()
    assert srv.retries_total >= 1            # the fault actually fired
    assert len(srv.completed) == 4
    assert reprefilled_with_live_kv == []
    assert len(off.kv._reqs) == 0
    assert off.kv.resident_kv_bytes == 0     # back to baseline


# ---------------------------------------------------------------------------
# TrafficServer: disaggregated virtual-time load serving
# ---------------------------------------------------------------------------


def _offload(**kw):
    kw.setdefault("channels", 4)
    return DecodeOffload(_small(), **kw)


def test_traffic_server_drains_and_counts():
    tr = poisson_trace(50.0, 40, seed=2, prompt_len=64, max_new=4)
    srv = TrafficServer(_offload(), slots=4, chunk_tokens=32)
    done = srv.run(tr)
    assert len(done) == 40
    s = srv.latency_summary()
    assert s["requests"] == 40 and s["shed"] == 0
    assert s["tokens"] == 40 * 4
    assert s["throughput_rps"] > 0
    assert s["link_prefill_bytes"] > 0       # KV handoffs crossed the link
    assert s["link_acts_bytes"] > 0          # decode activations too
    ts = [r.finished_at for r in done]
    assert all(t > 0 for t in ts)


def test_traffic_server_seed_deterministic():
    def one():
        srv = TrafficServer(_offload(), slots=4, chunk_tokens=32,
                            slo=SLO(ttft_s=1.0, tpot_s=0.5))
        srv.run(poisson_trace(30.0, 60, seed=6, prompt_len=64, max_new=4))
        return srv.latency_summary()

    assert one() == one()


def test_disaggregated_beats_colocated():
    """Balanced prefill/decode load at paper scale (the benchmark's
    regime): the disaggregated layout overlaps the phases and must win
    on goodput; colocated decode stalls behind prefill chunks (larger
    worst inter-token gap)."""
    off = DecodeOffload(get("qwen3-1.7b"), channels=16)
    cost = HostCostModel(off.cfg)
    slots, max_new = 8, 16
    probe = off.step(slots)
    costs = {slots: (probe.pim_s, probe.h2d_bytes)}
    step_s = probe.pim_s
    # prompt sized so prefill work ~ decode work per request
    per_tok = cost.flops_per_token / cost.peak_flops
    prompt = max(512, int(max_new * step_s / slots / per_tok))
    slo = SLO(ttft_s=4 * cost.prefill_s(prompt), tpot_s=1.3 * step_s)
    cap = 1.0 / max(cost.prefill_s(prompt), max_new * step_s / slots)
    tr = poisson_trace(0.5 * cap, 80, seed=7, prompt_len=prompt,
                       max_new=max_new)
    res = {}
    for label, dis in (("disagg", True), ("colo", False)):
        srv = TrafficServer(off, slots=slots, disaggregate=dis,
                            chunk_tokens=2048, slo=slo, step_costs=costs)
        srv.run(tr)
        res[label] = srv.latency_summary()
    assert res["disagg"]["goodput_rps"] > res["colo"]["goodput_rps"]
    assert res["disagg"]["max_decode_gap_s"] \
        < res["colo"]["max_decode_gap_s"]


def test_bursty_goodput_no_better_than_poisson():
    """Equal offered load, cv~2 arrival clumping: burst queues blow the
    TTFT budget Poisson clears, so bursty goodput can only be <= the
    Poisson point's (a win would mean the scheduler rewards congestion)."""
    off = DecodeOffload(get("qwen3-1.7b"), channels=16)
    cost = HostCostModel(off.cfg)
    slots, max_new = 8, 16
    probe = off.step(slots)
    costs = {slots: (probe.pim_s, probe.h2d_bytes)}
    step_s = probe.pim_s
    per_tok = cost.flops_per_token / cost.peak_flops
    prompt = max(512, int(max_new * step_s / slots / per_tok))
    slo = SLO(ttft_s=4 * cost.prefill_s(prompt), tpot_s=1.3 * step_s)
    cap = 1.0 / max(cost.prefill_s(prompt), max_new * step_s / slots)
    res = {}
    for label, mk in (("poisson", poisson_trace),
                      ("bursty", lambda *a, **kw: bursty_trace(
                          *a, cv=2.0, **kw))):
        tr = mk(0.55 * cap, 80, seed=7, prompt_len=prompt, max_new=max_new)
        srv = TrafficServer(off, slots=slots, disaggregate=True,
                            chunk_tokens=2048, slo=slo, step_costs=costs)
        srv.run(tr)
        res[label] = srv.latency_summary()
    assert res["bursty"]["goodput_rps"] \
        <= res["poisson"]["goodput_rps"] + 1e-9
    assert res["bursty"]["slo_attainment"] \
        <= res["poisson"]["slo_attainment"] + 1e-9


def test_traffic_server_routing_observed():
    """A routed offload behind the traffic server exposes its observed
    expert histogram; a dense one exposes None."""
    from repro.serve.traffic import zipf_routing
    cfg = get("mixtral-8x22b").reduced()
    n_moe = cfg.n_layers - cfg.moe.first_dense_layers
    prof = zipf_routing(n_moe, cfg.moe.num_experts, 256, seed=4)
    off = DecodeOffload(cfg, channels=4, stacks=2, routing=prof,
                        replicate_experts=1)
    srv = TrafficServer(off, slots=2, chunk_tokens=32)
    srv.run(poisson_trace(20.0, 12, seed=3, prompt_len=32, max_new=3))
    assert srv.routing_observed is off.observed
    assert srv.routing_observed.total_tokens > 0
    dense = TrafficServer(_offload(), slots=2)
    assert dense.routing_observed is None


def test_colocated_chunking_bounds_decode_stall():
    """Smaller prefill chunks preempt less decode time per iteration:
    the worst inter-token gap must shrink with the chunk size."""
    off = _offload()
    tr = poisson_trace(8.0, 40, seed=8, prompt_len=512, max_new=6)
    gaps = {}
    for chunk in (512, 64):
        srv = TrafficServer(off, slots=4, disaggregate=False,
                            chunk_tokens=chunk)
        srv.run(tr)
        gaps[chunk] = srv.latency_summary()["max_decode_gap_s"]
    assert gaps[64] < gaps[512]


def test_admission_control_sheds_under_overload():
    off = _offload()
    tr = poisson_trace(10_000.0, 80, seed=4, prompt_len=256, max_new=4)
    srv = TrafficServer(off, slots=2, max_queue=8,
                        slo=SLO(ttft_s=1e-6, tpot_s=1e-6))
    srv.run(tr)
    s = srv.latency_summary()
    assert s["shed"] > 0
    assert s["requests"] + s["shed"] == 80
    assert len(srv.shed_requests) == s["shed"]
    # shed arrivals count as SLO misses from the client's side
    assert s["slo_attainment"] <= s["requests"] / 80


def test_autoscaler_grows_slots_under_queue_pressure():
    off = _offload()
    tr = poisson_trace(5000.0, 60, seed=5, prompt_len=128, max_new=4)
    srv = TrafficServer(off, slots=1, chunk_tokens=64,
                        autoscale=QueueProportionalSlots(
                            min_slots=1, max_slots=6, per_queue=4))
    srv.run(tr)
    assert srv.slots_max_seen > 1        # pressure grew the fleet
    assert srv.slots_max_seen <= 6
    assert len(srv.completed) == 60


def test_slo_feedback_autoscaler_reacts():
    off = _offload()
    cost = HostCostModel(off.cfg)
    slo = SLO(ttft_s=2 * cost.prefill_s(128), tpot_s=1.0)
    tr = poisson_trace(100.0, 50, seed=3, prompt_len=128, max_new=4)
    srv = TrafficServer(off, slots=1, chunk_tokens=64, slo=slo,
                        autoscale=SLOFeedbackSlots(
                            slo, min_slots=1, max_slots=8))
    srv.run(tr)
    assert srv.slots_max_seen > 1
    assert len(srv.completed) == 50


def test_zero_traffic_additivity():
    """The traffic layer off must be byte-free: ==-equal link ledgers,
    h2d ledgers, step records, and byte-identical traces."""
    def run(wrap: bool):
        off = DecodeOffload(_small(), channels=4, stacks=2)
        if wrap:
            srv = TrafficServer(off, slots=2)
            srv.run(poisson_trace(1.0, 0, seed=0))
        for _ in range(3):
            off.step(2)
        return (off.rt.stack.link,
                [d.xfer.h2d_bytes for d in off.rt.stack],
                [dataclasses.asdict(s) for s in off.steps],
                emit_trace(off.rt.stack))

    assert run(False) == run(True)


def test_traffic_link_events_land_in_cluster_trace():
    """On a multi-stack offload the handoff windows charge the cluster's
    own ledger, so they serialize into its trace and parse back."""
    off = DecodeOffload(_small(), channels=4, stacks=2)
    srv = TrafficServer(off, slots=2, chunk_tokens=32)
    srv.run(poisson_trace(20.0, 8, seed=1, prompt_len=64, max_new=3))
    kinds = {k for k, _ in off.rt.stack.link.events}
    assert {"prefill", "acts"} <= kinds
    text = emit_trace(off.rt.stack)
    assert "# HOSTLINK prefill" in text and "# HOSTLINK acts" in text
    parse_trace(text)                    # round-trips without error


def test_traffic_server_kv_lifecycle():
    """With a kv_offload sidecar the handoff/release hooks run for real:
    exact stepping is forced and resident KV returns to zero."""
    off = DecodeOffload(_small(), channels=4, kv_offload=True)
    srv = TrafficServer(off, slots=2, chunk_tokens=32)
    assert not srv.cache_steps           # stateful KV -> exact stepping
    srv.run(poisson_trace(20.0, 6, seed=2, prompt_len=16, max_new=3))
    assert len(srv.completed) == 6
    assert off.kv.resident_kv_bytes == 0
    assert len(off.kv._reqs) == 0
    assert off.kv.append_bytes > 0


def test_traffic_server_rejects_async_offload():
    off = DecodeOffload(_small(), channels=4, stacks=2, async_mode=True)
    with pytest.raises(ValueError):
        TrafficServer(off)
