"""Operand-residency tests: resident-handle bit-exactness vs the
fresh-transfer path, ledger invariants (reuse charges zero h2d), trace
round-trips of resident-reuse events, gemv vector-transfer dedupe, output
residency / epilogue fusion, and the serve decode offload."""
import json

import numpy as np
import pytest

from repro.configs import get
from repro.runtime import (
    DeviceTensor,
    ChannelReport,
    PIMRuntime,
    PLACEMENTS,
    RuntimeReport,
    pim_gemv,
)
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.offload import DecodeOffload, decode_matmuls

RNG = np.random.default_rng(11)


def rand(*shape, scale=0.15):
    return (RNG.standard_normal(shape) * scale).astype(np.float16)


# ---------------------------------------------------------------------------
# bit-exactness: resident handles never change numerics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
@pytest.mark.parametrize("channels", [1, 4, 16])
def test_resident_gemv_bit_exact_all_placements(placement, channels):
    a, x = rand(256, 512), rand(512)
    y_fresh, _ = pim_gemv(a, x, channels=channels, placement=placement)
    rt = PIMRuntime(channels=channels)
    w = rt.place(a, placement=placement)
    for _ in range(2):                       # first use and steady state
        y_res, _ = rt.gemv(w, x, placement=placement)
        np.testing.assert_array_equal(np.asarray(y_fresh),
                                      np.asarray(y_res))


@pytest.mark.parametrize("placement", sorted(PLACEMENTS))
def test_resident_gemm_and_elementwise_bit_exact(placement):
    a, b = rand(384, 160), rand(160, 96)
    c, d = rand(384, 160), rand(384, 160)
    fresh = PIMRuntime(channels=4)
    res = PIMRuntime(channels=4)
    wa = res.place(a, placement=placement, other_dim=96)
    out_f, _ = fresh.gemm(a, b, placement=placement)
    out_r, _ = res.gemm(wa, b, placement=placement)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_r))
    wc = res.place(c, placement=placement)
    ew_f, _ = fresh.elementwise("mul", c, d, placement=placement)
    ew_r, _ = res.elementwise("mul", wc, d, placement=placement)
    np.testing.assert_array_equal(np.asarray(ew_f), np.asarray(ew_r))


# ---------------------------------------------------------------------------
# ledger invariants
# ---------------------------------------------------------------------------


def test_resident_reuse_charges_zero_h2d():
    a, x = rand(512, 1024), rand(1024)
    rt = PIMRuntime(channels=4)
    w = rt.place(a, placement="balanced")
    upload = sum(dev.xfer.h2d_bytes for dev in rt.stack)
    assert upload == a.size * 2               # the one-time weight upload
    _, rep1 = rt.gemv(w, x, placement="balanced")
    _, rep2 = rt.gemv(w, x, placement="balanced")
    for rep in (rep1, rep2):
        # steady state: only the x slices move; residency reuse is exactly
        # the weight bytes (within-op x dedupe is accounted separately)
        assert rep.total_h2d_bytes < a.size * 2
        assert rep.total_reuse_bytes == a.size * 2
    assert rep1.total_h2d_bytes == rep2.total_h2d_bytes
    # the device ledgers saw no weight re-transfer after placement
    assert sum(dev.xfer.h2d_bytes for dev in rt.stack) \
        == upload + rep1.total_h2d_bytes + rep2.total_h2d_bytes


def test_resident_h2d_drop_matches_reuse():
    """Fresh h2d - resident h2d == residency reuse, at every channel
    count; within-op x dedupe is identical on both paths."""
    a, x = rand(256, 768), rand(768)
    for ch in (1, 4, 16):
        fresh, res = PIMRuntime(channels=ch), PIMRuntime(channels=ch)
        w = res.place(a, placement="balanced")
        _, rf = fresh.gemv(a, x, placement="balanced")
        _, rr = res.gemv(w, x, placement="balanced")
        assert rf.total_h2d_bytes - rr.total_h2d_bytes \
            == rr.total_reuse_bytes
        assert rf.total_reuse_bytes == 0
        assert rr.total_dedupe_bytes == rf.total_dedupe_bytes
        assert rr.total_d2h_bytes == rf.total_d2h_bytes


def test_lazy_miss_becomes_resident():
    """A handle used without place() ships once, then reuses."""
    a, x = rand(256, 256), rand(256)
    rt = PIMRuntime(channels=2)
    w = DeviceTensor(rt.stack, a.shape, values=a)
    _, rep1 = rt.gemv(w, x, placement="row-striped")
    _, rep2 = rt.gemv(w, x, placement="row-striped")
    assert rep1.total_h2d_bytes == a.size * 2 + 2 * x.size * 2  # both chans
    assert rep2.total_h2d_bytes == 2 * x.size * 2               # x only
    assert rep2.total_reuse_bytes == a.size * 2


def test_analytic_and_numeric_residency_charge_identical_ledgers():
    a, x = rand(384, 512), rand(512)
    rep_by_mode = {}
    for execute in (True, False):
        rt = PIMRuntime(channels=4)
        w = rt.place(a if execute else tuple(a.shape), placement="balanced")
        rt.gemv(w, x, placement="balanced", execute=execute)
        _, rep = rt.gemv(w, x, placement="balanced", execute=execute)
        rep_by_mode[execute] = rep
    for cx, ca in zip(rep_by_mode[True].per_channel,
                      rep_by_mode[False].per_channel):
        assert cx.h2d_bytes == ca.h2d_bytes
        assert cx.reuse_bytes == ca.reuse_bytes
        assert cx.dedupe_bytes == ca.dedupe_bytes
        assert cx.compute_cycles == ca.compute_cycles
    assert rep_by_mode[True].makespan_cycles \
        == rep_by_mode[False].makespan_cycles


def test_analytic_handle_rejects_numeric_execution():
    rt = PIMRuntime(channels=2)
    w = rt.place((128, 128), placement="row-striped")
    with pytest.raises(AssertionError):
        rt.gemv(w, rand(128), placement="row-striped")


def test_place_snapshots_values_against_host_mutation():
    """Resident data cannot change without a transfer: mutating the
    source array after place() must not leak into the device copy."""
    a, x = rand(128, 128), rand(128)
    rt = PIMRuntime(channels=2)
    w = rt.place(a, placement="row-striped")
    expected, _ = PIMRuntime(channels=2).gemv(a, x, placement="row-striped")
    a *= 2                                    # host-side mutation
    y, rep = rt.gemv(w, x, placement="row-striped")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(expected))
    assert rep.total_reuse_bytes == a.size * 2


def test_evict_drops_residency_and_forces_retransfer():
    a, x = rand(256, 256), rand(256)
    rt = PIMRuntime(channels=2)
    w = rt.place(a, placement="row-striped")
    assert rt.stack.resident_bytes == a.size * 2
    w.evict()
    assert rt.stack.resident_bytes == 0
    _, rep = rt.gemv(w, x, placement="row-striped")
    assert rep.total_h2d_bytes == a.size * 2 + 2 * x.size * 2


def test_handle_rejects_foreign_runtime():
    """A handle placed on one runtime must not claim residency (or crash
    on channel-count mismatch) on another."""
    a, x = rand(128, 128), rand(128)
    rt_a = PIMRuntime(channels=4)
    w = rt_a.place(a, placement="row-striped")
    for ch in (4, 16):
        with pytest.raises(AssertionError):
            PIMRuntime(channels=ch).gemv(w, x, placement="row-striped")


def test_place_role_b():
    a, b = rand(256, 128), rand(128, 64)
    rt = PIMRuntime(channels=4)
    wb = rt.place(b, placement="row-striped", role="B", other_dim=256)
    out_r, rep = rt.gemm(a, wb, placement="row-striped")
    out_f, _ = PIMRuntime(channels=4).gemm(a, b, placement="row-striped")
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_r))
    assert rep.total_reuse_bytes > 0          # B shards were resident


# ---------------------------------------------------------------------------
# gemv x-vector dedupe (plain arrays, within one op)
# ---------------------------------------------------------------------------


def test_gemv_vector_transfer_deduped_per_channel():
    """Balanced LPT puts several row blocks on one channel; the dense x
    vector must ship once per channel, not once per shard."""
    m, k, ch = 2048, 256, 4                   # 16 row blocks on 4 channels
    a, x = rand(m, k), rand(k)
    _, rep = pim_gemv(a, x, channels=ch, placement="balanced")
    # per channel: its A rows once + x exactly once
    for c in rep.per_channel:
        a_bytes = c.h2d_bytes - k * 2
        assert a_bytes % (k * 2) == 0         # whole row blocks
        assert c.dedupe_bytes > 0             # the deduped x re-ships
        assert c.reuse_bytes == 0             # no handles in play
    total_a = sum(c.h2d_bytes - k * 2 for c in rep.per_channel)
    assert total_a == m * k * 2


def test_gemv_dedupe_preserves_numerics_and_d2h():
    a, x = rand(2048, 256), rand(256)
    ref = a.astype(np.float32) @ x.astype(np.float32)
    y, rep = pim_gemv(a, x, channels=4, placement="balanced")
    np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                               atol=0.05, rtol=0.05)
    assert rep.total_d2h_bytes == 2048 * 2    # one fp16 y element per row


# ---------------------------------------------------------------------------
# output residency / epilogue fusion
# ---------------------------------------------------------------------------


def test_gemm_keep_output_defers_d2h_to_host_fetch():
    a, b = rand(256, 128), rand(128, 64)
    rt = PIMRuntime(channels=2)
    h, rep = rt.gemm(a, b, placement="row-striped", keep_output=True)
    assert isinstance(h, DeviceTensor)
    assert rep.total_d2h_bytes == 0
    before = sum(d.xfer.d2h_bytes for d in rt.stack)
    out = h.to_host()
    drained = sum(d.xfer.d2h_bytes for d in rt.stack) - before
    assert drained == 256 * 64 * 2
    ref, _ = PIMRuntime(channels=2).gemm(a, b, placement="row-striped")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert h.to_host() is not None            # second fetch charges nothing
    assert sum(d.xfer.d2h_bytes for d in rt.stack) - before == drained


def test_gemm_elementwise_epilogue_chains_resident():
    """GEMM -> add epilogue: the intermediate never crosses the host."""
    a, b = rand(256, 128), rand(128, 64)
    c = rand(256, 64)
    rt = PIMRuntime(channels=2)
    h, rep_g = rt.gemm(a, b, placement="row-striped", keep_output=True)
    out, rep_e = rt.elementwise("add", h, c, placement="row-striped")
    assert rep_g.total_d2h_bytes == 0
    assert rep_e.total_h2d_bytes == c.size * 2      # only the epilogue term
    assert rep_e.total_reuse_bytes == 256 * 64 * 2  # intermediate reused
    fresh = PIMRuntime(channels=2)
    g, _ = fresh.gemm(a, b, placement="row-striped")
    ref, _ = fresh.elementwise("add", g, c, placement="row-striped")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_elementwise_chain_keeps_intermediates_resident():
    """add -> mul -> sub chain: only the fresh operand of each op moves."""
    xs = [rand(256, 192) for _ in range(4)]
    rt = PIMRuntime(channels=4)
    h, rep = rt.elementwise("add", xs[0], xs[1], placement="row-striped",
                            keep_output=True)
    assert rep.total_d2h_bytes == 0
    for kind, nxt in (("mul", xs[2]), ("sub", xs[3])):
        h, rep = rt.elementwise(kind, h, nxt, placement="row-striped",
                                keep_output=True)
        assert rep.total_h2d_bytes == nxt.size * 2
        assert rep.total_reuse_bytes == nxt.size * 2
        assert rep.total_d2h_bytes == 0
    ref = (((xs[0] + xs[1]).astype(np.float16) * xs[2]).astype(np.float16)
           - xs[3]).astype(np.float16)
    np.testing.assert_array_equal(np.asarray(h.to_host()), ref)


def test_partial_shards_always_drain_under_keep_output():
    """K-split partials must round-trip for the host reduction even when
    the output is kept resident."""
    a, b = rand(128, 1024), rand(1024, 8)     # 1 row block, 16ch -> K-split
    rt = PIMRuntime(channels=16)
    h, rep = rt.gemm(a, b, placement="balanced", keep_output=True)
    assert rep.total_d2h_bytes > 0            # the partials
    ref, _ = PIMRuntime(channels=16).gemm(a, b, placement="balanced")
    np.testing.assert_array_equal(np.asarray(h.to_host()), np.asarray(ref))


# ---------------------------------------------------------------------------
# trace round-trip of resident-reuse events
# ---------------------------------------------------------------------------


def test_trace_roundtrips_resident_reuse_events():
    a, x = rand(256, 256), rand(256)
    rt = PIMRuntime(channels=2)
    w = rt.place(a, placement="row-striped")
    _, rep = rt.gemv(w, x, placement="row-striped")
    stats = parse_trace(emit_trace(rt.stack))
    # reuse shows up per channel with the avoided bytes, zero MEM lines
    # (the trace marker covers residency reuse and within-op dedupe)
    for c in rep.per_channel:
        assert stats.resident_bytes[c.channel] \
            == c.reuse_bytes + c.dedupe_bytes
        assert stats.resident_reuses[c.channel] == 1     # one A shard each
        # MEM writes = place upload + x slice, nothing for the reuse
        assert stats.mem_writes[c.channel] * 32 >= c.h2d_bytes
    assert sum(stats.resident_bytes.values()) == rep.total_reuse_bytes
    # the trace still parses as strict HBM-PIMulator grammar otherwise
    assert stats.pim_commands == rep.total_commands


def test_trace_reuse_lines_are_comment_shaped():
    """External replay tools must be able to ignore reuse markers."""
    rt = PIMRuntime(channels=1)
    w = rt.place(rand(128, 64), placement="row-striped")
    rt.gemv(w, rand(64), placement="row-striped")
    text = emit_trace(rt.stack)
    reuse_lines = [ln for ln in text.splitlines()
                   if ln.startswith("# RESIDENT")]
    assert reuse_lines and all(ln.startswith("#") for ln in reuse_lines)


# ---------------------------------------------------------------------------
# RuntimeReport degenerate-op guard
# ---------------------------------------------------------------------------


def test_flop_per_cycle_zero_makespan_guard():
    empty = RuntimeReport(op="gemm", shape=(0,), placement="row-striped",
                          channels=1, per_channel=())
    assert empty.makespan_cycles == 0.0
    assert empty.flop_per_cycle == 0.0        # used to ZeroDivisionError
    assert empty.gflops == 0.0
    idle = RuntimeReport(
        op="gemm", shape=(0,), placement="row-striped", channels=1,
        per_channel=(ChannelReport(
            channel=0, compute_cycles=0, flops=0, commands=0, h2d_bytes=0,
            d2h_bytes=0, h2d_cycles=0, d2h_cycles=0, lead_in_cycles=0),))
    assert idle.flop_per_cycle == 0.0
    assert idle.gflops == 0.0


# ---------------------------------------------------------------------------
# serve decode offload
# ---------------------------------------------------------------------------


def test_decode_offload_steady_state_activations_only():
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=16, placement="balanced")
    assert off.upload_bytes == off.weight_bytes
    recs = [off.step(4) for _ in range(3)]
    for rec in recs:
        assert rec.reuse_bytes == off.weight_bytes   # full amortization
        assert rec.h2d_bytes == recs[0].h2d_bytes    # activations, constant
        assert rec.h2d_bytes < off.weight_bytes
        assert rec.pim_s > 0 and rec.host_s > 0


def test_decode_offload_batch_scales_activations_not_weights():
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=8)
    r1, r4 = off.step(1), off.step(4)
    assert r4.h2d_bytes > r1.h2d_bytes           # more activation traffic
    assert r4.reuse_bytes == r1.reuse_bytes      # same resident weights
    assert r4.flops == 4 * r1.flops


def test_decode_offload_reuse_exact_at_one_channel():
    """Regression: with 1 channel, balanced LPT puts several full-K row
    blocks on the same channel, whose deduped x slices must NOT inflate
    the residency-reuse == weight-bytes invariant."""
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=1)
    rec = off.step(2)
    assert rec.reuse_bytes == off.weight_bytes


def test_decode_offload_roofline_skips_drain_tail():
    """The steady-state summary must come from the latest full-batch step,
    not the shrunken drain-tail batch."""
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=4)
    full = off.step(4)
    off.step(1)                                # drain tail
    roof = off.roofline()
    assert roof["steady_h2d_bytes"] == full.h2d_bytes
    assert len(roof["steps"]) == 2


def test_decode_offload_dump_artifact(tmp_path):
    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=4)
    off.step(2)
    p = tmp_path / "x.pim_offload.json"
    rec = off.dump(str(p))
    loaded = json.loads(p.read_text())
    assert loaded["steady_h2d_bytes"] == rec["steady_h2d_bytes"]
    assert loaded["arch"] == cfg.name
    assert len(loaded["steps"]) == 1


def test_decode_offload_rejects_unmodeled_families():
    with pytest.raises(ValueError):
        decode_matmuls(get("mamba2-370m").reduced())


def test_decode_offload_moe_counts_active_experts():
    cfg = get("mixtral-8x22b").reduced()
    mms = {m.name: m for m in decode_matmuls(cfg)}
    moe = cfg.moe
    n_moe = cfg.n_layers - moe.first_dense_layers
    assert mms["moe.expert.wi"].count \
        == n_moe * (moe.top_k + moe.n_shared)
    assert mms["moe.router"].count == n_moe


# ---------------------------------------------------------------------------
# capacity eviction edge cases (PIMDevice.add_resident / drop_resident)
# ---------------------------------------------------------------------------


def test_doomed_insert_refused_when_only_pinned_could_free_enough():
    from repro.runtime.device import PIMDevice
    dev = PIMDevice(0, capacity_bytes=1024)
    # 768 B pinned (an undrained kept output) + 128 B evictable
    assert dev.add_resident(1, (0, 12, 0, 32), pin=True)    # 768 B
    assert dev.add_resident(2, (0, 2, 0, 32))               # 128 B
    # a 512 B insert needs 384 B freed but only 128 B is evictable:
    # the insert must be refused WITHOUT evicting uid 2
    assert not dev.add_resident(3, (0, 8, 0, 32))           # 512 B
    assert sorted(dev.resident) == [1, 2]
    assert dev.spill_bytes == 0
    assert not any(k == "spill" for k, _ in dev.events)
    # uid 2 is still usable (was not collateral damage)
    assert dev.has_resident(2, (0, 2, 0, 32))


def test_drop_resident_of_pinned_uid_unpins_it():
    from repro.runtime.device import PIMDevice
    dev = PIMDevice(0, capacity_bytes=1024)
    assert dev.add_resident(1, (0, 4, 0, 32), pin=True)
    assert 1 in dev.pinned
    dev.drop_resident(1)
    assert 1 not in dev.resident and 1 not in dev.pinned
    # the slot is genuinely free again: a capacity-filling insert works
    assert dev.add_resident(2, (0, 16, 0, 32))              # 1024 B


def test_incoming_uid_self_eviction_counts_spill():
    from repro.runtime.device import PIMDevice
    dev = PIMDevice(0, capacity_bytes=1024)
    assert dev.add_resident(1, (0, 12, 0, 32))              # 768 B
    # same uid streams a second 768 B box: its own older box is the only
    # candidate (last resort) and must be charged as spill
    assert dev.add_resident(1, (12, 24, 0, 32))
    assert dev.spill_bytes == 768
    assert [e for e in dev.events if e[0] == "spill"] == [("spill", 768)]
    assert dev.resident_bytes == 768
    assert dev.has_resident(1, (12, 24, 0, 32))
    assert not dev.has_resident(1, (0, 12, 0, 32))
