"""Substrate tests: optimizer (incl. int8/factored moments), checkpointing
(atomic/async/elastic), data determinism, fault-tolerant train loop
(resume + preemption), serving loop, grad compression error feedback."""
import json
import os
import signal
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import SHAPES, get
from repro.data.pipeline import PipelineState, SyntheticLM
from repro.models import model as lm
from repro.optim import adamw, compression
from repro.serve.loop import Request, Server
from repro.train.loop import LoopConfig, TrainLoop

RNG = np.random.default_rng(3)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def quad_params():
    return {"w": jnp.asarray(RNG.standard_normal((8, 16)), jnp.float32),
            "stack": {"k": jnp.asarray(RNG.standard_normal((4, 8, 16)),
                                       jnp.float32)}}


def quad_loss(p):
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(p))


@pytest.mark.parametrize("moment_dtype,factored", [
    ("float32", False), ("bfloat16", False), ("int8", False),
    ("float32", True), ("int8", True),
])
def test_adamw_decreases_quadratic(moment_dtype, factored):
    c = adamw.AdamWConfig(peak_lr=0.05, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, moment_dtype=moment_dtype,
                          factored_v=factored)
    p = quad_params()
    s = adamw.init(p, c)
    l0 = float(quad_loss(p))
    step = jax.jit(lambda p_, s_: adamw.apply(
        p_, jax.grad(quad_loss)(p_), s_, c))
    for _ in range(60):
        p, s, m = step(p, s)
    assert float(quad_loss(p)) < 0.5 * l0
    assert int(s["step"]) == 60


def test_adamw_int8_moments_close_to_fp32():
    cf = adamw.AdamWConfig(peak_lr=0.02, warmup_steps=0, weight_decay=0.0,
                           moment_dtype="float32")
    cq = adamw.AdamWConfig(peak_lr=0.02, warmup_steps=0, weight_decay=0.0,
                           moment_dtype="int8")
    p0 = quad_params()
    pf, sf = p0, adamw.init(p0, cf)
    pq, sq = p0, adamw.init(p0, cq)
    for _ in range(20):
        pf, sf, _ = adamw.apply(pf, jax.grad(quad_loss)(pf), sf, cf)
        pq, sq, _ = adamw.apply(pq, jax.grad(quad_loss)(pq), sq, cq)
    rel = abs(float(quad_loss(pq)) - float(quad_loss(pf))) / float(quad_loss(pf))
    assert rel < 0.15, rel


def test_adamw_grad_clipping_and_schedule():
    c = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          clip_norm=1.0)
    assert float(adamw.schedule(c, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(c, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(c, jnp.asarray(100))) == pytest.approx(
        c.peak_lr * c.end_lr_frac, rel=1e-3)
    p = {"w": jnp.ones((4,))}
    s = adamw.init(p, c)
    g = {"w": jnp.full((4,), 100.0)}      # huge grad, must be clipped
    p2, s2, m = adamw.apply(p, g, s, c)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert np.all(np.isfinite(np.asarray(p2["w"])))
    assert float(jnp.abs(p["w"] - p2["w"]).max()) < 1.0   # clip bounded step


def test_adamw_weight_decay_mask():
    c = adamw.AdamWConfig(peak_lr=0.0, warmup_steps=0, weight_decay=0.5)
    p = {"w": jnp.ones((4,)), "ln": {"scale": jnp.ones((4,))}}
    s = adamw.init(p, c)
    g = jax.tree.map(jnp.zeros_like, p)
    p2, _, _ = adamw.apply(p, g, s, c)
    # lr==0 => no update at all regardless of decay; now lr>0:
    c2 = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=0, weight_decay=0.5)
    p3, _, _ = adamw.apply(p, g, adamw.init(p, c2), c2)
    assert float(jnp.abs(p3["w"] - 1).max()) > 0        # decayed
    assert float(jnp.abs(p3["ln"]["scale"] - 1).max()) == 0  # masked


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_error_feedback_is_unbiased_over_time():
    g = jnp.asarray(RNG.standard_normal((64,)) * 1e-3, jnp.float32)
    ef = jnp.zeros_like(g, jnp.bfloat16)
    total_q = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, ef = compression.compress(g, ef)
        total_q = total_q + q.astype(jnp.float32)
    # sum of quantized payloads ~= sum of true grads (error fed back)
    err = float(jnp.abs(total_q - n * g).max())
    assert err < float(jnp.abs(g).max()) * 2.5


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    for step in (1, 2, 3):
        mgr.save(step, state, meta={"pipeline": {"step": step}},
                 blocking=True)
    assert mgr.latest_step() == 3
    assert sorted(mgr.steps()) == [2, 3]       # gc kept last 2
    restored, meta = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert meta["pipeline"]["step"] == 3


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, {"x": jnp.ones((2,))}, blocking=True)
    assert not list(Path(tmp_path).glob("*.tmp"))
    assert mgr.latest_step() == 7


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_sharded():
    cfg = get("qwen3-1.7b").reduced()
    pipe = SyntheticLM(cfg, SHAPES["train_4k"], seed=5, batch_override=8,
                       seq_override=32)
    b1 = pipe.batch(3)
    b2 = pipe.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipe.batch(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # different shards differ; same chain statistics
    p0 = SyntheticLM(cfg, SHAPES["train_4k"], seed=5, shard=0, num_shards=2,
                     batch_override=8, seq_override=32)
    p1 = SyntheticLM(cfg, SHAPES["train_4k"], seed=5, shard=1, num_shards=2,
                     batch_override=8, seq_override=32)
    assert not np.array_equal(p0.batch(0)["tokens"], p1.batch(0)["tokens"])


# ---------------------------------------------------------------------------
# train loop: resume + preemption
# ---------------------------------------------------------------------------


def _tiny_setup(tmp_path, total_steps=6, ckpt_every=2):
    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    oc = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=100)
    opt = adamw.init(params, oc)
    pipe = SyntheticLM(cfg, SHAPES["train_4k"], seed=1, batch_override=4,
                       seq_override=16)

    @jax.jit
    def step_fn(p, s, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, mets), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, batch, cfg), has_aux=True)(p)
        p2, s2, om = adamw.apply(p, g, s, oc)
        return p2, s2, dict(mets, **om)

    lc = LoopConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                    log_every=1, out_dir=str(tmp_path / "run"))
    return cfg, params, opt, pipe, step_fn, lc


def test_train_loop_runs_and_checkpoints(tmp_path):
    cfg, params, opt, pipe, step_fn, lc = _tiny_setup(tmp_path)
    loop = TrainLoop(lc, step_fn, params, opt, pipe)
    out = loop.run()
    assert out["status"] == "done" and out["step"] == 6
    assert np.isfinite(out["loss"])
    assert loop.ckpt.latest_step() == 6
    lines = [json.loads(l) for l in
             (Path(lc.out_dir) / "metrics.jsonl").read_text().splitlines()]
    assert lines[-1]["step"] == 6


def test_train_loop_resume_matches_uninterrupted(tmp_path):
    # run A: 6 steps straight through
    cfg, params, opt, pipe, step_fn, lc = _tiny_setup(tmp_path / "a",
                                                      total_steps=6,
                                                      ckpt_every=3)
    outA = TrainLoop(lc, step_fn, params, opt, pipe).run()
    # run B: 3 steps, "crash", new loop resumes from ckpt to 6
    cfg, params, opt, pipe, step_fn, lcB = _tiny_setup(tmp_path / "b",
                                                       total_steps=3,
                                                       ckpt_every=3)
    TrainLoop(lcB, step_fn, params, opt, pipe).run()
    lcB2 = LoopConfig(total_steps=6, ckpt_every=3, log_every=1,
                      out_dir=lcB.out_dir)
    outB = TrainLoop(lcB2, step_fn, params, opt, pipe).run()
    assert outB["step"] == 6
    assert outA["loss"] == pytest.approx(outB["loss"], rel=1e-4)


def test_train_loop_preemption_checkpoints(tmp_path):
    cfg, params, opt, pipe, step_fn, lc = _tiny_setup(tmp_path,
                                                      total_steps=50,
                                                      ckpt_every=50)
    loop = TrainLoop(lc, step_fn, params, opt, pipe)

    orig = loop.step_fn
    calls = {"n": 0}

    def counting(p, s, b):
        calls["n"] += 1
        if calls["n"] == 3:
            os.kill(os.getpid(), signal.SIGTERM)   # preempt mid-run
        return orig(p, s, b)

    loop.step_fn = counting
    out = loop.run()
    assert out["status"] == "preempted"
    assert loop.ckpt.latest_step() == out["step"] >= 3


# ---------------------------------------------------------------------------
# serving loop
# ---------------------------------------------------------------------------


def test_server_batched_decode_drains_queue():
    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, slots=2, cache_len=48)
    for uid in range(5):
        srv.submit(Request(uid=uid,
                           prompt=RNG.integers(0, 127, 8).astype(np.int32),
                           max_new=6))
    done = srv.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert 1 <= len(r.out_tokens) <= 6
        assert all(0 <= t < cfg.vocab_padded for t in r.out_tokens)


def test_server_decode_matches_offline_decode():
    """A request served through slot batching must produce the same greedy
    tokens as a standalone prefill+decode chain."""
    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    prompt = RNG.integers(0, 127, 8).astype(np.int32)
    # offline
    logits, caches = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                cfg, cache_len=48)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for i in range(4):
        lg, caches = lm.decode_step(params, jnp.asarray([[toks[-1]]]),
                                    jnp.asarray([pos + i]), caches, cfg)
        toks.append(int(jnp.argmax(lg[0])))
    # served
    srv = Server(cfg, params, slots=3, cache_len=48)
    srv.submit(Request(uid=0, prompt=prompt, max_new=5))
    done = srv.run_until_drained()
    assert done[0].out_tokens == toks
