"""Property-based tests (hypothesis) for core AME-PIM invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cost as cost_mod
from repro.core.engine import AMEEngine
from repro.core.isa import AAM_BLOCKS, JUMP_MAX_ITERS
from repro.core.pep import ew_invocations, mac_invocations, mac_pass_coords

F16 = np.float16

dims = st.integers(min_value=1, max_value=512)
small = st.integers(min_value=1, max_value=48)


@given(k=dims, n=dims)
@settings(max_examples=60, deadline=None)
def test_mac_schedule_is_a_partition(k, n):
    """Every (column, k-chunk) is visited exactly once, within pass bounds."""
    invs = mac_invocations(k, n)
    assert all(1 <= i.passes <= JUMP_MAX_ITERS for i in invs)
    total = sum(i.passes for i in invs)
    assert total == math.ceil(k / AAM_BLOCKS) * n
    # starts are contiguous
    assert [i.start for i in invs] == list(
        np.cumsum([0] + [i.passes for i in invs[:-1]]))
    # coords bijective over the grid
    seen = set()
    for i in invs:
        for t in range(i.passes):
            c = mac_pass_coords(i.start + t, k)
            assert c not in seen
            seen.add(c)
    assert len(seen) == total


@given(c=dims)
@settings(max_examples=60, deadline=None)
def test_ew_invocations_cover_columns(c):
    invs = ew_invocations(c)
    cols = []
    for col0, passes in invs:
        assert 1 <= passes <= JUMP_MAX_ITERS
        cols.extend(range(col0, col0 + passes * AAM_BLOCKS, AAM_BLOCKS))
    # contiguous 8-column windows covering at least c columns, no overlap
    assert cols == sorted(set(cols))
    assert cols[0] == 0 and cols[-1] + AAM_BLOCKS >= c


@given(m=st.integers(2, 128), k=small, n=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_mfmacc_linearity_in_blocks(m, k, n, seed):
    """Splitting K across two mfmacc calls == one call (in-memory
    accumulation is exact chunk-wise: same ascending-k order)."""
    rng = np.random.default_rng(seed)
    a = (rng.standard_normal((m, k)) * 0.25).astype(F16)
    b = (rng.standard_normal((k, n)) * 0.25).astype(F16)
    e1 = AMEEngine()
    e1.msettilem(m), e1.msettilek(k), e1.msettilen(n)
    e1.mld(0, a), e1.mld(1, b)
    e1.mfmacc(0, 0, 1)
    one = np.asarray(e1.mst(0))

    ks = max(1, (k // 2 // AAM_BLOCKS) * AAM_BLOCKS) if k > AAM_BLOCKS else k
    e2 = AMEEngine()
    e2.msettilem(m), e2.msettilen(n)
    for lo, hi in ((0, ks), (ks, k)):
        if hi <= lo:
            continue
        e2.msettilek(hi - lo)
        e2.mld(0, a[:, lo:hi]), e2.mld(1, b[lo:hi])
        e2.mfmacc(0, 0, 1)
    np.testing.assert_array_equal(one, np.asarray(e2.mst(0)))


@given(m=st.integers(1, 128), k=dims, n=dims)
@settings(max_examples=60, deadline=None)
def test_cost_monotone_and_positive(m, k, n):
    r = cost_mod.mfmacc_cost(m, k, n)
    assert r.cycles > r.commands > 0
    assert r.flops == 2 * m * k * n
    assert r.flop_per_cycle <= cost_mod.saturated_flop_per_cycle("mac") + 1e-9
    # ISA model always beats the bus model
    assert r.flop_per_cycle_isa > r.flop_per_cycle


@given(kind=st.sampled_from(["add", "mul", "sub"]),
       m=st.integers(1, 128), c=dims)
@settings(max_examples=60, deadline=None)
def test_elementwise_cost_lane_waste(kind, m, c):
    """Rows < 128 waste SIMD lanes: cycles fixed by c, flops scale with m."""
    r = cost_mod.elementwise_cost(kind, m, c)
    full = cost_mod.elementwise_cost(kind, 128, c)
    assert r.cycles == full.cycles
    assert r.flops == m * c
