"""Device-level runtime tests: placement covers, makespan semantics,
multi-channel bit-exactness, ledger parity, and trace round-trips."""
import numpy as np
import pytest

from repro.core import cost as cost_mod
from repro.core.pep import init_channel, run_mac_strict
from repro.runtime import (
    PIMRuntime,
    PLACEMENTS,
    get_placement,
    pim_gemm,
    pim_gemv,
    transfer_cycles,
    validate_cover,
)
from repro.runtime.placement import shard_mac_passes
from repro.runtime.trace import emit_trace, parse_trace

RNG = np.random.default_rng(7)


def rand(m, n, scale=0.2):
    return (RNG.standard_normal((m, n)) * scale).astype(np.float16)


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

SHAPES = [
    (128, 64, 32),       # one row block
    (512, 4096, 512),    # the benchmark GEMM
    (256, 2048, 1),      # skinny GEMV
    (1000, 100, 7),      # ragged everything
    (64, 8, 1),          # tiny
    (2048, 256, 128),    # more blocks than channels
]


@pytest.mark.parametrize("name", sorted(PLACEMENTS))
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("channels", [1, 3, 16])
def test_placement_is_disjoint_exact_cover(name, m, k, n, channels):
    shards = get_placement(name)(m, k, n, channels)
    validate_cover(shards, m, k, n)            # raises on overlap/hole
    assert all(0 <= s.channel < channels for s in shards)


def test_balanced_uses_all_channels_on_skinny_gemv():
    shards = get_placement("balanced")(256, 2048, 1, 16)
    assert len({s.channel for s in shards}) == 16
    loads = {}
    for s in shards:
        loads[s.channel] = loads.get(s.channel, 0) + shard_mac_passes(s)
    assert max(loads.values()) <= 2 * min(loads.values())


def test_row_striped_starves_channels_on_skinny_gemv():
    shards = get_placement("row-striped")(256, 2048, 1, 16)
    assert len({s.channel for s in shards}) == 2   # only 2 row blocks


def test_unknown_placement_raises():
    with pytest.raises(KeyError):
        get_placement("interleaved")


# ---------------------------------------------------------------------------
# scheduler: makespan, FLOP totals, bit-exactness
# ---------------------------------------------------------------------------


def test_makespan_is_max_over_channels_not_sum():
    a, b = rand(256, 160), rand(160, 192)
    _, rep = pim_gemm(a, b, channels=4)
    busy = [c.busy_cycles for c in rep.per_channel]
    assert rep.makespan_cycles == max(busy)
    assert rep.makespan_cycles < sum(busy)
    # busy model: lead-in + overlapped streaming + drain
    for c in rep.per_channel:
        if c.busy_cycles:
            assert c.busy_cycles == c.lead_in_cycles + max(
                c.compute_cycles, c.h2d_cycles - c.lead_in_cycles
            ) + c.d2h_cycles


@pytest.mark.parametrize("placement", ["row-striped", "2d-block"])
@pytest.mark.parametrize("channels", [2, 4, 16])
def test_multi_channel_gemm_bit_exact_with_single_channel(placement,
                                                          channels):
    a, b = rand(384, 96), rand(96, 160)
    out1, rep1 = pim_gemm(a, b, channels=1)
    outn, repn = pim_gemm(a, b, channels=channels, placement=placement)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(outn))
    assert rep1.total_flops == repn.total_flops


def test_flop_totals_pinned_across_channels_and_placements():
    """Regression for the old channels-FLOP double count: every channel
    count and placement charges exactly 2*M*K*N FLOPs."""
    m, k, n = 256, 320, 24
    a, b = rand(m, k), rand(k, n)
    for channels in (1, 2, 8, 16):
        for placement in sorted(PLACEMENTS):
            _, rep = pim_gemm(a, b, channels=channels, placement=placement)
            assert rep.total_flops == 2 * m * k * n, (channels, placement)


def test_balanced_gemv_close_to_fp32_and_faster():
    a, x = rand(256, 2048, 0.1), rand(2048, 1, 0.1)[:, 0]
    ref = a.astype(np.float32) @ x.astype(np.float32)
    y_rs, rep_rs = pim_gemv(a, x, channels=16, placement="row-striped")
    y_bal, rep_bal = pim_gemv(a, x, channels=16, placement="balanced")
    np.testing.assert_allclose(np.asarray(y_rs, np.float32), ref,
                               atol=0.05, rtol=0.05)
    np.testing.assert_allclose(np.asarray(y_bal, np.float32), ref,
                               atol=0.05, rtol=0.05)
    # the acceptance headline: balanced beats row-striped on skinny GEMV
    assert rep_bal.makespan_cycles < rep_rs.makespan_cycles


def test_analytic_mode_charges_identical_ledgers():
    a, b = rand(300, 520), rand(520, 130)
    for placement in sorted(PLACEMENTS):
        _, rep_x = PIMRuntime(channels=4).gemm(a, b, placement=placement)
        _, rep_a = PIMRuntime(channels=4).gemm(a, b, placement=placement,
                                               execute=False)
        for cx, ca in zip(rep_x.per_channel, rep_a.per_channel):
            assert cx.compute_cycles == ca.compute_cycles
            assert cx.flops == ca.flops
            assert cx.commands == ca.commands
            assert cx.h2d_bytes == ca.h2d_bytes
            assert cx.d2h_bytes == ca.d2h_bytes
        assert rep_x.makespan_cycles == rep_a.makespan_cycles


def test_transfer_accounting_row_striped():
    m, k, n = 256, 64, 32
    _, rep = pim_gemm(rand(m, k), rand(k, n), channels=2)
    half = m // 2
    for c in rep.per_channel:
        assert c.h2d_bytes == (half * k + k * n) * 2
        assert c.d2h_bytes == half * n * 2
        assert c.h2d_cycles == transfer_cycles(c.h2d_bytes)


def test_elementwise_runtime_matches_engine_and_partitions():
    a, b = rand(300, 96), rand(300, 96)
    rt = PIMRuntime(channels=4)
    out, rep = rt.elementwise("add", a, b)
    np.testing.assert_array_equal(
        np.asarray(out), (a.astype(np.float16) + b.astype(np.float16)))
    assert rep.total_flops == 300 * 96
    assert rep.makespan_cycles == max(c.busy_cycles for c in rep.per_channel)


def test_runtime_rejects_oversized_stack():
    with pytest.raises(AssertionError):
        PIMRuntime(channels=17)


# ---------------------------------------------------------------------------
# trace emission / parsing
# ---------------------------------------------------------------------------


def test_trace_roundtrips_through_parser():
    rt = PIMRuntime(channels=2)
    a, b = rand(200, 24), rand(24, 8)          # 2 row blocks -> both channels
    _, rep = rt.gemm(a, b)
    _, rep2 = rt.elementwise("sub", rand(140, 40), rand(140, 40))
    stats = parse_trace(emit_trace(rt.stack))
    # one PIM line per column command, summed over both ops
    assert stats.pim_commands == rep.total_commands + rep2.total_commands
    # every h2d/d2h byte shows up as a 32-byte MEM transaction
    for c in rep.per_channel:
        ch2 = next(x for x in rep2.per_channel if x.channel == c.channel)
        assert stats.mem_writes[c.channel] == \
            c.h2d_cycles + ch2.h2d_cycles
        assert stats.mem_reads[c.channel] == \
            c.d2h_cycles + ch2.d2h_cycles
    # launches: one AB-mode switch per PEP launch
    assert stats.launches == sum(r.launches for d in rt.stack
                                 for r in d.engine.log)
    assert set(stats.channels) == {0, 1}
    assert stats.opcodes["MAC"] > 0 and stats.opcodes["MUL"] > 0


def test_trace_analytic_mode_matches_numeric_trace_counts():
    a, b = rand(40, 56), rand(56, 24)
    rt_x, rt_a = PIMRuntime(channels=2), PIMRuntime(channels=2)
    rt_x.gemm(a, b)
    rt_a.gemm(a, b, execute=False)
    sx = parse_trace(emit_trace(rt_x.stack))
    sa = parse_trace(emit_trace(rt_a.stack))
    assert sx.pim_commands == sa.pim_commands
    assert sx.opcodes == sa.opcodes


def test_trace_command_count_cross_checks_strict_interpreter():
    """The emitted trace, the cost model, and the strict interpreter all
    agree on column commands for the same mfmacc."""
    k, n = 24, 8
    ch, mm = init_channel(nblocks=6200)
    strict_cmds = run_mac_strict(ch, mm, a_base=mm.tiles[0],
                                 acc_base=mm.accs[0], k=k, n=n)
    assert strict_cmds == cost_mod.mfmacc_cost(128, k, n).commands

    rt = PIMRuntime(channels=1)
    rt.gemm(rand(128, k), rand(k, n))
    stats = parse_trace(emit_trace(rt.stack))
    assert stats.pim_commands == strict_cmds


def test_trace_dump_and_unparseable_line(tmp_path):
    from repro.runtime import dump_trace
    rt = PIMRuntime(channels=1)
    rt.gemm(rand(8, 8), rand(8, 4))
    p = tmp_path / "op.trace"
    nlines = dump_trace(rt.stack, str(p))
    assert nlines == len(p.read_text().splitlines())
    assert parse_trace(p.read_text()).pim_commands > 0
    with pytest.raises(ValueError):
        parse_trace("PIM FROB GRF;0\n")
