"""Observability layer invariants (repro.obs).

* metrics primitives: counters monotonic, exact histogram percentiles,
  registry get-or-create with type safety;
* Chrome-trace export: valid Trace Event JSON, one track per busy
  (stack, channel) plus a host-link track, matched dep-flow pairs,
  µs <-> cycle unit round-trip;
* critical path: segments partition [0, makespan] exactly — chained,
  independent, slack-gapped and degenerate op logs;
* the serialized shadow profiler reproduces barrier semantics (shadow
  clock == sum of per-op cluster makespans) and feeds the same
  export/analysis pipeline;
* profiling strictly additive: with metrics/profile off and on, ledgers
  are ==-equal and traces byte-identical;
* end to end: an async DecodeOffload step exports + attributes, the
  Server reports TTFT/TPOT percentiles;
* satellites: degenerate Timeline.submit normalization, and one trace
  carrying # RESIDENT + # STACK/# HOSTLINK + # SPILL + # TSTART/# TEND
  simultaneously round-trips through parse_trace / strip_timestamps.
"""
import json

import numpy as np
import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Profiler,
    US_PER_CYCLE,
    chrome_trace,
    critical_path,
    export_chrome_trace,
    profile_report,
)
from repro.runtime import PIMRuntime, emit_trace, parse_trace, \
    strip_timestamps
from repro.runtime.timeline import OpHandle

rng = np.random.default_rng(7)


def rand(*shape):
    return (rng.standard_normal(shape) * 0.1).astype(np.float16)


A = rand(256, 128)
B = rand(128, 64)
X = rand(128)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_counter_monotonic():
    c = Counter("t.bytes", unit="bytes")
    c.inc(), c.inc(41)
    assert c.value == 42
    with pytest.raises(AssertionError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = Gauge("t.slots", unit="slots")
    g.set(4), g.inc(), g.dec(2)
    assert g.value == 3.0


def test_histogram_exact_percentiles():
    h = Histogram("t.lat", unit="s")
    for v in range(1, 101):          # 1..100
        h.record(v)
    assert h.count == 100 and h.mean == pytest.approx(50.5)
    assert h.percentile(0) == 1 and h.percentile(100) == 100
    assert h.percentile(50) == pytest.approx(50.5)   # interpolated
    s = h.summary()
    assert s["p99"] == pytest.approx(99.01)
    assert s["min"] == 1 and s["max"] == 100


def test_histogram_empty_summary_is_zeroes():
    s = Histogram("t.empty").summary()
    assert s == {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                 "p50": 0.0, "p90": 0.0, "p99": 0.0}


def test_registry_get_or_create_and_type_safety():
    m = MetricsRegistry()
    c1 = m.counter("a.b", unit="bytes", help="first wins")
    c2 = m.counter("a.b", unit="ignored")
    assert c1 is c2 and c1.unit == "bytes"
    with pytest.raises(TypeError):
        m.gauge("a.b")
    m.histogram("a.h").record(1.0)
    assert "a.b" in m and len(m) == 2
    snap = m.snapshot()
    assert snap["a.b"]["type"] == "counter"
    assert snap["a.h"]["p50"] == 1.0
    assert {r["name"] for r in m.catalog()} == {"a.b", "a.h"}
    json.dumps(snap)                  # JSON-ready


# ---------------------------------------------------------------------------
# critical path on hand-built op logs
# ---------------------------------------------------------------------------


def _op(op_id, name, spans, deps=(), link=None):
    ends = [s + b for s, b in spans.values()]
    if link:
        ends.append(link[1])
    start = min((s for s, _ in spans.values()),
                default=link[0] if link else 0.0)
    return OpHandle(op_id=op_id, name=name, deps=tuple(deps), start=start,
                    retire=max(ends, default=start), spans=dict(spans),
                    link_window=link)


def test_critical_path_chain_sums_exactly():
    ops = [_op(1, "a", {0: (0.0, 100.0)}),
           _op(2, "b", {0: (100.0, 50.0)}, deps=(1,))]
    rep = critical_path(ops)
    assert rep.makespan_cycles == 150.0
    assert rep.coverage_cycles == rep.makespan_cycles
    assert rep.by_op == {1: 100.0, 2: 50.0} and rep.slack_cycles == 0.0
    assert [s.op_id for s in rep.segments] == [1, 2]   # chronological


def test_critical_path_independent_ops_attribute_longest():
    ops = [_op(1, "short", {0: (0.0, 40.0)}),
           _op(2, "long", {1: (0.0, 100.0)})]
    rep = critical_path(ops)
    assert rep.coverage_cycles == rep.makespan_cycles == 100.0
    assert rep.by_op == {2: 100.0}        # the short op is off-path
    assert rep.channel_busy == {0: 40.0, 1: 100.0}


def test_critical_path_slack_fills_gaps():
    # op 2 starts 30 cycles after op 1 ends, bound by nothing we model
    ops = [_op(1, "a", {0: (0.0, 50.0)}),
           _op(2, "b", {1: (80.0, 20.0)})]
    rep = critical_path(ops)
    assert rep.coverage_cycles == rep.makespan_cycles == 100.0
    assert rep.slack_cycles == 30.0
    kinds = [s.kind for s in rep.segments]
    assert kinds == ["channel", "slack", "channel"]


def test_critical_path_link_bound():
    ops = [_op(1, "xfer", {0: (0.0, 10.0)}, link=(0.0, 60.0)),
           _op(2, "use", {1: (60.0, 40.0)}, deps=(1,))]
    rep = critical_path(ops)
    assert rep.coverage_cycles == rep.makespan_cycles == 100.0
    assert rep.link_cycles == 60.0 and rep.by_op[1] == 60.0


def test_critical_path_hops_through_degenerate_ops():
    noop = OpHandle(op_id=2, name="noop", deps=(1,), start=50.0,
                    retire=50.0, spans={})
    ops = [_op(1, "a", {0: (0.0, 50.0)}), noop,
           _op(3, "b", {0: (50.0, 25.0)}, deps=(2,))]
    rep = critical_path(ops)
    assert rep.coverage_cycles == rep.makespan_cycles == 75.0
    assert rep.by_op == {1: 50.0, 3: 25.0}     # noop contributes 0


def test_critical_path_empty_log():
    rep = critical_path([])
    assert rep.makespan_cycles == 0.0 and rep.segments == []


def test_profile_report_json_round_trip(tmp_path):
    ops = [_op(1, "a", {0: (0.0, 100.0)}),
           _op(2, "b", {0: (100.0, 50.0)}, deps=(1,))]
    rep = critical_path(ops)
    p = tmp_path / "rep.json"
    rep.dump(str(p))
    data = json.load(open(p))
    assert data["profile_report"] == 1
    assert data["coverage_cycles"] == data["makespan_cycles"] == 150.0
    assert "top" not in data and data["by_op"] == {"1": 100.0, "2": 50.0}
    assert "makespan=150" in rep.summary()


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------


def _async_cluster_rt():
    rt = PIMRuntime(channels=2, stacks=2, async_mode=True)
    a, b = rand(256, 128), rand(128, 128)
    h1 = rt.gemm(a, b, placement="2d-block")      # crosses the link
    rt.gemm(a, b, placement="2d-block", after=[h1])
    return rt


def test_chrome_trace_structure_and_units():
    rt = _async_cluster_rt()
    trace = chrome_trace(rt)
    json.loads(json.dumps(trace))                 # valid JSON
    events = trace["traceEvents"]
    assert trace["otherData"]["makespan_cycles"] == rt.timeline.now
    # one op track per busy (stack, channel); flat ids recoverable
    ops = [e for e in events if e.get("ph") == "X" and e["cat"] == "op"]
    busy = {ch for h in rt.timeline.ops for ch in h.spans}
    assert {(e["pid"], e["tid"]) for e in ops} == \
        {(ch // 2, ch % 2) for ch in busy}
    # host-link track named and carrying the link windows
    names = {e["args"]["name"] for e in events
             if e.get("ph") == "M" and e.get("name") == "process_name"}
    assert names == {"stack 0", "stack 1", "host-link"}
    links = [e for e in events if e.get("ph") == "X" and e["cat"] == "link"]
    assert links and all(e["pid"] == 2 for e in links)
    # µs timestamps are cycles / 250
    for e in ops:
        assert e["ts"] == pytest.approx(
            e["args"]["start_cycles"] * US_PER_CYCLE)
        assert e["dur"] == pytest.approx(
            e["args"]["busy_cycles"] * US_PER_CYCLE)


def test_chrome_trace_flow_pairs_match_dep_edges():
    rt = _async_cluster_rt()
    events = chrome_trace(rt)["traceEvents"]
    s = sorted(e["id"] for e in events if e.get("ph") == "s")
    f = sorted(e["id"] for e in events if e.get("ph") == "f")
    n_edges = sum(len(h.deps) for h in rt.timeline.ops)
    assert s == f and len(s) == n_edges > 0
    assert all(e.get("bp") == "e" for e in events if e.get("ph") == "f")


def test_export_writes_file(tmp_path):
    rt = _async_cluster_rt()
    p = tmp_path / "prof.json"
    trace = export_chrome_trace(rt, str(p))
    assert json.load(open(p)) == json.loads(json.dumps(trace))


def test_phase_slices_cover_span():
    rt = PIMRuntime(channels=2, async_mode=True)
    rt.gemm(A, B, placement="balanced")
    events = chrome_trace(rt)["traceEvents"]
    ops = [e for e in events if e.get("ph") == "X" and e["cat"] == "op"]
    phases = [e for e in events if e.get("ph") == "X" and e["cat"] == "phase"]
    assert phases
    for op in ops:
        mine = [p for p in phases
                if (p["pid"], p["tid"]) == (op["pid"], op["tid"])
                and op["ts"] - 1e-9 <= p["ts"]
                and p["ts"] + p["dur"] <= op["ts"] + op["dur"] + 1e-9]
        assert mine, "every op slice nests its phase breakdown"


# ---------------------------------------------------------------------------
# serialized shadow profiler
# ---------------------------------------------------------------------------


def test_shadow_profiler_barrier_semantics():
    rt = PIMRuntime(channels=4, profile=True)
    w = rt.place(A, placement="balanced")
    _, r1 = rt.gemv(w, X, placement="balanced")
    _, r2 = rt.gemm(A, B, placement="balanced")
    names = [h.name for h in rt.profile.ops]
    assert names == ["place", "gemv", "gemm"]
    # shadow clock == serialized accumulation; ops chain by dep edges
    assert rt.profile.now == pytest.approx(
        rt.profile.ops[0].retire + r1.cluster_makespan_cycles
        + r2.cluster_makespan_cycles)
    for prev, nxt in zip(rt.profile.ops, rt.profile.ops[1:]):
        assert nxt.deps == (prev.op_id,)
        assert nxt.start == pytest.approx(prev.retire)
    rep = profile_report(rt)
    assert rep.coverage_cycles == rep.makespan_cycles == \
        pytest.approx(rt.profile.now)
    assert rep.slack_cycles == 0.0


def test_profiler_requires_an_op_log():
    with pytest.raises(ValueError):
        profile_report(PIMRuntime(channels=2))


def test_profiler_is_strictly_additive():
    bare = PIMRuntime(channels=4)
    prof = PIMRuntime(channels=4, profile=True)
    for rt in (bare, prof):
        w = rt.place(A, placement="balanced")
        rt.gemv(w, X, placement="balanced")
        rt.gemm(A, B, placement="balanced")
    assert emit_trace(bare.stack) == emit_trace(prof.stack)
    _, rb = bare.elementwise("add", A, A, placement="balanced")
    _, rp = prof.elementwise("add", A, A, placement="balanced")
    assert rb == rp
    assert [h.name for h in prof.profile.ops][-1] == "ew-add"


def test_metrics_do_not_perturb_ledgers_or_traces():
    m = MetricsRegistry()
    bare = PIMRuntime(channels=2, stacks=2)
    inst = PIMRuntime(channels=2, stacks=2, metrics=m)
    a, b = rand(256, 128), rand(128, 128)
    _, rb = bare.gemm(a, b, placement="2d-block")
    _, ri = inst.gemm(a, b, placement="2d-block")
    assert rb == ri
    # the ledger == holds even though the instrumented link ledger
    # carries a registry (compare=False field)
    assert bare._cluster.link == inst._cluster.link
    assert emit_trace(bare.stack) == emit_trace(inst.stack)
    # ... and the registry actually observed the run
    assert m.get("runtime.ops").value == 1
    assert m.get("runtime.flops").value == ri.total_flops
    def val(name):         # instruments are created on first charge only
        inst_ = m.get(name)
        return inst_.value if inst_ is not None else 0

    assert val("link.xstack_bytes") + val("link.drain_bytes") == \
        inst._cluster.link.bytes > 0
    assert m.get("link.cycles").value == inst._cluster.link.cycles
    assert m.get("runtime.op_makespan_cycles").count == 1


def test_runtime_metrics_cover_residency_and_place():
    m = MetricsRegistry()
    rt = PIMRuntime(channels=4, metrics=m)
    w = rt.place(A, placement="balanced")
    rt.gemv(w, X, placement="balanced")
    assert m.get("runtime.place_ops").value == 1
    assert m.get("runtime.upload_bytes").value == A.nbytes
    assert m.get("runtime.reuse_bytes").value == A.nbytes  # weights reused


# ---------------------------------------------------------------------------
# satellites: degenerate submit, multi-marker trace round-trip, summary
# ---------------------------------------------------------------------------


def test_timeline_degenerate_submit_normalizes():
    rt = PIMRuntime(channels=2, async_mode=True)
    h0 = rt.gemm(A, B, placement="balanced")
    h = rt.timeline.submit("noop", {0: 0.0, 1: 0.0}, deps=[h0])
    assert h.spans == {} and h.link_window is None
    assert h.start == h.retire == h0.retire     # zero-length at ready
    # with no deps it sits at t=0 and never moves the frontier
    h2 = rt.timeline.submit("noop2", {})
    assert h2.start == h2.retire == 0.0
    assert rt.timeline.now == h0.retire
    # the critical path hops through it without stalling
    rep = critical_path(rt.timeline.ops)
    assert rep.coverage_cycles == rep.makespan_cycles


def test_all_marker_classes_round_trip_one_trace():
    """# RESIDENT + # STACK/# HOSTLINK + # SPILL + # TSTART/# TEND in a
    single trace: parse_trace sees every class, strip_timestamps recovers
    the serialized twin's bytes."""
    def drive(rt):
        w = rt.place(A, placement="balanced")
        rt.gemv(w, X, placement="balanced")          # -> # RESIDENT
        a, b = rand(256, 128), rand(128, 128)
        rt.gemm(a, b, placement="2d-block")          # -> # HOSTLINK
        rt.place(rand(256, 128), placement="balanced")   # -> # SPILL
        return rt

    kw = dict(channels=2, stacks=2, capacity_bytes=20_000)
    rng_state = rng.bit_generator.state
    rs = drive(PIMRuntime(**kw))
    rng.bit_generator.state = rng_state          # identical op stream
    ra = drive(PIMRuntime(async_mode=True, **kw))

    tr_a, tr_s = emit_trace(ra.stack), emit_trace(rs.stack)
    st = parse_trace(tr_a)
    assert sum(st.resident_bytes.values()) > 0   # residency reuse
    assert sorted(set(st.stacks_seen)) == [0, 1]  # stack grouping
    assert st.host_link_bytes["xstack"] > 0      # link traffic
    assert sum(st.spill_bytes.values()) > 0      # capacity eviction
    assert st.op_starts and st.op_ends           # async timestamps
    # timestamps are the only difference from the serialized twin
    assert tr_a != tr_s
    assert strip_timestamps(tr_a) == tr_s
    assert not parse_trace(tr_s).op_starts
    # the stripped trace parses identically to the serialized one
    stripped = parse_trace(strip_timestamps(tr_a))
    assert stripped.resident_bytes == parse_trace(tr_s).resident_bytes
    assert stripped.spill_bytes == parse_trace(tr_s).spill_bytes


def test_multi_stack_summary_reports_link_and_residency():
    rt = PIMRuntime(channels=2, stacks=2)
    w = rt.place(A, placement="balanced")
    _, rep = rt.gemv(w, X, placement="balanced")
    text = rep.summary()
    assert "stacks=2" in text and "link_util=" in text
    assert f"reuse={A.nbytes}" in text and "spill=0" in text
    # single-stack summaries keep the old single-line shape
    rt1 = PIMRuntime(channels=2)
    _, rep1 = rt1.gemm(A, B, placement="balanced")
    assert "link_util=" not in rep1.summary()


# ---------------------------------------------------------------------------
# end to end: offload + server
# ---------------------------------------------------------------------------


def _cfg():
    from repro.configs import get
    return get("qwen3-1.7b").reduced()


def test_async_offload_profile_end_to_end(tmp_path):
    from repro.serve.offload import DecodeOffload

    m = MetricsRegistry()
    off = DecodeOffload(_cfg(), channels=8, stacks=2, placement="balanced",
                        async_mode=True, metrics=m)
    off.step(1), off.step(1)
    p = tmp_path / "decode.json"
    trace = export_chrome_trace(off.rt, str(p))
    events = trace["traceEvents"]
    assert any(e.get("ph") == "M" and e.get("name") == "process_name"
               and e["args"]["name"] == "host-link" for e in events)
    s_ids = sorted(e["id"] for e in events if e.get("ph") == "s")
    assert s_ids == sorted(e["id"] for e in events if e.get("ph") == "f")
    rep = profile_report(off.rt)
    assert rep.makespan_cycles == off.rt.timeline.now
    assert abs(rep.coverage_cycles - rep.makespan_cycles) <= \
        1e-9 * max(1.0, rep.makespan_cycles)
    assert m.get("offload.steps").value == 2
    assert m.get("offload.step_pim_cycles").count == 2
    assert m.get("offload.flops").value == sum(s.flops for s in off.steps)


def test_offload_metrics_off_is_identical():
    from repro.serve.offload import DecodeOffload

    bare = DecodeOffload(_cfg(), channels=8, placement="balanced")
    inst = DecodeOffload(_cfg(), channels=8, placement="balanced",
                         metrics=MetricsRegistry())
    rb, ri = bare.step(2), inst.step(2)
    assert (rb.pim_cycles, rb.flops, rb.h2d_bytes, rb.reuse_bytes) == \
        (ri.pim_cycles, ri.flops, ri.h2d_bytes, ri.reuse_bytes)


def test_server_reports_ttft_tpot_percentiles():
    import jax

    from repro.configs import get
    from repro.models import model as lm
    from repro.serve.loop import Request, Server

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    m = MetricsRegistry()
    srv = Server(cfg, params, slots=2, cache_len=48, metrics=m)
    for uid in range(5):
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(0, 127, 8).astype(np.int32),
                           max_new=4))
    done = srv.run_until_drained()
    assert len(done) == 5
    ttft, tpot = m.get("serve.ttft_s"), m.get("serve.tpot_s")
    assert ttft.count == 5 and tpot.count == 5
    assert ttft.percentile(99) >= ttft.percentile(50) > 0.0
    assert m.get("serve.requests").value == 5
    assert m.get("serve.tokens").value == sum(
        len(r.out_tokens) for r in done)
    assert m.get("serve.step_s").count > 0
    # latency_summary works from timestamps alone and matches the registry
    summ = srv.latency_summary()
    assert summ["requests"] == 5
    assert summ["ttft_s"]["p50"] == pytest.approx(ttft.percentile(50))
    assert summ["tpot_s"]["count"] == 5
    # an uninstrumented server still summarizes
    srv2 = Server(cfg, params, slots=2, cache_len=48)
    srv2.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                        max_new=2))
    srv2.run_until_drained()
    assert srv2.latency_summary()["requests"] == 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_summarizes_all_artifact_kinds(tmp_path, capsys):
    from repro.obs.__main__ import main

    rt = _async_cluster_rt()
    chrome = tmp_path / "chrome.json"
    export_chrome_trace(rt, str(chrome))
    report = tmp_path / "report.json"
    profile_report(rt).dump(str(report))
    trace = tmp_path / "cmds.trace"
    trace.write_text(emit_trace(rt.stack))

    assert main([str(chrome)]) == 0
    assert "chrome trace:" in capsys.readouterr().out
    assert main([str(report), "--top", "2"]) == 0
    assert "critical path" in capsys.readouterr().out
    assert main([str(trace)]) == 0
    assert "command trace:" in capsys.readouterr().out
    bogus = tmp_path / "bogus.json"
    bogus.write_text('{"nope": 1}')
    assert main([str(bogus)]) == 2
