"""KV-cache-resident attention decode (PR 8).

Covers the paged placement policy (block-cyclic, growth-stable, and
deliberately un-memoized), :class:`PagedTensor` in-place growth, the
:class:`KVCacheManager` append/evict/restore ledger, the KVAPPEND /
KVEVICT trace markers, DecodeOffload's attention-on-PIM step (zero KV
prefix re-upload; numeric cross-check vs the XLA FP32 reference across
evictions and injected faults), and the serve-loop lifecycle hooks.
"""
import dataclasses
from collections import Counter

import numpy as np
import pytest

from repro.configs import get
from repro.core.isa import ROWNUM
from repro.runtime import (
    KV_BLOCK_TOKENS,
    KVCacheManager,
    PagedTensor,
    PIMRuntime,
    paged,
    placement_shards,
    validate_cover,
)
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.offload import DecodeOffload

RNG = np.random.default_rng(0)


def _small():
    return get("qwen3-1.7b").reduced()


def _kv_mgr(rt, channels, **kw):
    chans = tuple(range(channels))
    kw.setdefault("n_layers", 1)
    kw.setdefault("n_kv_heads", 1)
    kw.setdefault("head_dim", 64)
    return KVCacheManager(rt, channels_for_layer=lambda ell: chans, **kw)


# ---------------------------------------------------------------------------
# paged placement policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,c", [
    (1, 64, 4, 8), (64, 64, 1, 1), (128, 64, 2, 4), (200, 64, 2, 8),
    (512, 64, 2, 4), (64, 200, 2, 8), (64, 640, 4, 3),
])
def test_paged_covers_exactly(m, k, n, c):
    shards = paged(m, k, n, c)
    validate_cover(shards, m, k, n)


def test_paged_is_growth_stable():
    """Growing the context must never move an existing page's channel —
    that is the whole point of block-cyclic ownership."""
    for c in (1, 3, 8):
        small = {s.m0: s.channel for s in paged(512, 64, 2, c)}
        grown = {s.m0: s.channel for s in paged(640, 64, 2, c)}
        assert small == {m0: grown[m0] for m0 in small}
        # transposed regime: K-groups along the k axis
        ksmall = {s.k0: s.channel for s in paged(64, 512, 2, c)}
        kgrown = {s.k0: s.channel for s in paged(64, 640, 2, c)}
        assert ksmall == {k0: kgrown[k0] for k0 in ksmall}


def test_paged_page_owner_matches_manager_formula():
    """Shard channel for page b is chans[b % C] in both regimes — the
    invariant KVCacheManager's ownership formula relies on."""
    for c in (1, 2, 5):
        for s in paged(5 * ROWNUM, 64, 2, c):
            assert s.channel == (s.m0 // ROWNUM) % c
        for s in paged(64, 5 * ROWNUM, 2, c):
            assert s.channel == (s.k0 // ROWNUM) % c


def test_paged_bypasses_placement_memoization():
    """Block-quantized KV shapes grow every step; caching them would
    blow up the lru_cache key space — 'paged' must not memoize, the
    fixed policies must keep their identity-cached fast path."""
    a = placement_shards("paged", 256, 64, 2, 4)
    b = placement_shards("paged", 256, 64, 2, 4)
    assert a == b
    assert a is not b           # fresh computation, no cache entry
    x = placement_shards("balanced", 256, 64, 2, 4)
    y = placement_shards("balanced", 256, 64, 2, 4)
    assert x is y               # memoized exactly as before


# ---------------------------------------------------------------------------
# PagedTensor
# ---------------------------------------------------------------------------


def test_paged_tensor_grows_in_place():
    rt = PIMRuntime(channels=4)
    t = PagedTensor(rt.stack, 64, grow_axis=0, numeric=True)
    v1 = RNG.standard_normal((100, 64)).astype(np.float16)
    t.append(100, v1)
    assert t.shape == (100, 64) and t.tokens == 100
    np.testing.assert_array_equal(t.values, v1)
    v2 = RNG.standard_normal((60, 64)).astype(np.float16)
    t.append(60, v2)
    assert t.shape == (160, 64) and t.num_blocks == 2
    np.testing.assert_array_equal(t.values[:100], v1)
    np.testing.assert_array_equal(t.values[100:], v2)
    with pytest.raises(ValueError):
        t.append(0)


def test_paged_tensor_transposed_axis():
    rt = PIMRuntime(channels=4)
    t = PagedTensor(rt.stack, 32, grow_axis=1, numeric=True)
    v = RNG.standard_normal((32, 130)).astype(np.float16)
    t.append(130, v)
    assert t.shape == (32, 130)
    np.testing.assert_array_equal(t.values, v)
    assert t.block_box(1) == (0, 32, KV_BLOCK_TOKENS, 130)


def test_trailing_page_remark_supersedes():
    """Re-marking the grown trailing page must replace the old contained
    box, not double-count it."""
    rt = PIMRuntime(channels=2)
    dev = rt.stack[0]
    t = PagedTensor(rt.stack, 64, grow_axis=0)
    t.append(100)
    t.mark_resident(0, t.block_box(0))
    b0 = dev.resident_bytes_of(t.uid)
    assert b0 == 100 * 64 * 2
    t.append(28)
    t.mark_resident(0, t.block_box(0))
    assert len(dev.resident[t.uid]) == 1
    assert dev.resident_bytes_of(t.uid) == KV_BLOCK_TOKENS * 64 * 2


# ---------------------------------------------------------------------------
# KVCacheManager: appends, zero prefix re-upload, trace markers
# ---------------------------------------------------------------------------


def test_append_charges_new_tokens_only():
    rt = PIMRuntime(channels=4)
    kv = _kv_mgr(rt, 4)
    kv.request("r")
    kv.append_tokens("r", 0, 256)           # prefill: 2 pages x K,V
    base = sum(d.xfer.h2d_bytes for d in rt.stack)
    assert base == 256 * 64 * 2 * 2         # tokens * hd * B/elem * (K+V)
    for _ in range(3):                      # steady-state decode appends
        before = sum(d.xfer.h2d_bytes for d in rt.stack)
        kv.append_tokens("r", 0, 1)
        assert sum(d.xfer.h2d_bytes for d in rt.stack) - before \
            == 1 * 64 * 2 * 2               # one token, never the prefix
    assert kv.resident_kv_bytes == 259 * 64 * 2 * 2


def test_append_h2d_independent_of_context_length():
    """The tentpole invariant: per-step host-link bytes at steady state
    do not depend on how long the context already is."""
    deltas = []
    for prefill in (128, 1024):
        rt = PIMRuntime(channels=8)
        kv = _kv_mgr(rt, 8)
        kv.request("r")
        kv.append_tokens("r", 0, prefill)
        before = sum(d.xfer.h2d_bytes for d in rt.stack)
        kv.append_tokens("r", 0, 1)
        deltas.append(sum(d.xfer.h2d_bytes for d in rt.stack) - before)
    assert deltas[0] == deltas[1]


def test_attention_gemvs_hit_residency_page_for_page():
    """Score GEMV ships only q; softmax and context GEMV ship nothing
    (scores stay resident, V^T pages are resident)."""
    rt = PIMRuntime(channels=8)
    kv = KVCacheManager(rt, n_layers=1, n_kv_heads=1, head_dim=64,
                        channels_for_layer=lambda ell: tuple(range(8)),
                        numeric=True)
    kv.request("r")
    hd, group, tokens = 64, 2, 300
    kv.append_tokens(
        "r", 0, tokens,
        k_vals=[(RNG.standard_normal((tokens, hd)) * 0.05
                 ).astype(np.float16)],
        v_vals=[(RNG.standard_normal((hd, tokens)) * 0.05
                 ).astype(np.float16)])
    K, VT = kv.tensors("r", 0, 0)
    q = (RNG.standard_normal((hd, group)) * 0.05).astype(np.float16)

    h2d = lambda: sum(d.xfer.h2d_bytes for d in rt.stack)
    before = h2d()
    scores, _ = rt.gemm(K, q, placement="paged", keep_output=True)
    # q alone, once per participating channel (3 pages -> 3 channels);
    # the 300-token K prefix ships nothing
    assert h2d() - before == 3 * hd * group * 2
    before = h2d()
    rt.softmax(scores, placement="paged")
    assert h2d() - before == 0
    before = h2d()
    y, _ = rt.gemm(VT, scores, placement="paged")
    assert h2d() - before == 0

    # numeric: matches FP32 softmax-attention over the full context
    K32 = np.asarray(K.values, np.float32)
    V32 = np.asarray(VT.values, np.float32)
    s = K32 @ q.astype(np.float32)
    e = np.exp(s - s.max(axis=0, keepdims=True))
    ref = V32 @ (e / e.sum(axis=0, keepdims=True))
    assert float(np.max(np.abs(np.asarray(y, np.float32) - ref))) < 2e-4


def test_kvappend_kvevict_trace_roundtrip():
    rt = PIMRuntime(channels=4)
    kv = _kv_mgr(rt, 4, capacity_bytes=80_000)
    kv.request("a")
    kv.begin_decode("a")
    kv.append_tokens("a", 0, 400)           # over budget: evicts pages
    assert kv.evictions > 0
    text = emit_trace(rt.stack)
    assert "# KVAPPEND" in text and "# KVEVICT" in text
    stats = parse_trace(text)
    assert sum(stats.kvappend_bytes.values()) == kv.append_bytes
    assert sum(stats.kvevict_bytes.values()) == kv.evict_bytes
    # replay-neutral: a stripped trace still parses to the same PIM
    # command stream (markers are comment-shaped)
    plain = "\n".join(ln for ln in text.splitlines()
                      if not ln.startswith("# KV"))
    assert parse_trace(plain).pim_commands == stats.pim_commands


def test_release_reclaims_capacity():
    rt = PIMRuntime(channels=4)
    kv = _kv_mgr(rt, 4)
    kv.request("a")
    kv.append_tokens("a", 0, 200)
    held = kv.resident_kv_bytes
    assert held > 0
    assert kv.release("a") == held
    assert kv.resident_kv_bytes == 0
    assert kv.release("a") == 0             # idempotent


# ---------------------------------------------------------------------------
# eviction edge cases (satellite: paged eviction under pressure)
# ---------------------------------------------------------------------------


def test_evicts_oldest_page_of_coldest_request():
    rt = PIMRuntime(channels=4)
    kv = _kv_mgr(rt, 4, capacity_bytes=300 * 64 * 2 * 2)
    for rid in ("cold", "hot"):
        kv.request(rid)
        kv.begin_decode(rid)
        kv.append_tokens(rid, 0, 140)       # 2 pages each, fits
    assert kv.evictions == 0
    kv.begin_decode("hot")                  # hot is now the youngest
    kv.append_tokens("hot", 0, 128)         # over budget
    cold, hot = kv._reqs["cold"], kv._reqs["hot"]
    assert 0 in cold.evicted                # oldest page, coldest request
    assert not hot.evicted


def test_evicting_currently_decoding_request_stays_correct():
    """A lone request under a tight budget evicts its own old pages;
    the next attention step re-ships them at the residency miss and the
    numerics never notice (host mirrors are exact)."""
    cfg = _small()
    off = DecodeOffload(cfg, channels=4, numeric=True, kv_offload=True,
                        kv_capacity_bytes=64_000)
    off.kv_prefill("solo", 300)
    assert off.kv.evictions > 0             # prefill alone overflows
    r1 = off.step(1, request_ids=["solo"])
    r2 = off.step(1, request_ids=["solo"])
    assert max(r1.attn_max_err, r2.attn_max_err) < 2e-4
    assert off.kv.restore_bytes > 0         # evicted pages re-shipped


def test_capacity_smaller_than_one_layer_is_graceful():
    """Trailing pages are never evicted, so a budget below one layer's
    KV stays over budget gracefully instead of thrashing."""
    cfg = _small()
    off = DecodeOffload(cfg, channels=4, numeric=True, kv_offload=True,
                        kv_capacity_bytes=1024)
    off.kv_prefill("r", 100)
    ev0 = off.kv.evictions
    r = off.step(1, request_ids=["r"])
    assert r.attn_max_err < 2e-4
    assert off.kv.resident_kv_bytes > 1024          # floor holds
    assert off.kv.evictions == ev0                  # no thrash loop


def test_eviction_is_deterministic():
    def run():
        cfg = _small()
        off = DecodeOffload(cfg, channels=4, numeric=True,
                            kv_offload=True, kv_capacity_bytes=200_000)
        for rid in ("a", "b"):
            off.kv_prefill(rid, 260)
        for _ in range(3):
            off.step(2, request_ids=["a", "b"])
        return (off.kv.summary(),
                [d.xfer.h2d_bytes for d in off.rt.stack],
                [s.h2d_bytes for s in off.steps])
    assert run() == run()


# ---------------------------------------------------------------------------
# DecodeOffload: attention on PIM
# ---------------------------------------------------------------------------


def test_offload_attention_h2d_flat_in_context():
    """Full decode steps: per-step h2d at steady state equals the
    new-token activations + q + new KV regardless of context length."""
    cfg = _small()
    off = DecodeOffload(cfg, channels=4, numeric=True, kv_offload=True)
    off.kv_prefill(0, 140)
    recs = [off.step(1, request_ids=[0]) for _ in range(4)]
    assert len({r.h2d_bytes for r in recs[1:]}) == 1
    assert max(r.attn_max_err for r in recs) < 2e-4
    assert recs[-1].attn_cycles > 0
    assert recs[-1].kv_tokens == 144
    # later steps see a longer context: attention cycles grow, h2d not
    assert recs[-1].attn_cycles >= recs[0].attn_cycles


def test_offload_attention_async_matches_reference():
    cfg = _small()
    off = DecodeOffload(cfg, channels=4, numeric=True, async_mode=True,
                        kv_offload=True)
    off.kv_prefill("r", 40)
    r1 = off.step(1, request_ids=["r"])
    r2 = off.step(1, request_ids=["r"])
    assert max(r1.attn_max_err, r2.attn_max_err) < 2e-4
    assert r1.h2d_bytes == r2.h2d_bytes
    assert r2.overlapped and r2.attn_cycles > 0
    # the DAG overlaps attention with the step's other ops: the step
    # makespan grows by less than the summed attention op makespans
    assert r2.pim_cycles < r2.attn_cycles + sum(
        s.pim_cycles for s in [r2]) + r2.attn_cycles


def test_offload_roofline_includes_attention():
    """Satellite: the PIM-vs-host roofline accounts attention FLOPs and
    the host's per-step KV HBM reads."""
    cfg = _small()
    plain = DecodeOffload(cfg, channels=4)
    kvoff = DecodeOffload(cfg, channels=4, kv_offload=True)
    kvoff.kv_prefill(0, 200)
    rp = plain.step(1)
    rk = kvoff.step(1, request_ids=[0])
    assert rk.flops > rp.flops              # attention GEMV flops added
    assert rk.kv_host_bytes > 0
    assert rk.kv_host_bytes == rk.kv_tokens * cfg.head_dim_ * 2 * 2 \
        * max(1, cfg.n_kv_heads) * cfg.n_layers
    roof = kvoff.roofline()
    assert roof["kv"]["append_bytes"] > 0
    assert roof["steady_kv_tokens"] == rk.kv_tokens
    assert roof["steady_attn_cycles"] == rk.attn_cycles
    assert plain.roofline()["kv"] is None


def test_fault_kv_page_loss_reships_as_reupload():
    """Killing a channel wipes its KV pages; the next attention step
    re-ships them (charged as reupload on the cluster link) and the
    numeric cross-check still holds."""
    cfg = _small()
    off = DecodeOffload(cfg, channels=4, stacks=2, numeric=True,
                        kv_offload=True, faults="kill channel 1 @ 1000")
    off.kv_prefill("f", 200)
    recs = [off.step(1, request_ids=["f"]) for _ in range(3)]
    assert off.rt.faults.failed == {1}
    kinds = Counter(k for k, _ in off.rt.stack.link.events)
    assert kinds["reupload"] > 0
    assert max(r.attn_max_err for r in recs) < 2e-4


def test_kv_offload_validation():
    cfg = _small()
    off = DecodeOffload(cfg, channels=4)
    with pytest.raises(ValueError):
        off.kv_prefill(0, 10)               # kv_offload not enabled
    off2 = DecodeOffload(cfg, channels=4, kv_offload=True)
    with pytest.raises(ValueError):
        off2.kv_prefill(0, 0)
    with pytest.raises(ValueError):
        DecodeOffload(cfg.replace(head_dim=256), channels=4,
                      kv_offload=True)      # page must span one block


# ---------------------------------------------------------------------------
# empty case: strictly additive
# ---------------------------------------------------------------------------


def test_without_kv_offload_nothing_changes():
    """kv_offload=False must be byte-identical to the pre-KV sidecar:
    same StepRecords (new fields all zero), same ledgers, same trace."""
    cfg = _small()
    a = DecodeOffload(cfg, channels=4)
    b = DecodeOffload(cfg, channels=4)
    ra = a.step(2, request_ids=["x", "y"])  # ids ignored without kv
    rb = b.step(2)
    assert dataclasses.asdict(ra) == dataclasses.asdict(rb)
    assert ra.kv_tokens == 0 and ra.attn_cycles == 0.0
    assert [d.xfer for d in a.rt.stack] == [d.xfer for d in b.rt.stack]
    assert emit_trace(a.rt.stack) == emit_trace(b.rt.stack)


# ---------------------------------------------------------------------------
# serve-loop lifecycle
# ---------------------------------------------------------------------------


def _server(**kw):
    import jax

    from repro.models import model as lm
    from repro.serve.loop import Server

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    off = DecodeOffload(cfg, channels=4, numeric=True, kv_offload=True)
    return Server(cfg, params, cache_len=48, pim_offload=off, **kw), off


def test_serve_loop_kv_lifecycle():
    from repro.serve.loop import Request
    srv, off = _server(slots=2)
    for uid in range(3):
        srv.submit(Request(uid=uid,
                           prompt=RNG.integers(1, 127, 6).astype(np.int32),
                           max_new=4))
    done = srv.run_until_drained()
    assert len(done) == 3
    assert len(off.kv._reqs) == 0           # every retire released its KV
    assert off.kv.append_bytes > 0
    assert max(s.attn_max_err for s in off.steps) < 2e-4


def test_serve_fault_knockout_releases_kv():
    from repro.serve.loop import Request
    srv, off = _server(slots=1, faults="fail slot 0 @ iter 2")
    srv.submit(Request(uid=9,
                       prompt=RNG.integers(1, 127, 6).astype(np.int32),
                       max_new=6))
    srv.run_until_drained()
    # the knocked-out request re-prefilled from scratch and completed
    assert len(srv.completed) == 1
    assert srv.retries_total == 1
    assert len(off.kv._reqs) == 0
