"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.ame_gemm import ame_gemm, vmem_bytes
from repro.kernels.attention import flash_attention
from repro.kernels.elementwise import ame_elementwise
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def randn(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=0.06, rtol=0.06),
       jnp.float16: dict(atol=0.02, rtol=0.02)}


def allclose(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# ame_gemm — shape x dtype x block sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (32, 32, 32), (128, 64, 128), (100, 130, 70), (1, 256, 64),
    (257, 33, 129), (8, 8, 8),
])
def test_ame_gemm_vs_oracle(m, k, n, dtype):
    a, b = randn(m, k, dtype=dtype, scale=0.3), randn(k, n, dtype=dtype, scale=0.3)
    got = ame_gemm(a, b, block_m=32, block_n=32, block_k=32, interpret=True)
    allclose(got, ref.gemm(a, b), dtype)


@pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (64, 32, 128), (128, 128, 64)])
def test_ame_gemm_block_sweep(bm, bn, bk):
    a, b = randn(96, 160, scale=0.3), randn(160, 96, scale=0.3)
    got = ame_gemm(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    allclose(got, ref.gemm(a, b), jnp.float32)


def test_ame_gemm_vmem_claim_fits():
    # default blocks must fit a v5e VMEM (~16 MiB per core) with headroom
    assert vmem_bytes() < 8 * 1024 * 1024


def test_ame_gemm_out_dtype():
    a, b = randn(64, 64, dtype=jnp.bfloat16), randn(64, 64, dtype=jnp.bfloat16)
    got = ame_gemm(a, b, block_m=32, block_n=32, block_k=32,
                   out_dtype=jnp.float32, interpret=True)
    assert got.dtype == jnp.float32


# ---------------------------------------------------------------------------
# elementwise — the fused PEP analogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["add", "sub", "mul"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("m,c", [(128, 2048), (57, 129), (1, 8)])
def test_elementwise_vs_oracle(kind, dtype, m, c):
    a, b = randn(m, c, dtype=dtype), randn(m, c, dtype=dtype)
    got = ame_elementwise(a, b, kind=kind, block_m=64, block_c=128,
                          interpret=True)
    allclose(got, ref.elementwise(kind, a, b), dtype)


def test_elementwise_fused_relu():
    a, b = randn(64, 64), randn(64, 64)
    got = ame_elementwise(a, b, kind="add", relu=True, block_m=32,
                          block_c=32, interpret=True)
    allclose(got, ref.elementwise("add", a, b, relu=True), jnp.float32)


# ---------------------------------------------------------------------------
# ssd_scan — chunked vs sequential recurrence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 64, 16, 8, 16), (1, 100, 32, 16, 32), (3, 33, 8, 4, 16),
    (1, 16, 8, 8, 16),
])
def test_ssd_scan_vs_recurrence(bh, t, p, n, chunk, dtype):
    x = randn(bh, t, p, dtype=dtype, scale=0.5)
    log_a = -jnp.abs(randn(bh, t, dtype=jnp.float32, scale=0.2))
    b = randn(bh, t, n, dtype=dtype, scale=0.5)
    c = randn(bh, t, n, dtype=dtype, scale=0.5)
    got = ssd_scan(x, log_a, b, c, chunk=chunk, interpret=True)
    want = jax.vmap(ref.ssd_scan)(x, log_a, b, c)
    tol = dict(atol=1e-4, rtol=1e-3) if dtype == jnp.float32 else \
        dict(atol=0.08, rtol=0.08)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_ssd_state_carries_across_chunks():
    """A long-decay sequence: late outputs must see early inputs."""
    bh, t, p, n = 1, 64, 4, 4
    x = jnp.zeros((bh, t, p)).at[0, 0].set(1.0)      # impulse at t=0
    log_a = jnp.full((bh, t), -0.01)                  # slow decay
    b = jnp.ones((bh, t, n))
    c = jnp.ones((bh, t, n))
    got = ssd_scan(x, log_a, b, c, chunk=16, interpret=True)
    assert float(jnp.abs(got[0, -1]).max()) > 0.1     # impulse visible at end


# ---------------------------------------------------------------------------
# flash attention — causal, windowed, decode-aligned
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,tq,tk,d,causal,window", [
    (2, 64, 64, 32, True, 0),
    (1, 128, 128, 64, True, 0),
    (1, 100, 100, 32, True, 0),       # ragged seq vs block
    (2, 64, 64, 32, False, 0),
    (1, 128, 128, 32, True, 48),      # sliding window
    (1, 16, 128, 32, True, 0),        # chunked decode: q tail-aligned
])
def test_flash_attention_vs_oracle(bh, tq, tk, d, causal, window, dtype):
    q = randn(bh, tq, d, dtype=dtype, scale=0.5)
    k = randn(bh, tk, d, dtype=dtype, scale=0.5)
    v = randn(bh, tk, d, dtype=dtype, scale=0.5)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = jax.vmap(lambda q_, k_, v_: ref.attention(
        q_, k_, v_, causal=causal, window=window))(q, k, v)
    allclose(got, want, dtype)


def test_flash_attention_block_sweep():
    q = randn(1, 96, 32, scale=0.5)
    k = randn(1, 96, 32, scale=0.5)
    v = randn(1, 96, 32, scale=0.5)
    want = jax.vmap(ref.attention)(q, k, v)
    for bq, bk in [(16, 16), (32, 96), (96, 32)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        allclose(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# chunked-jnp SSD (the XLA-lowered production path) vs sequential oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 64, 16, 8, 16), (1, 100, 32, 16, 32), (3, 33, 8, 4, 16),
])
def test_ssd_chunked_jnp_vs_recurrence(bh, t, p, n, chunk):
    from repro.kernels.ssd_scan import ssd_chunked_jnp
    x = randn(bh, t, p, scale=0.5)
    log_a = -jnp.abs(randn(bh, t, dtype=jnp.float32, scale=0.2))
    b = randn(bh, t, n, scale=0.5)
    c = randn(bh, t, n, scale=0.5)
    got = ssd_chunked_jnp(x, log_a, b, c, chunk=chunk)
    want = jax.vmap(ref.ssd_scan)(x, log_a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_ssd4_vs_recurrence():
    from repro.kernels import ops
    b_, h_, t_, p_, n_ = 2, 3, 64, 8, 4
    x = randn(b_, h_, t_, p_, scale=0.5)
    log_a = -jnp.abs(randn(b_, h_, t_, dtype=jnp.float32, scale=0.2))
    bb = randn(b_, h_, t_, n_, scale=0.5)
    cc = randn(b_, h_, t_, n_, scale=0.5)
    got = ops.ssd4(x, log_a, bb, cc, chunk=16)
    want = jax.vmap(jax.vmap(ref.ssd_scan))(x, log_a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
