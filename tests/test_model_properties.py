"""Model-level property tests: causality, backend equivalence, scaling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get
from repro.models import model as lm
from repro.models.layers import PALLAS, XLA

RNG = np.random.default_rng(11)


def tiny(name, **kw):
    import dataclasses
    cfg = get(name).reduced().replace(n_layers=2, d_model=64, n_heads=4,
                                      n_kv_heads=2, d_ff=96, vocab_size=128,
                                      head_dim=None, **kw)
    if cfg.ssm:
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, d_state=8,
                                                  head_dim=8, chunk=16))
    if cfg.moe:
        # capacity drops are deliberately non-causal at train time (see
        # models/moe.py); ample capacity isolates the network's causality
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-370m",
                                  "deepseek-v3-671b"])
@given(flip=st.integers(8, 15), seed=st.integers(0, 99))
@settings(max_examples=5, deadline=None)
def test_causality(name, flip, seed):
    """Changing token j must not change any logit at positions < j."""
    cfg = tiny(name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    toks2 = toks.at[0, flip].set((toks[0, flip] + 1) % cfg.vocab_size)

    def logits(tk):
        h, _, _ = lm._family_fns(cfg)[1](
            params["stack"],
            params["embed"]["table"].astype(jnp.float32)[tk],
            cfg, positions=jnp.arange(16)[None], caches=None)
        return h

    l1, l2 = logits(toks), logits(toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :flip]),
                               np.asarray(l2[:, :flip]), atol=1e-5)
    # and the flipped position itself must differ (no dead inputs)
    assert float(jnp.abs(l1[:, flip:] - l2[:, flip:]).max()) > 1e-6


def test_encoder_is_not_causal():
    cfg = tiny("hubert-xlarge")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    frames = jnp.asarray(RNG.standard_normal((1, 16, 64)) * 0.1, jnp.float32)
    f2 = frames.at[0, 12].add(1.0)
    from repro.models.transformer import decoder_apply
    h1, _, _ = decoder_apply(params["stack"], frames, cfg,
                             positions=jnp.arange(16)[None], causal=False)
    h2, _, _ = decoder_apply(params["stack"], f2, cfg,
                             positions=jnp.arange(16)[None], causal=False)
    # bidirectional: early positions DO see the late change
    assert float(jnp.abs(h1[:, :12] - h2[:, :12]).max()) > 1e-6


@pytest.mark.parametrize("name", ["qwen3-1.7b", "gemma-2b"])
def test_pallas_backend_matches_xla(name):
    """The AME kernel substrate is a drop-in for XLA matmuls: the full
    model loss agrees between backends (interpret-mode kernels on CPU)."""
    cfg = tiny(name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 16)),
                               jnp.int32),
        "loss_mask": jnp.ones((2, 16), jnp.float32),
    }
    l_xla, _ = lm.loss_fn(params, batch, cfg, backend=XLA)
    l_pal, _ = lm.loss_fn(params, batch, cfg, backend=PALLAS)
    assert abs(float(l_xla) - float(l_pal)) < 5e-3, (float(l_xla),
                                                     float(l_pal))


def test_loss_scales_with_random_vs_learnable_targets():
    """CE on targets == inputs-shifted (learnable) must be below CE on
    unrelated random targets after a few gradient steps."""
    from repro.optim import adamw
    cfg = tiny("qwen3-1.7b")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    oc = adamw.AdamWConfig(peak_lr=5e-3, warmup_steps=2, total_steps=40,
                           weight_decay=0.0)
    opt = adamw.init(params, oc)
    toks = jnp.asarray(RNG.integers(0, 64, (4, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
             "loss_mask": jnp.ones((4, 16), jnp.float32)}

    @jax.jit
    def step(p, s):
        (l, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, batch, cfg), has_aux=True)(p)
        p2, s2, _ = adamw.apply(p, g, s, oc)
        return p2, s2, l

    losses = []
    for _ in range(30):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5   # memorizes the fixed batch
