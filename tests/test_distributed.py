"""Distribution-layer integration tests (subprocess: device count must be
set before jax initializes)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def run(args, env=None, timeout=520):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, timeout=timeout, cwd=ROOT,
                          env=env or ENV)


@pytest.mark.slow
def test_distributed_train_example_4dev():
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = run([str(ROOT / "examples" / "distributed_train.py")], env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "distributed_train OK" in r.stdout
    assert "tp_mode=allreduce" in r.stdout and "tp_mode=allgather" in r.stdout


@pytest.mark.slow
def test_dryrun_cell_multi_pod():
    """One full-config cell lowers+compiles on the 512-chip multi-pod mesh
    (the dry-run path end to end, including the roofline extraction)."""
    out = ROOT / "results" / "dryrun" / "qwen3-1.7b.decode_32k.multi.json"
    r = run(["-m", "repro.launch.dryrun", "--arch", "qwen3-1.7b",
             "--shape", "decode_32k", "--mesh", "multi", "--force"])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    rec = json.loads(out.read_text())
    assert rec["ok"] and rec["flops"] > 0
    assert rec["memory"]["peak_bytes_per_device"] < 16 * 2 ** 30


def test_sharding_rules_cover_all_archs():
    """Every parameter of every full config gets a valid spec on a mock
    16x16 mesh (divisibility-checked), and FSDP/TP axes land where the
    rules say."""
    import jax
    from repro.configs import all_names, get
    from repro.launch.params import param_shapes
    from repro.sharding import rules

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for name in all_names():
        cfg = get(name)
        shapes = param_shapes(cfg)
        specs = rules.param_pspecs(cfg, shapes, FakeMesh())
        for (path, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: hasattr(x, "index"))):
            assert len(spec) <= leaf.ndim, (name, path)
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                size = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    size *= FakeMesh.shape[a]
                assert leaf.shape[dim] % size == 0, (name, path, spec)


def test_embedding_and_ffn_sharded_on_model_axis():
    from repro.configs import get
    from repro.launch.params import param_shapes
    from repro.sharding import rules

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get("command-r-35b")
    specs = rules.param_pspecs(cfg, param_shapes(cfg), FakeMesh())
    emb = specs["embed"]["table"]
    assert emb[0] == "model"                      # vocab on model
    wi = specs["stack"]["dense_stack"]["mlp"]["wi"]["w"]
    assert wi[-1] == "model" and wi[-2] == "data"  # TP + FSDP
    wo = specs["stack"]["dense_stack"]["mlp"]["wo"]["w"]
    assert wo[-2] == "model"                       # row-sharded (allreduce TP)
