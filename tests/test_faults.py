"""Fault injection + graceful degradation tests (repro.faults).

Invariants under test, in rough dependency order:

* An **empty FaultPlan is strictly additive**: ledgers compare ``==``
  and emitted traces are byte-identical to a fault-free runtime, in
  both serialized and async modes.
* **Fail-stop channels are excluded** from placement decompositions
  (the PR-5 ``channels=`` subset machinery) and reported in
  ``RuntimeReport.failed_channels`` + the degraded summary section.
* **Lost residency is re-shipped**: shards resident on failed channels
  are declared lost and their re-upload charged as host-link
  ``reupload`` traffic at next use; pinned undrained outputs replay
  onto survivors and ``to_host`` still matches the FP32 reference.
* **Transient link corruption** charges seeded retry-with-backoff busy
  windows — same seed, same ledgers; **degradation windows** stretch
  in-window link charges.
* ``# FAULT`` / ``# RECOVER`` markers are replay-neutral and round-trip
  through ``parse_trace``.
* ``DecodeOffload`` **fails over** a dead home stack's weights to the
  least-loaded survivor (numeric mode cross-checks vs XLA FP32 after
  the failover), and ``Server`` degrades gracefully: slot knock-outs
  requeue with capped exponential backoff, admission control sheds
  load against surviving capacity, and ``run_until_drained`` never
  masquerades a hung loop as success.
"""
import warnings

import numpy as np
import pytest

from repro.configs import get
from repro.faults import (
    ChannelFault,
    FaultPlan,
    LinkDegradation,
    LinkTransient,
    NoHealthyChannelsError,
    ServeFault,
    StackFault,
    as_plan,
)
from repro.runtime import PIMRuntime
from repro.runtime.trace import emit_trace, parse_trace
from repro.serve.offload import DecodeOffload

RNG = np.random.default_rng(3)


def rand(*shape, scale=0.1):
    return (RNG.standard_normal(shape) * scale).astype(np.float16)


def _workload(rt, execute=False):
    """A small mixed workload touching place/gemv/gemm."""
    h = rt.place((2048, 128), placement="row-striped", other_dim=1) \
        if not execute else rt.place(rand(2048, 128),
                                     placement="row-striped", other_dim=1)
    x = rand(128)
    rt.gemv(h, x, placement="row-striped", execute=execute)
    rt.gemv(h, x, placement="row-striped", execute=execute)
    return h


# ---------------------------------------------------------------------------
# empty plan: strictly additive
# ---------------------------------------------------------------------------


def test_empty_plan_is_strictly_additive_serialized():
    plain = PIMRuntime(channels=4, stacks=2)
    faulted = PIMRuntime(channels=4, stacks=2, faults=FaultPlan())
    assert faulted.faults is not None and faulted.faults.plan.empty
    _workload(plain)
    _workload(faulted)
    assert plain.stack.link == faulted.stack.link
    assert emit_trace(plain.stack) == emit_trace(faulted.stack)
    assert faulted.faults.counters == {}
    assert faulted.faults.instants == []


def test_empty_plan_is_strictly_additive_async():
    plain = PIMRuntime(channels=4, stacks=2, async_mode=True)
    faulted = PIMRuntime(channels=4, stacks=2, async_mode=True,
                         faults=FaultPlan())
    for rt in (plain, faulted):
        h = rt.place((2048, 128), placement="row-striped", other_dim=1)
        x = rand(128)
        rt.gemv(h, x, placement="row-striped", execute=False)
        rt.gemv(h, x, placement="row-striped", execute=False)
    assert plain.timeline.now == faulted.timeline.now
    assert plain.stack.link == faulted.stack.link
    assert emit_trace(plain.stack) == emit_trace(faulted.stack)


def test_empty_plan_dsl_string_accepted():
    rt = PIMRuntime(channels=2, faults="# nothing to see\n")
    assert rt.faults.plan.empty


# ---------------------------------------------------------------------------
# fail-stop channels: exclusion + reporting
# ---------------------------------------------------------------------------


def test_channel_fault_excluded_from_decomposition():
    plan = FaultPlan(channel_faults=(ChannelFault(at_cycle=0.0, channel=1),))
    rt = PIMRuntime(channels=4, faults=plan)
    a, b = rand(512, 64), rand(64, 32)
    _, rep = rt.gemm(a, b, placement="row-striped")
    assert rep.failed_channels == (1,)
    busy = {c.channel: c.busy_cycles for c in rep.per_channel}
    assert busy.get(1, 0.0) == 0.0
    assert sum(1 for c in rep.per_channel if c.busy_cycles > 0) == 3
    assert "degraded:" in rep.summary()
    assert "failed_channels=[1]" in rep.summary()


def test_fault_free_report_has_no_degraded_section():
    rt = PIMRuntime(channels=4)
    _, rep = rt.gemm(rand(512, 64), rand(64, 32), placement="row-striped")
    assert rep.failed_channels == ()
    assert "degraded:" not in rep.summary()


def test_all_channels_failed_raises():
    plan = FaultPlan(channel_faults=tuple(
        ChannelFault(at_cycle=0.0, channel=c) for c in range(2)))
    rt = PIMRuntime(channels=2, faults=plan)
    with pytest.raises(NoHealthyChannelsError):
        rt.gemm(rand(256, 64), rand(64, 16), placement="row-striped")


def test_explicit_subset_remaps_to_survivors():
    plan = FaultPlan(channel_faults=(ChannelFault(at_cycle=0.0, channel=2),))
    rt = PIMRuntime(channels=4, faults=plan)
    _, rep = rt.gemm(rand(512, 64), rand(64, 32), placement="row-striped",
                     channels=(1, 2, 3))
    busy = {c.channel: c.busy_cycles for c in rep.per_channel}
    assert busy.get(2, 0.0) == 0.0
    assert busy.get(1, 0.0) > 0 and busy.get(3, 0.0) > 0


def test_fault_out_of_range_rejected_at_construction():
    with pytest.raises(ValueError):
        PIMRuntime(channels=2, faults=FaultPlan(
            channel_faults=(ChannelFault(at_cycle=0.0, channel=9),)))
    with pytest.raises(ValueError):
        PIMRuntime(channels=2, stacks=2, faults=FaultPlan(
            stack_faults=(StackFault(at_cycle=0.0, stack=5),)))


# ---------------------------------------------------------------------------
# lost residency -> re-upload; pinned outputs -> replay
# ---------------------------------------------------------------------------


def test_stack_fault_loses_residency_and_recharges_reupload():
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=10.0, stack=1),))
    rt = PIMRuntime(channels=4, stacks=2, faults=plan)
    h = rt.place((2048, 128), placement="row-striped", other_dim=1)
    assert all(d.resident for d in rt.stack), \
        "scenario needs shards on every channel"
    x = rand(128)
    rt.gemv(h, x, placement="row-striped", execute=False)       # fault fires at op boundary
    assert rt.faults.failed == {4, 5, 6, 7}
    # survivors held their shards; the lost half re-ships on next use and
    # is charged on the host link as reupload traffic
    link_before = [k for k, _ in rt.stack.link.events]
    _, rep = rt.gemv(h, x, placement="row-striped", execute=False)
    reups = [(k, n) for k, n in rt.stack.link.events if k == "reupload"]
    assert len(reups) == 4                       # one per surviving channel
    assert sum(n for _, n in reups) == \
        rt.faults.counters["reupload_bytes"]
    assert rt.faults.counters["lost_resident_bytes"] > 0
    assert rt.faults.counters["stack_failures"] == 1
    assert rt.faults.counters["channel_failures"] == 4


def test_fault_and_recover_markers_roundtrip():
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=10.0, stack=1),))
    rt = PIMRuntime(channels=4, stacks=2, faults=plan)
    h = rt.place((2048, 128), placement="row-striped", other_dim=1)
    x = rand(128)
    rt.gemv(h, x, placement="row-striped", execute=False)
    rt.gemv(h, x, placement="row-striped", execute=False)
    st = parse_trace(emit_trace(rt.stack))
    assert set(st.fault_channels) == {4, 5, 6, 7}
    assert all(cyc == 10.0 for cyc in st.fault_channels.values())
    # recovery landed on the surviving stack's channels
    assert set(st.recover_bytes) == {0, 1, 2, 3}
    assert sum(st.recover_bytes.values()) == \
        rt.faults.counters["reupload_bytes"]


def test_pinned_output_replays_onto_survivor_numeric():
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=5000.0, stack=1),))
    rt = PIMRuntime(channels=4, stacks=2, faults=plan)
    a, b = rand(2048, 64), rand(64, 8)
    oh = rt.gemm(a, b, placement="row-striped", keep_output=True)[0]
    assert {c for c, _ in oh.pending_d2h} == set(range(8))
    rt.gemm(a, b, placement="row-striped", execute=False)   # fires fault
    # undrained shards of the dead stack were replayed onto survivors
    assert {c for c, _ in oh.pending_d2h} <= {0, 1, 2, 3}
    assert rt.faults.counters["replayed_outputs"] == 4
    assert rt.faults.counters["replay_cycles"] > 0
    y = oh.to_host()
    ref = a.astype(np.float32) @ b.astype(np.float32)
    assert float(np.max(np.abs(np.asarray(y, np.float32) - ref))) < 0.05


# ---------------------------------------------------------------------------
# link perturbations: transient retries + degradation windows
# ---------------------------------------------------------------------------


def _flaky_run(seed=7):
    plan = FaultPlan(seed=seed, link_transient=LinkTransient(prob=0.9))
    rt = PIMRuntime(channels=4, stacks=2, faults=plan)
    h = rt.place((2048, 256), placement="row-striped", other_dim=1)
    rt.gemv(h, rand(256), placement="row-striped", execute=False)
    rt.gemv(h, rand(256), placement="row-striped", execute=False)
    return rt


def test_link_transient_charges_retry_windows():
    rt = _flaky_run()
    # cross-stack reduction traffic crosses the flaky link -> retries
    retries = [(k, n) for k, n in rt.stack.link.events if k == "retry"]
    assert retries, "p=0.9 transient must produce retransmissions"
    assert rt.faults.counters["link_retries"] == len(retries)
    assert rt.faults.counters["retransmitted_bytes"] == \
        sum(n for _, n in retries)


def test_link_transient_same_seed_identical_ledgers():
    ra, rb = _flaky_run(seed=7), _flaky_run(seed=7)
    assert ra.stack.link == rb.stack.link
    assert ra.faults.counters == rb.faults.counters
    # a different seed draws a different retry pattern
    rc = _flaky_run(seed=8)
    assert (rc.stack.link.events != ra.stack.link.events
            or rc.stack.link.cycles != ra.stack.link.cycles)


def test_link_degradation_window_stretches_charges():
    base = PIMRuntime(channels=4, stacks=2)
    slow = PIMRuntime(channels=4, stacks=2, faults=FaultPlan(
        link_degradations=(LinkDegradation(start_cycle=0.0,
                                           end_cycle=1e12, factor=2.0),)))
    for rt in (base, slow):
        h = rt.place((2048, 256), placement="row-striped", other_dim=1)
        rt.gemv(h, rand(256), placement="row-striped", execute=False)
    assert slow.stack.link.cycles == 2 * base.stack.link.cycles
    assert slow.stack.link.bytes == base.stack.link.bytes   # extra is time
    assert any(k == "degrade" for k, _ in slow.stack.link.events)
    assert slow.faults.counters["degraded_cycles"] == \
        slow.stack.link.cycles - base.stack.link.cycles


def test_link_degradation_outside_window_is_free():
    rt = PIMRuntime(channels=4, stacks=2, faults=FaultPlan(
        link_degradations=(LinkDegradation(start_cycle=1e11,
                                           end_cycle=1e12, factor=4.0),)))
    h = rt.place((2048, 256), placement="row-striped", other_dim=1)
    rt.gemv(h, rand(256), placement="row-striped", execute=False)
    assert not any(k == "degrade" for k, _ in rt.stack.link.events)


# ---------------------------------------------------------------------------
# plan DSL + coercion
# ---------------------------------------------------------------------------


def test_fault_plan_dsl_parses_every_clause():
    plan = FaultPlan.parse("""
        # a full scenario
        kill channel 3 @ 1000
        kill stack 1 @ 2e6
        flaky link p=0.01 backoff=32 retries=4 cap=1024
        slow link x2.5 @ 100:900
        fail slot 0 @ iter 5
    """, seed=42)
    assert plan.seed == 42 and not plan.empty
    assert plan.channel_faults == (ChannelFault(at_cycle=1000.0, channel=3),)
    assert plan.stack_faults == (StackFault(at_cycle=2e6, stack=1),)
    lt = plan.link_transient
    assert (lt.prob, lt.backoff_cycles, lt.max_retries,
            lt.backoff_cap_cycles) == (0.01, 32, 4, 1024)
    d = plan.link_degradations[0]
    assert (d.start_cycle, d.end_cycle, d.factor) == (100.0, 900.0, 2.5)
    assert plan.serve_faults == (ServeFault(at_iter=5, slot=0),)


def test_fault_plan_dsl_rejects_garbage():
    with pytest.raises(ValueError):
        FaultPlan.parse("explode everything @ 5")
    with pytest.raises(ValueError):
        FaultPlan.parse("flaky link p=0.5; flaky link p=0.6")


def test_as_plan_coerces_and_rejects():
    assert as_plan("kill ch 0 @ 1").channel_faults[0].channel == 0
    p = FaultPlan()
    assert as_plan(p) is p
    with pytest.raises(TypeError):
        as_plan(123)


# ---------------------------------------------------------------------------
# DecodeOffload: stack failover (numeric cross-check vs XLA FP32)
# ---------------------------------------------------------------------------


def _small_cfg():
    return get("qwen3-1.7b").reduced()


def test_decode_offload_stack_failover_numeric_serialized():
    cfg = _small_cfg()
    # kill the last layer block's home stack between step 1 and step 2
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=28529.0, stack=3),))
    off = DecodeOffload(cfg, channels=4, stacks=4, numeric=True, faults=plan)
    assert off.surviving_fraction == 1.0
    r1 = off.step(2)
    r2 = off.step(2)
    r3 = off.step(2)
    for rec in (r1, r2, r3):
        assert rec.numeric and rec.numeric_max_err < off.atol
        assert rec.logits_max_err < off.atol
    inj = off.rt.faults
    assert inj.counters["stack_failovers"] == 1
    assert inj.counters["failover_migrated_bytes"] > 0
    assert 3 not in off.stack_map          # dead stack remapped away
    assert off.surviving_fraction == pytest.approx(0.75)
    # the weight migration crossed the host link
    assert any(k == "reupload" for k, _ in off.rt.stack.link.events)


def test_decode_offload_stack_failover_numeric_async():
    cfg = _small_cfg()
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=28529.0, stack=3),))
    off = DecodeOffload(cfg, channels=4, stacks=4, numeric=True,
                        async_mode=True, faults=plan)
    for _ in range(3):
        rec = off.step(2)
        assert rec.numeric_max_err < off.atol
    assert off.rt.faults.counters["stack_failovers"] == 1
    assert 3 not in off.stack_map
    assert off.surviving_fraction == pytest.approx(0.75)


def test_decode_offload_unrecoverable_when_no_survivor():
    cfg = _small_cfg()
    # single stack: nothing to fail over to — the loss is total, whether
    # it lands during weight upload or during a decode step
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=1.0, stack=0),))
    with pytest.raises(NoHealthyChannelsError):
        off = DecodeOffload(cfg, channels=2, stacks=1, faults=plan)
        off.step(1)
        off.step(1)


# ---------------------------------------------------------------------------
# Server: graceful degradation
# ---------------------------------------------------------------------------


def _server(**kw):
    import jax

    from repro.models import model as lm
    from repro.serve.loop import Server

    cfg = get("qwen3-1.7b").reduced().replace(n_layers=2, d_model=64,
                                              d_ff=128, vocab_size=128)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    return Server(cfg, params, cache_len=48, **kw)


def _req(uid, n=6, plen=4):
    from repro.serve.loop import Request
    return Request(uid=uid,
                   prompt=RNG.integers(0, 127, plen).astype(np.int32),
                   max_new=n)


def test_server_rejects_overlong_prompt_at_submit():
    srv = _server(slots=1)
    with pytest.raises(ValueError, match="cache_len"):
        srv.submit(_req(0, plen=48))
    with pytest.raises(ValueError, match="cache_len"):
        srv.submit(_req(1, plen=64))
    srv.submit(_req(2, plen=47))           # boundary: one slot to decode
    assert len(srv.run_until_drained()) == 1


def test_server_slot_fault_requeues_with_backoff():
    from repro.faults.plan import as_plan as _ap
    srv = _server(slots=2, faults="fail slot 0 @ iter 2", max_retries=2)
    for i in range(3):
        srv.submit(_req(i))
    done = srv.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert srv.retries_total == 1
    knocked = [r for r in done if r.retries == 1]
    assert len(knocked) == 1
    # the knocked-out request restarted from its prompt after backoff
    assert len(knocked[0].out_tokens) == 6


def test_server_fails_request_past_max_retries():
    srv = _server(slots=1, max_retries=1, retry_backoff_steps=1,
                  faults=FaultPlan(serve_faults=(
                      ServeFault(at_iter=2, slot=0),
                      ServeFault(at_iter=8, slot=0))))
    srv.submit(_req(7, n=8))
    done = srv.run_until_drained()
    assert done == [] and [r.uid for r in srv.failed_requests] == [7]
    assert srv.latency_summary()["failed"] == 1


def test_server_admission_control_sheds_over_cap():
    from repro.serve.loop import AdmissionError
    srv = _server(slots=1, max_queue=2)
    srv.submit(_req(0))
    srv.submit(_req(1))
    with pytest.raises(AdmissionError):
        srv.submit(_req(2))
    assert srv.shed == 1
    assert len(srv.run_until_drained()) == 2
    assert srv.latency_summary()["shed"] == 1


def test_server_admission_cap_scales_with_surviving_capacity():
    class _Half:
        surviving_fraction = 0.5
    srv = _server(slots=1, max_queue=4)
    srv.pim_offload = _Half()              # duck-typed sidecar stand-in
    from repro.serve.loop import AdmissionError
    srv.submit(_req(0))
    srv.submit(_req(1))
    with pytest.raises(AdmissionError):    # cap 4 * 0.5 = 2
        srv.submit(_req(2))
    srv.pim_offload = None


def test_server_run_until_drained_raises_on_undrained():
    srv = _server(slots=1)
    srv.submit(_req(0, n=40))
    with pytest.raises(RuntimeError, match="max_iters"):
        srv.run_until_drained(max_iters=3)
    assert srv.undrained == 1


def test_server_run_until_drained_warns_on_undrained():
    srv = _server(slots=1)
    srv.submit(_req(0, n=40))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = srv.run_until_drained(max_iters=3, on_undrained="warn")
    assert len(w) == 1 and issubclass(w[0].category, RuntimeWarning)
    assert out == [] and srv.latency_summary()["undrained"] == 1
    with pytest.raises(ValueError):
        srv.run_until_drained(on_undrained="ignore")


def test_server_step_deadline_counts_misses():
    srv = _server(slots=1, step_deadline_s=0.0)
    srv.submit(_req(0, n=3))
    srv.run_until_drained()
    assert srv.deadline_misses > 0
    assert srv.latency_summary()["deadline_misses"] == srv.deadline_misses


# ---------------------------------------------------------------------------
# observability surfaces
# ---------------------------------------------------------------------------


def test_fault_counters_mirror_into_metrics_registry():
    from repro.obs.metrics import MetricsRegistry
    m = MetricsRegistry()
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=10.0, stack=1),))
    rt = PIMRuntime(channels=4, stacks=2, metrics=m, faults=plan)
    h = rt.place((2048, 128), placement="row-striped", other_dim=1)
    rt.gemv(h, rand(128), placement="row-striped", execute=False)
    rt.gemv(h, rand(128), placement="row-striped", execute=False)
    assert m.get("faults.channel_failures").value == 4
    assert m.get("faults.reupload_bytes").value == \
        rt.faults.counters["reupload_bytes"]


def test_chrome_trace_emits_fault_instants():
    import json

    from repro.obs.profile import chrome_trace
    plan = FaultPlan(stack_faults=(StackFault(at_cycle=10.0, stack=1),))
    rt = PIMRuntime(channels=4, stacks=2, faults=plan, profile=True)
    h = rt.place((2048, 128), placement="row-striped", other_dim=1)
    rt.gemv(h, rand(128), placement="row-striped", execute=False)
    rt.gemv(h, rand(128), placement="row-striped", execute=False)
    trace = chrome_trace(rt)
    json.dumps(trace)                      # must stay serializable
    inst = [e for e in trace["traceEvents"]
            if e.get("ph") == "i" and e.get("cat") == "fault"]
    kinds = {e["args"]["kind"] for e in inst}
    assert "fault" in kinds and "recover" in kinds
    assert all(e["s"] == "g" for e in inst)


def test_injector_summary_reports_failed_channels():
    plan = FaultPlan(channel_faults=(ChannelFault(at_cycle=0.0, channel=0),))
    rt = PIMRuntime(channels=4, faults=plan)
    rt.gemm(rand(512, 64), rand(64, 16), placement="row-striped")
    s = rt.faults.summary()
    assert s["failed_channels"] == 1
    assert s["channel_failures"] == 1
