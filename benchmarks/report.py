"""Assemble EXPERIMENTS.md from dry-run artifacts + cost-model benchmarks.

  PYTHONPATH=src:. python -m benchmarks.report
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, all_names, applicable, get
from repro.core import cost as cost_mod
from repro.launch import hw, memmodel
from benchmarks.roofline import RESULTS, derive, fix_note, load_cell, \
    markdown_table, rows

ROOT = Path(__file__).resolve().parents[1]
PERF_LOG = ROOT / "results" / "perf_log.md"


def repro_section() -> str:
    s = cost_mod.summary()
    lines = [
        "## §Paper-reproduction (faithful baseline)",
        "",
        "Validated against the paper's own claims (benchmarks/paper_figures.py"
        " asserts all of these):",
        "",
        "| claim (paper) | reproduced |",
        "|---|---|",
        f"| mfmacc 59.4 FLOP/cycle saturated | "
        f"{s['mfmacc_flop_per_cycle_saturated']:.2f} |",
        f"| mfmacc 14.9 GFLOP/s @250 MHz | "
        f"{s['mfmacc_flop_per_cycle_saturated'] * 250e6 / 1e9:.2f} |",
        f"| 256 MAC-PEP launches at 128x4096 tiles | "
        f"{s['mfmacc_launches_maxtile']:.0f} |",
        f"| setup <1% of runtime at max tile | "
        f"{100 * s['setup_share_maxtile']:.2f}% |",
        f"| <=1/2 of 128 FLOP/cycle peak (1:1 move:compute) | "
        f"{s['mfmacc_flop_per_cycle_saturated']:.1f} <= 64 |",
        f"| beats MPC-Wrapper's 58.1 FLOP/cycle per channel | "
        f"{s['mfmacc_flop_per_cycle_saturated']:.1f} > 58.1 |",
        "| mfsub slower than mfadd (emulated via -1 MUL) | "
        f"{s['sub_flop_per_cycle_saturated']:.1f} < "
        f"{s['add_flop_per_cycle_saturated']:.1f} FLOP/cyc |",
        "| mfmax/mfmin/widening unsupported (Table 1) | raise "
        "UnsupportedOnPIM (tested) |",
        "| numerics: outer-product == inner-product GEMM | bit-exact strict "
        "interpreter vs engine; allclose vs fp32 (tests) |",
        "",
        "Fig 9 scaling (FLOP/cycle vs K at N=1): "
        + ", ".join(f"{k}: {cost_mod.mfmacc_cost(128, k, 1).flop_per_cycle:.1f}"
                    for k in (8, 64, 256, 1024, 2048)),
        "",
    ]
    return "\n".join(lines)


def dryrun_section() -> str:
    lines = [
        "## §Dry-run",
        "",
        "Every live (arch x shape) cell lowered + compiled for BOTH meshes "
        "(single pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips). "
        "`memory_analysis()` peak is measured on the CPU backend, which "
        "float-normalizes bf16 ops into f32 temporaries and double-buffers "
        "concurrent leaf updates — a strict upper bound for the TPU "
        "deployment.  `analytic GiB` is the dtype-true per-chip residency "
        "model (launch/memmodel.py).",
        "",
        "| arch | shape | step | single ok | multi ok | flops/chip | "
        "link B/chip | measured GiB | analytic GiB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in all_names():
        cfg = get(arch)
        for shape in SHAPES:
            ok, why = applicable(cfg, SHAPES[shape])
            if not ok:
                lines.append(f"| {arch} | {shape} | — | SKIP ({why}) | | | "
                             f"| | | |")
                continue
            s = load_cell(arch, shape, "single")
            m = load_cell(arch, shape, "multi")
            est = memmodel.estimate(cfg, SHAPES[shape])
            if not s or not s.get("ok"):
                lines.append(f"| {arch} | {shape} | ? | **FAIL** | | | | | |")
                continue
            lines.append(
                f"| {arch} | {shape} | {s['step']} | ok ({s['compile_s']}s) "
                f"| {'ok' if m and m.get('ok') else '?'} "
                f"| {s['flops']:.3g} "
                f"| {s['collectives']['total_link_bytes']:.3g} "
                f"| {s['memory']['peak_bytes_per_device'] / 2**30:.1f} "
                f"| {est['total'] / 2**30:.1f} "
                f"| {'yes' if est['fits_16g'] else 'NO'} |")
    lines.append("")
    return "\n".join(lines)


def roofline_section() -> str:
    lines = [
        "## §Roofline (single-pod 16x16, per chip)",
        "",
        "Terms from the trip-count-aware HLO analyzer "
        "(launch/hloanalysis.py) over the compiled partitioned module; "
        "hardware: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI. "
        "`MODEL/HLO` = 6ND (or 6·N_active·D) / compiled FLOPs; "
        "`roofline_frac` = useful-compute time / dominant term.",
        "",
        markdown_table("single"),
        "",
        "### Dominant-term notes (one per cell)",
        "",
    ]
    for d in rows("single"):
        lines.append(f"- **{d['arch']} x {d['shape']}** ({d['dominant']}): "
                     f"{fix_note(d)}")
    lines.append("")
    return "\n".join(lines)


def perf_section() -> str:
    if PERF_LOG.exists():
        return PERF_LOG.read_text()
    return ("## §Perf\n\n(hillclimb log pending — see results/perf_log.md)\n")


def main():
    doc = "\n".join([
        "# EXPERIMENTS",
        "",
        "Generated by `benchmarks/report.py` from `results/dryrun/*.json` "
        "(produced by `repro.launch.dryrun`) and the calibrated PIM cost "
        "model.  See DESIGN.md for the system inventory.",
        "",
        repro_section(),
        dryrun_section(),
        roofline_section(),
        perf_section(),
    ])
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(doc.splitlines())} lines)")


if __name__ == "__main__":
    main()
