"""Benchmark driver.  One section per paper table/figure, the device-runtime
multi-pseudo-channel scaling sweep (``channels``), the operand-residency /
serve-offload sweep (``residency`` — also writes the
``results/dryrun/*.pim_offload.json`` BENCH artifact), the roofline summary
(from dry-run artifacts, if present), and kernel micro-checks.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig8       # one section
  PYTHONPATH=src python -m benchmarks.run channels   # scaling sweep
  PYTHONPATH=src python -m benchmarks.run residency  # resident operands
"""
from __future__ import annotations

import sys
import time

import numpy as np


def kernel_microbench():
    """Pallas kernels (interpret mode on CPU) vs jnp reference — correctness
    guard + host-time observability; real perf is the TPU target."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.ame_gemm import ame_gemm

    rows = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)

    def timed(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        return (time.perf_counter() - t0) / 3 * 1e6

    t_ref = timed(lambda: ref.gemm(a, b).block_until_ready())
    t_pal = timed(lambda: ame_gemm(a, b, block_m=128, block_n=128,
                                   block_k=128, interpret=True
                                   ).block_until_ready())
    err = float(jnp.max(jnp.abs(
        ame_gemm(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
        - ref.gemm(a, b))))
    rows.append(("kernel/ame_gemm_256_interpret", t_pal,
                 f"ref_us={t_ref:.0f} max_err={err:.2e}"))
    return rows


def roofline_summary():
    try:
        from benchmarks.roofline import csv_rows
        rows = csv_rows()
        return rows if rows else [("roofline/none", 0.0,
                                   "run launch/dryrun.py first")]
    except Exception as e:  # dry-run artifacts absent
        return [("roofline/error", 0.0, str(e)[:120])]


def main() -> None:
    from benchmarks.paper_figures import ALL
    sections = dict(ALL)
    sections["kernels"] = kernel_microbench
    sections["roofline"] = roofline_summary

    wanted = sys.argv[1:] or list(sections)
    unknown = [k for k in wanted if k not in sections]
    if unknown:
        print(f"unknown section(s) {unknown}; available: {sorted(sections)}",
              file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failures = 0
    for key in wanted:
        try:
            for name, us, derived in sections[key]():
                print(f"{name},{us:.1f},{derived}")
        except AssertionError as e:
            failures += 1
            print(f"{key}/FAILED,0,{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
