"""Benchmark driver.  One section per paper table/figure, the device-runtime
multi-pseudo-channel scaling sweep (``channels``), the operand-residency /
serve-offload sweep (``residency`` — also writes the
``results/dryrun/*.pim_offload.json`` BENCH artifact), the fast-path
microbench (``engine``), the multi-stack cluster scaling sweep
(``cluster`` — makespan parity + scaling-efficiency gates), the roofline
summary (from dry-run artifacts, if present), and kernel micro-checks.

Prints ``name,us_per_call,derived`` CSV and writes
``results/BENCH_runtime.json`` — harness wall-clock per section plus the
``engine`` section's measured fast-vs-reference numbers — so the perf
trajectory of the harness itself is tracked across PRs (CI's
``bench-engine`` job gates on it).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig8       # one section
  PYTHONPATH=src python -m benchmarks.run channels   # scaling sweep
  PYTHONPATH=src python -m benchmarks.run residency  # resident operands
  PYTHONPATH=src python -m benchmarks.run engine     # fast-path gates
  PYTHONPATH=src python -m benchmarks.run cluster    # multi-stack scaling
  PYTHONPATH=src python -m benchmarks.run decode     # async decode overlap
  PYTHONPATH=src python -m benchmarks.run obs        # observability gates
  PYTHONPATH=src python -m benchmarks.run kv         # paged-KV attention
  PYTHONPATH=src python -m benchmarks.run serve      # SLO frontier sweep
  PYTHONPATH=src python -m benchmarks.run moe        # routed expert parallel
  PYTHONPATH=src python -m benchmarks.run obs --out /tmp/bench.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

BENCH_RUNTIME = Path(__file__).resolve().parents[1] / "results" \
    / "BENCH_runtime.json"


def kernel_microbench():
    """Pallas kernels (interpret mode on CPU) vs jnp reference — correctness
    guard + host-time observability; real perf is the TPU target."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.ame_gemm import ame_gemm

    rows = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)

    def timed(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        return (time.perf_counter() - t0) / 3 * 1e6

    t_ref = timed(lambda: ref.gemm(a, b).block_until_ready())
    t_pal = timed(lambda: ame_gemm(a, b, block_m=128, block_n=128,
                                   block_k=128, interpret=True
                                   ).block_until_ready())
    err = float(jnp.max(jnp.abs(
        ame_gemm(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
        - ref.gemm(a, b))))
    rows.append(("kernel/ame_gemm_256_interpret", t_pal,
                 f"ref_us={t_ref:.0f} max_err={err:.2e}"))
    return rows


def roofline_summary():
    try:
        from benchmarks.roofline import csv_rows
        rows = csv_rows()
        return rows if rows else [("roofline/none", 0.0,
                                   "run launch/dryrun.py first")]
    except Exception as e:  # dry-run artifacts absent
        return [("roofline/error", 0.0, str(e)[:120])]


def write_bench_runtime(section_s: dict, out: Path = None) -> None:
    """Update the BENCH_runtime.json artifact: harness wall-clock per
    section + the engine section's fast-path measurements (if it ran).

    Merges into the existing file so a partial run (e.g. ``run fig8``)
    refreshes only its own sections and never wipes the engine metrics
    the artifact exists to track across PRs.  ``out`` redirects the
    artifact (``--out``) — e.g. CI's bench-trend step writes a fresh
    JSON next to the committed baseline and diffs the two.
    """
    from benchmarks.paper_figures import LAST_CLUSTER_METRICS, \
        LAST_DECODE_METRICS, LAST_ENGINE_METRICS, LAST_FAULTS_METRICS, \
        LAST_KV_METRICS, LAST_MOE_METRICS, LAST_OBS_METRICS, \
        LAST_SERVE_METRICS
    out = Path(out) if out is not None else BENCH_RUNTIME
    out.parent.mkdir(parents=True, exist_ok=True)
    rec = {"generated_by": "benchmarks.run", "section_wall_s": {},
           "engine": {}, "cluster": {}, "decode": {}, "obs": {},
           "faults": {}, "kv": {}, "serve": {}, "moe": {}}
    if out.exists():
        try:
            prev = json.load(open(out))
            rec["section_wall_s"] = prev.get("section_wall_s", {})
            rec["engine"] = prev.get("engine", {})
            rec["cluster"] = prev.get("cluster", {})
            rec["decode"] = prev.get("decode", {})
            rec["obs"] = prev.get("obs", {})
            rec["faults"] = prev.get("faults", {})
            rec["kv"] = prev.get("kv", {})
            rec["serve"] = prev.get("serve", {})
            rec["moe"] = prev.get("moe", {})
        except (OSError, ValueError):
            pass
    rec["section_wall_s"].update(
        {k: round(v, 4) for k, v in section_s.items()})
    # merge (never replace): a partially-failed engine section must not
    # wipe previously recorded trajectory keys
    rec["engine"].update({k: round(v, 6)
                          for k, v in LAST_ENGINE_METRICS.items()})
    rec["cluster"].update({k: round(v, 6)
                           for k, v in LAST_CLUSTER_METRICS.items()})
    rec["decode"].update({k: round(v, 6)
                          for k, v in LAST_DECODE_METRICS.items()})
    rec["obs"].update({k: round(v, 6)
                       for k, v in LAST_OBS_METRICS.items()})
    rec["faults"].update({k: round(v, 6)
                          for k, v in LAST_FAULTS_METRICS.items()})
    rec["kv"].update({k: round(v, 6)
                      for k, v in LAST_KV_METRICS.items()})
    # serve/moe merge unrounded: their "frontier"/"replication_sweep"
    # values are nested structures (already rounded at the leaves)
    rec["serve"].update(LAST_SERVE_METRICS)
    rec["moe"].update(LAST_MOE_METRICS)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
        f.write("\n")


def main() -> None:
    from benchmarks.paper_figures import ALL
    sections = dict(ALL)
    sections["kernels"] = kernel_microbench
    sections["roofline"] = roofline_summary

    ap = argparse.ArgumentParser(
        description="benchmark driver; no sections = run everything")
    ap.add_argument("sections", nargs="*", metavar="SECTION",
                    help=f"sections to run (default: all of "
                         f"{sorted(sections)})")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the BENCH JSON artifact here instead of "
                         "results/BENCH_runtime.json (a fresh path skips "
                         "the merge with the committed baseline)")
    args = ap.parse_args()

    wanted = args.sections or list(sections)
    unknown = [k for k in wanted if k not in sections]
    if unknown:
        print(f"unknown section(s) {unknown}; available: {sorted(sections)}",
              file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failures = 0
    section_s: dict = {}
    for key in wanted:
        t0 = time.perf_counter()
        try:
            for name, us, derived in sections[key]():
                print(f"{name},{us:.1f},{derived}")
        except AssertionError as e:
            failures += 1
            print(f"{key}/FAILED,0,{e}")
        section_s[key] = time.perf_counter() - t0
    write_bench_runtime(section_s, out=args.out)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
