"""Roofline table builder: reads results/dryrun/*.json (written by
launch/dryrun.py) and derives, per (arch x shape) cell on the single-pod
mesh:

  compute_s    = HLO_FLOPs_per_chip / 197e12
  memory_s     = HLO_bytes_per_chip / 819e9
  collective_s = link_bytes_per_chip / 50e9
  dominant     = argmax of the three
  model_ratio  = MODEL_FLOPS / HLO_FLOPs  (useful-compute fraction)
  roofline_frac= (MODEL_FLOPS_per_chip / 197e12) / dominant_s
                 — the fraction of the roofline the step achieves

Emits CSV rows + a markdown table (for EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.configs import SHAPES, all_names, applicable, get
from repro.launch import hw
from repro.launch.modelflops import model_flops

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cell(arch: str, shape: str, mesh: str = "single",
              tp_mode: Optional[str] = None) -> Optional[Dict]:
    tag = f".{tp_mode}" if tp_mode else ""
    p = RESULTS / f"{arch}.{shape}.{mesh}{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def derive(rec: Dict) -> Optional[Dict]:
    if not rec or rec.get("skipped") or not rec.get("ok"):
        return None
    cfg = get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = hw.CHIPS_MULTI_POD if rec["mesh"] == "multi" \
        else hw.CHIPS_SINGLE_POD
    compute_s = rec["flops"] / hw.PEAK_FLOPS
    memory_s = rec["hlo_bytes"] / hw.HBM_BW
    link = rec["collectives"].get("total_link_bytes_bf16") \
        or rec["collectives"]["total_link_bytes"]
    coll_s = link / hw.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / chips
    ratio = mf / rec["flops"] if rec["flops"] else 0.0
    frac = (mf / hw.PEAK_FLOPS) / max(terms[dominant], 1e-12)
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        step=rec.get("step", "?"), compute_s=compute_s, memory_s=memory_s,
        collective_s=coll_s, dominant=dominant, model_flops_per_chip=mf,
        hlo_flops=rec["flops"], model_ratio=ratio, roofline_frac=frac,
        mem_gib=rec["memory"]["peak_bytes_per_device"] / 2 ** 30,
    )


def fix_note(d: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    if d["dominant"] == "compute":
        if d["model_ratio"] < 0.5:
            return ("compute-bound but <50% useful: cut remat recompute "
                    "(selective checkpointing) and attention-mask FLOPs")
        return "compute-bound near useful peak: scale batch or accept"
    if d["dominant"] == "memory":
        if d["shape"].startswith("decode") or d["shape"].startswith("long"):
            return ("weight/KV streaming bound: quantize KV cache + fuse "
                    "decode matmuls (Pallas flash-decode keeps stats in VMEM)")
        return ("HBM-bound: fuse attention chain into the Pallas flash "
                "kernel (VMEM-resident scores) and drop f32 materialization")
    return ("collective-bound: switch TP dataflow (allgather vs allreduce), "
            "overlap grad sync with backward, compress cross-pod traffic")


def rows(mesh: str = "single") -> List[Dict]:
    out = []
    for arch in all_names():
        for shape in SHAPES:
            ok, _ = applicable(get(arch), SHAPES[shape])
            if not ok:
                continue
            d = derive(load_cell(arch, shape, mesh))
            if d:
                out.append(d)
    return out


def markdown_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | step | compute_s | memory_s | collective_s | "
        "dominant | MODEL/HLO | roofline_frac | mem GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows(mesh):
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['step']} "
            f"| {d['compute_s']:.4f} | {d['memory_s']:.4f} "
            f"| {d['collective_s']:.4f} | **{d['dominant']}** "
            f"| {d['model_ratio']:.2f} | {d['roofline_frac']:.3f} "
            f"| {d['mem_gib']:.1f} |")
    return "\n".join(lines)


def csv_rows() -> List[Tuple[str, float, str]]:
    out = []
    for d in rows("single"):
        out.append((
            f"roofline/{d['arch']}/{d['shape']}", 0.0,
            f"dom={d['dominant']} comp={d['compute_s']:.4f}s "
            f"mem={d['memory_s']:.4f}s coll={d['collective_s']:.4f}s "
            f"frac={d['roofline_frac']:.3f} ratio={d['model_ratio']:.2f}"))
    return out
