"""Benchmarks reproducing the paper's tables/figures from the calibrated
cost model + the functional PIM engine.

  fig7    — PEP cycle counts (operand dims annotated), paper Fig. 7
  fig8    — AME instruction cycles / FLOP-per-cycle / GFLOP/s, paper Fig. 8
  fig9    — mfmacc FLOP/cycle vs tile size scaling, paper Fig. 9
  table3  — comparison row vs MPC-Wrapper / RNN-T, paper Table 3
  channels— device-runtime multi-pseudo-channel scaling sweep (makespan
            semantics; the paper's named future work, via repro.runtime)
  residency— device-resident operands: steady-state decode h2d drops to
            activations-only, bit-exact with the fresh-transfer path, and
            the serve-loop decode offload roofline (dumps the
            ``results/dryrun/*.pim_offload.json`` BENCH artifact)

Each returns rows of (name, us_per_call, derived) where us_per_call is the
measured host execution time of the functional engine (small tiles; the
cycle numbers themselves are the calibrated model) and ``derived`` carries
the paper-comparable quantity.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import cost as cost_mod
from repro.core.engine import AMEEngine
from repro.core.isa import PIM_FREQ_HZ, THEORETICAL_PEAK_FLOP_PER_CYCLE
from repro.runtime import PIMRuntime, pim_gemm, pim_gemv

Row = Tuple[str, float, str]

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _time_engine(fn, reps=3) -> float:
    fn()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def fig7_pep_cycles() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)

    def run_ew(kind):
        eng = AMEEngine()
        a = jnp.asarray(rng.standard_normal((128, 64)), jnp.float16)
        eng.msettilek(64)
        eng.mld(0, a), eng.mld(1, a)
        getattr(eng, f"mf{kind}")(0, 0, 1)

    for kind in ("add", "mul", "sub"):
        rep = cost_mod.elementwise_cost(kind, 128, 2048)
        us = _time_engine(lambda k=kind: run_ew(k))
        rows.append((f"fig7/{kind}-pep_128x2048", us,
                     f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    rep = cost_mod.mfmacc_cost(128, 2048, 1)
    us = _time_engine(lambda: pim_gemv(
        jnp.asarray(rng.standard_normal((128, 256)), jnp.float16),
        jnp.asarray(rng.standard_normal((256,)), jnp.float16)))
    rows.append(("fig7/mac-pep_128x2048x1", us,
                 f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    rep = cost_mod.mfmacc_cost(128, 8, 256)
    rows.append(("fig7/mac-pep_128x8x256", us,
                 f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    return rows


def fig8_ame_instructions() -> List[Row]:
    rows = []
    for name, rep in [
        ("mfadd.h_128x4096", cost_mod.elementwise_cost("add", 128, 4096)),
        ("mfmul.h_128x4096", cost_mod.elementwise_cost("mul", 128, 4096)),
        ("mfsub.h_128x4096", cost_mod.elementwise_cost("sub", 128, 4096)),
        ("mfmacc.h_128x4096", cost_mod.mfmacc_cost(128, 4096, 128)),
    ]:
        rows.append((f"fig8/{name}", 0.0,
                     f"cycles={rep.cycles:.0f} flop/cyc={rep.flop_per_cycle:.2f} "
                     f"gflops={rep.gflops:.2f} launches={rep.launches}"))
    sat = cost_mod.saturated_flop_per_cycle("mac")
    rows.append(("fig8/mfmacc_saturated", 0.0,
                 f"flop/cyc={sat:.2f} paper=59.4 "
                 f"gflops={sat * PIM_FREQ_HZ / 1e9:.2f} paper_gflops=14.9"))
    # paper reproduction gates
    assert abs(sat - 59.4) < 0.1, sat
    assert abs(sat * PIM_FREQ_HZ / 1e9 - 14.9) < 0.1
    assert cost_mod.mfmacc_cost(128, 4096, 128).launches == 256
    assert sat <= THEORETICAL_PEAK_FLOP_PER_CYCLE / 2
    return rows


def fig9_tile_scaling() -> List[Row]:
    rows = []
    for k in (8, 16, 64, 128, 256, 512, 1024, 2048):
        rep = cost_mod.mfmacc_cost(128, k, 1)
        rows.append((f"fig9/mfmacc_128x{k}x1", 0.0,
                     f"flop/cyc={rep.flop_per_cycle:.2f}"))
    r88 = cost_mod.mfmacc_cost(128, 8, 256)   # (*) same perf as 128x2048x1
    rows.append(("fig9/mfmacc_128x8x256", 0.0,
                 f"flop/cyc={r88.flop_per_cycle:.2f}"))
    return rows


def table3_comparison() -> List[Row]:
    ours = cost_mod.saturated_flop_per_cycle("mac")
    rows = [
        ("table3/this-work", 0.0,
         f"pchannels=1 inmem_acc=yes elementwise=yes gemv+gemm=yes "
         f"flop/cyc={ours:.1f}"),
        ("table3/mpc-wrapper", 0.0,
         "pchannels=16 inmem_acc=no elementwise=no gemv_only=yes "
         "flop/cyc=58.1"),
        ("table3/rnn-t", 0.0,
         "pchannels=1 inmem_acc=no gemv_only=yes flop/cyc=n.a."),
        ("table3/multichannel-16", 0.0,
         f"pchannels=16 aggregate_gflops="
         f"{16 * ours * PIM_FREQ_HZ / 1e9:.1f} "
         "(upper bound; see `channels` sweep for makespan-based scaling)"),
    ]
    assert ours > 58.1  # the paper's headline comparison
    return rows


def channel_sweep() -> List[Row]:
    """Multi-pseudo-channel scaling through the device runtime (analytic
    cost mode — same ledgers as numeric execution, property-tested).

    Reports makespan-based speedup and per-channel utilization for the
    paper-scale GEMM (512x4096x512, 2d-block placement: at 16 channels
    every channel executes exactly the paper's 128x4096x128 max tile) and
    a skinny GEMV where AMD-style balanced placement must beat naive row
    striping to scale at all.
    """
    rows = []
    # paper reproduction gate: the single-channel engine underneath the
    # runtime still hits the 59.4 FLOP/cycle headline at max tile
    sat = cost_mod.saturated_flop_per_cycle("mac")
    assert abs(sat - 59.4) < 0.1, sat
    head = cost_mod.max_tile_mfmacc()
    rows.append(("channels/maxtile_mfmacc_1ch", 0.0,
                 f"flop/cyc={head.flop_per_cycle:.1f} "
                 f"saturated={sat:.1f} paper=59.4"))

    def sweep(tag, m, k, n, placement):
        a = np.zeros((m, k), np.float16)      # analytic mode: shapes only
        b = np.zeros((k, n), np.float16)
        base = None
        out = []
        for ch in (1, 2, 4, 8, 16):
            _, rep = pim_gemm(a, b, channels=ch, placement=placement,
                              execute=False)
            base = base or rep.makespan_cycles
            us = rep.utilizations()
            busy = sum(1 for c in rep.per_channel if c.busy_cycles > 0)
            out.append((f"channels/{tag}_{placement}_{ch}ch", 0.0,
                        f"makespan={rep.makespan_cycles:.0f} "
                        f"speedup={base / rep.makespan_cycles:.2f} "
                        f"gflops={rep.gflops:.1f} busy={busy} "
                        f"util_mean={sum(us) / len(us):.2f} "
                        f"util_min={min(us):.2f}"))
        return out, base / rep.makespan_cycles, rep.makespan_cycles

    gemm_rows, gemm_speedup, _ = sweep("gemm_512x4096x512",
                                       512, 4096, 512, "2d-block")
    rows += gemm_rows
    rs_rows, _, rs_makespan = sweep("gemv_256x8192", 256, 8192, 1,
                                    "row-striped")
    rows += rs_rows
    bal_rows, bal_speedup, bal_makespan = sweep("gemv_256x8192",
                                                256, 8192, 1, "balanced")
    rows += bal_rows

    # scaling gates: GEMM scales near-linearly in makespan; balanced
    # placement beats row striping on the skinny GEMV (AMD's result)
    assert gemm_speedup > 10, gemm_speedup
    assert bal_makespan < rs_makespan, (bal_makespan, rs_makespan)
    rows.append(("channels/gemv_balanced_vs_striped_16ch", 0.0,
                 f"balanced_makespan={bal_makespan:.0f} "
                 f"striped_makespan={rs_makespan:.0f} "
                 f"advantage={rs_makespan / bal_makespan:.2f}x"))
    return rows


def residency_sweep() -> List[Row]:
    """Device-resident operands (the serve-loop decode regime).

    Steady-state gate: with weights placed once (``PIMRuntime.place``),
    every decode GEMV's h2d traffic is the activation vector alone — the
    weight re-transfer of the fresh path shows up entirely as resident
    reuse, and outputs stay bit-exact with fresh transfers at 1, 4 and 16
    channels.  Also accounts the GEMM->elementwise epilogue fusion and
    dumps the serve decode-offload roofline artifact.
    """
    rows = []
    rng = np.random.default_rng(3)
    m, k, steps = 256, 2048, 3

    def rand(*shape):
        return (rng.standard_normal(shape) * 0.1).astype(np.float16)

    a = rand(m, k)
    xs = [rand(k) for _ in range(steps)]
    for ch in (1, 4, 16):
        rt_fresh, rt_res = PIMRuntime(channels=ch), PIMRuntime(channels=ch)
        w = rt_res.place(a, placement="balanced")
        weight_upload = sum(d.xfer.h2d_bytes for d in rt_res.stack)
        fresh_h2d = res_h2d = res_reuse = 0
        for t in range(steps):
            y_f, rep_f = rt_fresh.gemv(a, xs[t], placement="balanced")
            y_r, rep_r = rt_res.gemv(w, xs[t], placement="balanced")
            # acceptance: resident path bit-exact with fresh transfers
            assert np.array_equal(np.asarray(y_f), np.asarray(y_r)), ch
            # acceptance: resident h2d = activations only — the h2d the
            # fresh path ships on top is exactly the residency reuse, and
            # within-op x-slice dedupe is identical on both paths
            assert rep_f.total_h2d_bytes - rep_r.total_h2d_bytes \
                == rep_r.total_reuse_bytes, ch
            assert rep_r.total_dedupe_bytes == rep_f.total_dedupe_bytes, ch
            assert rep_f.total_reuse_bytes == 0, ch
            assert rep_r.total_d2h_bytes == rep_f.total_d2h_bytes, ch
            if t > 0:      # steady state: no weight re-transfer at all
                assert rep_r.total_h2d_bytes == res_h2d, ch
            fresh_h2d, res_h2d = rep_f.total_h2d_bytes, rep_r.total_h2d_bytes
            res_reuse = rep_r.total_reuse_bytes
        assert res_h2d < fresh_h2d
        rows.append((f"residency/gemv_{m}x{k}_{ch}ch", 0.0,
                     f"fresh_h2d={fresh_h2d} resident_h2d={res_h2d} "
                     f"reuse={res_reuse} upload_once={weight_upload} "
                     f"h2d_cut={fresh_h2d / res_h2d:.1f}x bit_exact=yes"))

    # GEMM -> elementwise epilogue: intermediate never round-trips
    rt = PIMRuntime(channels=4)
    b, c = rand(k, 64), rand(m, 64)
    h, rep_g = rt.gemm(a, b, placement="row-striped", keep_output=True)
    _, rep_e = rt.elementwise("add", h, c, placement="row-striped")
    assert rep_g.total_d2h_bytes == 0          # output stayed resident
    assert rep_e.total_h2d_bytes == c.size * 2  # only the epilogue operand
    rows.append(("residency/gemm_ew_epilogue_4ch", 0.0,
                 f"gemm_d2h={rep_g.total_d2h_bytes} "
                 f"ew_h2d={rep_e.total_h2d_bytes} "
                 f"ew_reuse={rep_e.total_reuse_bytes} fused=yes"))

    # serve-loop decode offload roofline (analytic, reduced config) + the
    # BENCH artifact for future cost-model regressions
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=16, placement="balanced")
    for _ in range(steps):
        rec = off.step(4)
    assert rec.reuse_bytes == off.weight_bytes      # weights fully amortized
    assert all(s.h2d_bytes == rec.h2d_bytes for s in off.steps)
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{cfg.name}.decode.pim_offload.json"
    roof = off.dump(str(out))
    rows.append((f"residency/serve_offload_{cfg.name}_16ch", 0.0,
                 f"steady_h2d={roof['steady_h2d_bytes']} "
                 f"weights={roof['weight_bytes']} "
                 f"pim_s={roof['steady_pim_s']:.2e} "
                 f"host_s={roof['steady_host_s']:.2e} "
                 f"host_bound={roof['steady_host_bound']} "
                 f"artifact={out.name}"))
    return rows


ALL = {
    "fig7": fig7_pep_cycles,
    "fig8": fig8_ame_instructions,
    "fig9": fig9_tile_scaling,
    "table3": table3_comparison,
    "channels": channel_sweep,
    "residency": residency_sweep,
}
